"""Property tests for ``core.chunking``: split/join, serialization, and
k-replica placement (hypothesis; each has the seed-level example inline
so the file still exercises the contract when hypothesis is stubbed)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunking import (
    arrays_to_bytes,
    bytes_to_arrays,
    bytes_to_dequantized,
    chunk_server,
    dequantize_int8,
    join_chunks,
    num_chunks,
    quantize_int8,
    quantized_to_bytes,
    replica_delta,
    split_chunks,
)


@given(data=st.binary(max_size=8192), chunk=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_split_join_roundtrip(data, chunk):
    chunks = split_chunks(data, chunk)
    assert join_chunks(chunks) == data
    assert all(len(c) <= chunk for c in chunks)
    # only the final chunk may be ragged (empty payloads keep one
    # sentinel chunk so the block still exists on a server)
    assert all(len(c) == chunk for c in chunks[:-1])
    assert len(chunks) >= 1


@given(data=st.binary(max_size=8192), chunk=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_num_chunks_consistent_with_split(data, chunk):
    assert num_chunks(len(data), chunk) == len(split_chunks(data, chunk))


def _arrays(draw_f32=True):
    """Strategy for lists of small arrays with mixed shapes/dtypes."""
    dtypes = [np.float32, np.int32] if not draw_f32 else [np.float32]
    return st.lists(
        st.tuples(
            st.sampled_from(dtypes),
            st.lists(st.integers(0, 5), min_size=0, max_size=3),
            st.integers(0, 2**32 - 1),
        ),
        min_size=0, max_size=4,
    )


def _build(specs):
    out = []
    for dt, shape, seed in specs:
        rng = np.random.default_rng(seed)
        n = int(np.prod(shape)) if shape else 1
        a = rng.standard_normal(n).astype(np.float32) * 100
        out.append(a.astype(dt).reshape(shape))
    return out


@given(specs=_arrays(draw_f32=False))
@settings(max_examples=60, deadline=None)
def test_serialize_roundtrip(specs):
    arrays = _build(specs)
    back = bytes_to_arrays(arrays_to_bytes(arrays))
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(a, b)


@given(specs=_arrays())
@settings(max_examples=60, deadline=None)
def test_quantized_serialize_roundtrip(specs):
    """Serialization adds zero error on top of int8 quantization: the
    wire round trip equals quantize->dequantize applied in memory."""
    arrays = _build(specs)
    back = bytes_to_dequantized(quantized_to_bytes(arrays))
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        direct = dequantize_int8(quantize_int8(a))
        assert np.array_equal(direct, b)
        # quantization error itself is bounded by one step per channel
        if a.size:
            step = np.abs(a).max() / 127.0
            assert np.abs(direct - np.asarray(a, np.float32)).max() <= (
                step + 1e-6)


@given(
    num_planes=st.integers(1, 24),
    sats_per_plane=st.integers(1, 24),
    k=st.integers(1, 32),
    base_plane=st.integers(0, 23),
    base_slot=st.integers(0, 23),
)
@settings(max_examples=200, deadline=None)
def test_replica_placement_never_shares_a_satellite(
        num_planes, sats_per_plane, k, base_plane, base_slot):
    """No two replicas of a chunk on the same satellite (while the
    constellation has enough satellites), and plane-diversity while
    k <= planes -- for ANY base placement, because the offsets compose
    with the base modulo the torus."""
    k = min(k, num_planes * sats_per_plane)
    homes = set()
    planes = set()
    for r in range(k):
        dp, ds = replica_delta(r, num_planes, sats_per_plane)
        sat = ((base_plane + dp) % num_planes,
               (base_slot + ds) % sats_per_plane)
        homes.add(sat)
        planes.add(sat[0])
    assert len(homes) == k
    if k <= num_planes:
        assert len(planes) == k
    # replica 0 is always the base server satellite itself
    assert replica_delta(0, num_planes, sats_per_plane) == (0, 0)


@given(cid=st.integers(0, 10**6), n=st.integers(1, 512))
@settings(max_examples=60, deadline=None)
def test_chunk_server_is_base_striping(cid, n):
    sid = chunk_server(cid, n)
    assert 0 <= sid < n
    assert sid == cid % n
