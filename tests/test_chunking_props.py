"""Property tests for ``core.chunking``: split/join, serialization,
k-replica placement, and the versioned payload codec (hypothesis; each
has the seed-level example inline so the file still exercises the
contract when hypothesis is stubbed)."""
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunking import (
    PayloadCodec,
    arrays_to_bytes,
    bytes_to_arrays,
    bytes_to_dequantized,
    cat_payloads,
    chunk_server,
    decode_payload_arrays,
    delta_info,
    dequantize_int8,
    encode_arrays,
    join_chunks,
    make_delta_payload,
    num_chunks,
    payload_raw_bytes,
    quantize_int8,
    quantized_to_bytes,
    replica_delta,
    split_chunks,
)

_BF16 = np.dtype(ml_dtypes.bfloat16)


@given(data=st.binary(max_size=8192), chunk=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_split_join_roundtrip(data, chunk):
    chunks = split_chunks(data, chunk)
    assert join_chunks(chunks) == data
    assert all(len(c) <= chunk for c in chunks)
    # only the final chunk may be ragged (empty payloads keep one
    # sentinel chunk so the block still exists on a server)
    assert all(len(c) == chunk for c in chunks[:-1])
    assert len(chunks) >= 1


@given(data=st.binary(max_size=8192), chunk=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_num_chunks_consistent_with_split(data, chunk):
    assert num_chunks(len(data), chunk) == len(split_chunks(data, chunk))


def _arrays(draw_f32=True):
    """Strategy for lists of small arrays with mixed shapes/dtypes."""
    dtypes = [np.float32, np.int32] if not draw_f32 else [np.float32]
    return st.lists(
        st.tuples(
            st.sampled_from(dtypes),
            st.lists(st.integers(0, 5), min_size=0, max_size=3),
            st.integers(0, 2**32 - 1),
        ),
        min_size=0, max_size=4,
    )


def _build(specs):
    out = []
    for dt, shape, seed in specs:
        rng = np.random.default_rng(seed)
        n = int(np.prod(shape)) if shape else 1
        a = rng.standard_normal(n).astype(np.float32) * 100
        out.append(a.astype(dt).reshape(shape))
    return out


@given(specs=_arrays(draw_f32=False))
@settings(max_examples=60, deadline=None)
def test_serialize_roundtrip(specs):
    arrays = _build(specs)
    back = bytes_to_arrays(arrays_to_bytes(arrays))
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert np.array_equal(a, b)


@given(specs=_arrays())
@settings(max_examples=60, deadline=None)
def test_quantized_serialize_roundtrip(specs):
    """Serialization adds zero error on top of int8 quantization: the
    wire round trip equals quantize->dequantize applied in memory."""
    arrays = _build(specs)
    back = bytes_to_dequantized(quantized_to_bytes(arrays))
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        direct = dequantize_int8(quantize_int8(a))
        assert np.array_equal(direct, b)
        # quantization error itself is bounded by one step per channel
        if a.size:
            step = np.abs(a).max() / 127.0
            assert np.abs(direct - np.asarray(a, np.float32)).max() <= (
                step + 1e-6)


@given(
    num_planes=st.integers(1, 24),
    sats_per_plane=st.integers(1, 24),
    k=st.integers(1, 32),
    base_plane=st.integers(0, 23),
    base_slot=st.integers(0, 23),
)
@settings(max_examples=200, deadline=None)
def test_replica_placement_never_shares_a_satellite(
        num_planes, sats_per_plane, k, base_plane, base_slot):
    """No two replicas of a chunk on the same satellite (while the
    constellation has enough satellites), and plane-diversity while
    k <= planes -- for ANY base placement, because the offsets compose
    with the base modulo the torus."""
    k = min(k, num_planes * sats_per_plane)
    homes = set()
    planes = set()
    for r in range(k):
        dp, ds = replica_delta(r, num_planes, sats_per_plane)
        sat = ((base_plane + dp) % num_planes,
               (base_slot + ds) % sats_per_plane)
        homes.add(sat)
        planes.add(sat[0])
    assert len(homes) == k
    if k <= num_planes:
        assert len(planes) == k
    # replica 0 is always the base server satellite itself
    assert replica_delta(0, num_planes, sats_per_plane) == (0, 0)


@given(cid=st.integers(0, 10**6), n=st.integers(1, 512))
@settings(max_examples=60, deadline=None)
def test_chunk_server_is_base_striping(cid, n):
    sid = chunk_server(cid, n)
    assert 0 <= sid < n
    assert sid == cid % n


# ---------------------------------------------------------------------------
# The versioned payload codec (SKYC containers)
# ---------------------------------------------------------------------------

def _kv_array(dtype, n_tok, chans, seed):
    """A KVC-shaped [L, T, C] array (token axis 1, channels last)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((2, n_tok, chans)).astype(np.float32) * 10
    return a.astype(dtype)


@given(
    name=st.sampled_from(["int8", "int4"]),
    src=st.sampled_from(["float32", "bfloat16"]),
    seg=st.sampled_from([0, 3, 8]),
    n_tok=st.sampled_from([0, 1, 5, 17]),
    chans=st.integers(1, 6),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=80, deadline=None)
def test_codec_roundtrip_restores_dtype_and_shape(
        name, src, seg, n_tok, chans, seed):
    """Every quantized codec x source dtype x scale-table chunking
    (0 = whole tensor, ragged and exact chunkings, empty tensors) round
    trips to the recorded dtype/shape, deterministically, with a
    header-only raw-byte scan that is exact."""
    dt = _BF16 if src == "bfloat16" else np.dtype(np.float32)
    a = _kv_array(dt, n_tok, chans, seed)
    codec = PayloadCodec(name, seg)
    enc = encode_arrays([a], codec)
    assert encode_arrays([a], codec) == enc          # deterministic
    (back,) = decode_payload_arrays(enc)
    assert back.dtype == dt and back.shape == a.shape
    assert payload_raw_bytes(enc) == a.nbytes        # header-only scan
    assert decode_payload_arrays(enc)[0].tobytes() == back.tobytes()
    if a.size:
        qmax = 127.0 if name == "int8" else 7.0
        af = np.asarray(a, np.float32)
        err = np.abs(np.asarray(back, np.float32) - af)
        # one quantization step (of the global amax -- per-chunk scales
        # are never larger), plus bf16 output rounding (<= amax/128)
        amax = np.abs(af).max()
        bound = amax / qmax + (amax / 128.0 if dt == _BF16 else 0.0)
        assert err.max() <= bound + 1e-6


@given(
    name=st.sampled_from(["int8", "int4"]),
    n_blocks=st.integers(1, 4),
    bt=st.sampled_from([2, 4]),
    chans=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=60, deadline=None)
def test_delta_chain_cat_decode_matches_full_encode(
        name, n_blocks, bt, chans, seed):
    """A delta chain (base + per-block deltas, cat-reassembled) decodes
    EXACTLY like the full array encoded in one shot: scale-table chunks
    align with block boundaries, so quantizing per block is quantizing
    per chunk."""
    codec = PayloadCodec(name, bt)
    a = _kv_array(np.float32, n_blocks * bt, chans, seed)
    (full,) = decode_payload_arrays(encode_arrays([a], codec))
    segs = []
    for i in range(n_blocks):
        inner = encode_arrays([a[:, i * bt:(i + 1) * bt]], codec)
        segs.append(inner if i == 0 else
                    make_delta_payload(inner, b"\x01" * 32, i * bt))
    cat = cat_payloads(segs)
    (out,) = decode_payload_arrays(cat)
    assert out.dtype == full.dtype and out.shape == full.shape
    assert np.array_equal(out, full)
    # back-pointers round trip, and the raw scan sums the segments
    if n_blocks > 1:
        prev_hash, prev_tokens, inner = delta_info(segs[1])
        assert prev_hash == b"\x01" * 32 and prev_tokens == bt
        assert decode_payload_arrays(inner)[0].shape[1] == bt
    assert payload_raw_bytes(cat) == a.nbytes


@given(
    name=st.sampled_from(["int8", "int4"]),
    seed=st.integers(0, 2**32 - 1),
    frac=st.floats(0.0, 1.0),
)
@settings(max_examples=80, deadline=None)
def test_truncated_codec_payload_rejected(name, seed, frac):
    """Any strict prefix of a quantized container fails loudly with
    ValueError -- no decoder ever returns short arrays from short
    bytes."""
    a = _kv_array(np.float32, 6, 4, seed)
    enc = encode_arrays([a, a + 1.0], PayloadCodec(name, 4))
    cut = min(int(len(enc) * frac), len(enc) - 1)
    with pytest.raises(ValueError):
        decode_payload_arrays(enc[:cut])


@given(seed=st.integers(0, 2**32 - 1), frac=st.floats(0.3, 1.0))
@settings(max_examples=40, deadline=None)
def test_truncated_delta_and_cat_rejected(seed, frac):
    a = _kv_array(np.float32, 4, 3, seed)
    inner = encode_arrays([a], PayloadCodec("int8", 4))
    delta = make_delta_payload(inner, b"\x02" * 32, 4)
    cat = cat_payloads([inner, delta])
    for payload in (delta, cat):
        cut = min(int(len(payload) * frac), len(payload) - 1)
        with pytest.raises(ValueError):
            decode_payload_arrays(payload[:cut])


@given(specs=_arrays())
@settings(max_examples=40, deadline=None)
def test_f32_codec_is_byte_identical_legacy(specs):
    """The default codec emits the legacy SKYM container byte-for-byte,
    so an upgraded fabric reads old payloads and vice versa."""
    arrays = _build(specs)
    assert encode_arrays(arrays, PayloadCodec("f32")) == (
        arrays_to_bytes(arrays))
