"""Hashing, chunking, store, radix, and the Set/Get protocol (paper §3)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunking import (
    arrays_to_bytes,
    bytes_to_arrays,
    bytes_to_dequantized,
    join_chunks,
    num_chunks,
    quantized_to_bytes,
    split_chunks,
)
from repro.core.constellation import ConstellationSpec, LosWindow, Sat
from repro.core.eviction import gossip_cost, run_periodic_sweep
from repro.core.hashing import NULL_HASH, chain_hashes, hash_block, split_token_blocks
from repro.core.mapping import Strategy
from repro.core.protocol import ConstellationKVC, IslTransport, KVCManager
from repro.core.radix import BlockMeta, RadixBlockIndex
from repro.core.store import SatelliteStore


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------

@given(tokens=st.lists(st.integers(0, 2**31 - 1), max_size=600),
       block=st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_chain_hash_prefix_property(tokens, block):
    """hash_i covers blocks 1..i: equal prefixes give equal hash prefixes."""
    h = chain_hashes(tokens, block)
    assert len(h) == len(tokens) // block
    # a prompt extending this one shares the full hash prefix
    h2 = chain_hashes(tokens + [1, 2, 3], block)
    assert h2[: len(h)] == h
    # mutating any token changes every subsequent hash
    if tokens and len(h) >= 1:
        t2 = list(tokens)
        t2[0] = t2[0] ^ 1
        h3 = chain_hashes(t2, block)
        assert all(a != b for a, b in zip(h, h3))


def test_hash_block_depends_on_prev():
    a = hash_block(NULL_HASH, [1, 2, 3])
    b = hash_block(a, [1, 2, 3])
    assert a != b
    assert len(a) == 32


def test_split_token_blocks_full_only():
    assert split_token_blocks([1, 2, 3, 4, 5], 2) == [(1, 2), (3, 4)]
    assert split_token_blocks([1, 2, 3, 4, 5], 2, full_only=False)[-1] == (5,)


# ---------------------------------------------------------------------------
# chunking / serialization
# ---------------------------------------------------------------------------

@given(data=st.binary(max_size=4096), chunk=st.integers(1, 512))
@settings(max_examples=80, deadline=None)
def test_chunk_roundtrip(data, chunk):
    chunks = split_chunks(data, chunk)
    assert join_chunks(chunks) == data
    assert len(chunks) == num_chunks(len(data), chunk)
    assert all(len(c) <= chunk for c in chunks)
    if data:
        assert all(len(c) == chunk for c in chunks[:-1])


def test_array_serialization_roundtrip():
    arrays = [
        np.arange(24, dtype=np.float32).reshape(2, 3, 4),
        np.array([[1, 2]], dtype=np.int8),
        (np.arange(8) / 3).astype(np.float16),
    ]
    back = bytes_to_arrays(arrays_to_bytes(arrays))
    assert len(back) == len(arrays)
    for a, b in zip(arrays, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_int8_quantized_roundtrip_close():
    rng = np.random.default_rng(0)
    arrays = [rng.normal(size=(4, 16, 8)).astype(np.float32)]
    back = bytes_to_dequantized(quantized_to_bytes(arrays))
    err = np.max(np.abs(back[0] - arrays[0]))
    scale = np.max(np.abs(arrays[0]))
    assert err <= scale / 127.0 * 1.01  # one quantization step


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

def test_store_lru_eviction_order():
    evicted = []
    s = SatelliteStore(
        capacity_bytes=10, on_evict=lambda st_, k, v_: evicted.append(k))
    s.set((b"a", 0), b"xxxx")
    s.set((b"b", 0), b"yyyy")
    assert s.get((b"a", 0)) == b"xxxx"  # touch a -> b becomes LRU
    s.set((b"c", 0), b"zzzz")           # 12 bytes > 10 -> evict b
    assert evicted == [(b"b", 0)]
    assert s.get((b"b", 0)) is None
    assert s.get((b"a", 0)) == b"xxxx"


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------

def _meta(i):
    return BlockMeta(n_chunks=i + 1, set_time=float(i))


def test_radix_longest_prefix_and_removal():
    idx = RadixBlockIndex()
    h = chain_hashes(list(range(512)), 64)  # 8 blocks
    idx.insert(h, [_meta(i) for i in range(8)])
    n, meta = idx.longest_cached_prefix(h)
    assert n == 8 and meta.n_chunks == 8
    # diverging suffix matches only the shared prefix
    h2 = chain_hashes(list(range(256)) + [999] * 256, 64)
    n2, m2 = idx.longest_cached_prefix(h2)
    assert n2 == 4 and m2.n_chunks == 4
    assert idx.remove(h[:6]) is True
    n3, m3 = idx.longest_cached_prefix(h[:6])
    assert n3 == 5
    assert len(idx) == 7


@given(
    base=st.lists(st.integers(0, 100), min_size=0, max_size=8),
    probe=st.lists(st.integers(0, 100), min_size=0, max_size=8),
)
@settings(max_examples=100, deadline=None)
def test_radix_prefix_matches_naive(base, probe):
    """Radix longest-prefix equals the naive common-prefix computation."""
    bh = chain_hashes([t for t in base for _ in range(4)], 4)
    ph = chain_hashes([t for t in probe for _ in range(4)], 4)
    idx = RadixBlockIndex()
    idx.insert(bh, [_meta(i) for i in range(len(bh))])
    n, _ = idx.longest_cached_prefix(ph)
    naive = 0
    for a, b in zip(bh, ph):
        if a != b:
            break
        naive += 1
    assert n == naive


# ---------------------------------------------------------------------------
# constellation KVC protocol
# ---------------------------------------------------------------------------

SPEC = ConstellationSpec(num_planes=15, sats_per_plane=15, altitude_km=550.0)


def make_kvc(strategy=Strategy.ROTATION_HOP, **kw):
    window = LosWindow(Sat(7, 7), 9, 9)
    return ConstellationKVC(
        SPEC, window, strategy, num_servers=10, chunk_bytes=64, **kw
    )


def test_set_get_roundtrip_and_striping():
    kvc = make_kvc()
    payload = bytes(range(256)) * 3  # 768 bytes -> 12 chunks over 10 servers
    meta = kvc.set_block(b"h1" * 16, payload)
    assert meta.n_chunks == 12
    # chunks striped chunk_id mod 10: server 0 holds chunks 0 and 10
    s0 = kvc.store_for(kvc.server_sat(0))
    assert s0.contains((b"h1" * 16, 0)) and s0.contains((b"h1" * 16, 10))
    assert kvc.get_block(b"h1" * 16) == payload
    assert kvc.stats.block_hits == 1


def test_missing_chunk_fails_block_and_lazy_evicts():
    kvc = make_kvc()
    h = b"h2" * 16
    kvc.set_block(h, b"z" * 640)
    # kill one chunk on its satellite
    kvc.store_for(kvc.server_sat(3)).delete((h, 3))
    assert kvc.get_block(h) is None
    assert kvc.stats.block_misses == 1
    # lazy eviction purged the remainder
    assert all(
        not kvc.store_for(kvc.server_sat(i % 10)).contains((h, i))
        for i in range(10)
    )


def test_lookup_longest_binary_search():
    kvc = make_kvc()
    hashes = chain_hashes(list(range(640)), 64)  # 10 blocks
    for h in hashes[:6]:
        kvc.set_block(h, b"p" * 100)
    assert kvc.lookup_longest(hashes) == 6
    assert kvc.lookup_longest(hashes[:3]) == 3
    assert kvc.lookup_longest([b"nope" * 8]) == 0


def test_rotation_migration_preserves_blocks():
    kvc = make_kvc()
    h = b"h3" * 16
    payload = b"q" * 1000
    kvc.set_block(h, payload)
    before = list(kvc.server_map)
    moves = kvc.rotate(steps=3)
    assert kvc.get_block(h) == payload
    # every migrated server stayed in its orbital plane (paper §3.4)
    for mv in moves:
        assert mv.src.plane == mv.dst.plane
    # servers that left LOS were remapped
    assert kvc.server_map != before or not moves
    for sat in kvc.server_map:
        assert kvc.window.contains(SPEC, sat)


def test_rotation_many_steps_stays_consistent():
    """Blocks survive an arbitrary number of rotation steps; every server
    remains inside LOS and within its original orbital plane."""
    kvc = make_kvc()
    h = b"h4" * 16
    planes0 = [s.plane for s in kvc.server_map]
    kvc.set_block(h, b"r" * 500)
    kvc.rotate(steps=2 * SPEC.sats_per_plane + 3)
    assert kvc.get_block(h) == b"r" * 500
    assert [s.plane for s in kvc.server_map] == planes0
    for sat in kvc.server_map:
        assert kvc.window.contains(SPEC, sat)


def test_hop_strategy_never_migrates():
    kvc = make_kvc(strategy=Strategy.HOP)
    h = b"h5" * 16
    kvc.set_block(h, b"s" * 300)
    before = list(kvc.server_map)
    moves = kvc.rotate(steps=4)
    assert moves == [] and kvc.server_map == before
    assert kvc.get_block(h) == b"s" * 300


def test_capacity_eviction_invalidates_whole_block():
    kvc = make_kvc(per_sat_capacity_bytes=128)
    h1, h2, h3 = b"a" * 32, b"b" * 32, b"c" * 32
    kvc.set_block(h1, b"1" * 640)
    kvc.set_block(h2, b"2" * 640)
    kvc.set_block(h3, b"3" * 640)  # pressure: each sat holds 64B/block
    # at most 2 blocks fit; the oldest must be fully gone
    assert kvc.get_block(h1) is None
    assert kvc.get_block(h3) == b"3" * 640


def test_gossip_cost_and_sweep():
    kvc = make_kvc()
    h = b"g" * 32
    kvc.set_block(h, b"x" * 640)
    cost = gossip_cost(kvc, h)
    assert cost.messages == 9  # 10 servers minus origin
    assert cost.max_hops >= 1
    kvc.store_for(kvc.server_sat(5)).delete((h, 5))
    assert run_periodic_sweep(kvc) == 1
    assert kvc.get_block(h) is None


def test_transport_accounting():
    t = IslTransport(SPEC, ground_hosted=True, chunk_processing_time_s=0.001)
    kvc = make_kvc(transport=t)
    kvc.set_block(b"t" * 32, b"y" * 640)
    # 10 chunk writes + 1 directory-stripe register (0 payload bytes)
    assert t.stats.messages == 11
    assert t.stats.bytes_moved == 640
    assert t.stats.op_latencies_s[-1] > 550.0 / 299792.458  # at least uplink


# ---------------------------------------------------------------------------
# KVCManager end-to-end (paper §3.3 interface)
# ---------------------------------------------------------------------------

def _tokenize(prompt: str) -> list[int]:
    return [ord(c) for c in prompt]


def _fake_kvc_fn(tokens, past, past_len):
    # deterministic "KV cache": cumulative sum bytes of the tokens
    arr = np.cumsum(np.asarray(tokens, dtype=np.int64))
    return arrays_to_bytes([arr])


def make_manager(block_size=16, use_radix=True):
    kvc = make_kvc()
    return KVCManager(
        _tokenize, _fake_kvc_fn, kvc, block_size=block_size, use_radix=use_radix
    )


@pytest.mark.parametrize("use_radix", [True, False])
def test_manager_add_then_get(use_radix):
    mgr = make_manager(use_radix=use_radix)
    prompt = "The quick brown fox jumps over the lazy dog, twice over."
    added = mgr.add_blocks(prompt)
    assert added == len(prompt) // 16
    payload, n_tokens = mgr.get_cache(prompt)
    assert n_tokens == (len(prompt) // 16) * 16
    expected = _fake_kvc_fn(_tokenize(prompt)[:n_tokens], None, 0)
    assert payload == expected


def test_manager_prefix_reuse_only_computes_suffix():
    mgr = make_manager()
    base = "shared prefix of meaningful length!!"  # 36 chars -> 2 blocks
    added1 = mgr.add_blocks(base)
    assert added1 == 2
    added2 = mgr.add_blocks(base + " and a different continuation here")
    assert added2 > 0
    # the shared 2 blocks were not recomputed
    assert added2 == (len(base + " and a different continuation here") // 16) - 2


def test_manager_miss_returns_empty():
    mgr = make_manager()
    payload, n = mgr.get_cache("never seen before prompt")
    assert payload is None and n == 0


def test_manager_survives_eviction_under_it():
    mgr = make_manager()
    prompt = "a" * 64  # 4 blocks
    mgr.add_blocks(prompt)
    # purge the final block behind the manager's back
    from repro.core.hashing import chain_hashes as ch

    hashes = ch(_tokenize(prompt), 16)
    mgr.cache.purge_block(hashes[-1])
    payload, n = mgr.get_cache(prompt)
    assert n == 48  # falls back to the longest still-complete prefix
    assert payload is not None


def test_prefetch_for_rotation_prepositions_chunks():
    """Paper §3.7: predicted future LOS windows are known exactly, so
    chunks can be made available on those satellites ahead of time."""
    kvc = make_kvc()
    h = b"pf" * 16
    kvc.set_block(h, b"z" * 640)
    copied = kvc.prefetch_for_rotation(h, steps=5)
    assert copied > 0
    # simulate the future placement and verify chunks are already there
    import copy as _copy

    from repro.core import migration as mig
    future_window = kvc.window
    future_map = list(kvc.server_map)
    for _ in range(5):
        nw = future_window.shifted(SPEC, d_slot=1)
        for mv in mig.plan_migration(SPEC, future_window, nw, future_map):
            future_map[mv.server_id - 1] = mv.dst
        future_window = nw
    present = sum(
        1 for cid in range(kvc.directory[h])
        if kvc.store_for(future_map[cid % kvc.num_servers]).contains((h, cid))
    )
    assert present == kvc.directory[h]
    # rotation still works and the block remains retrievable
    kvc.rotate(steps=5)
    assert kvc.get_block(h) == b"z" * 640


def test_prefetch_noop_for_onboard_hop_strategy():
    kvc = make_kvc(strategy=Strategy.HOP)
    h = b"pg" * 16
    kvc.set_block(h, b"q" * 100)
    assert kvc.prefetch_for_rotation(h, steps=3) == 0
