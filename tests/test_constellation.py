"""Torus model tests (paper §2, §3.2, Eqs 1-4)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.constellation import (
    C_KM_S,
    R_EARTH_KM,
    ConstellationSpec,
    LosWindow,
    Sat,
)

SPEC = ConstellationSpec(num_planes=15, sats_per_plane=15, altitude_km=550.0)

sats = st.builds(
    Sat,
    plane=st.integers(0, SPEC.num_planes - 1),
    slot=st.integers(0, SPEC.sats_per_plane - 1),
)


def test_eq1_intra_plane_distance():
    # Eq (1) closed form: (r_E + h) * sqrt(2 (1 - cos(2 pi / M))).
    d = SPEC.intra_plane_distance_km()
    expected = (R_EARTH_KM + 550.0) * math.sqrt(2 * (1 - math.cos(2 * math.pi / 15)))
    assert d == pytest.approx(expected)
    # equivalently 2 (r_E+h) sin(pi/M)
    assert d == pytest.approx(2 * (R_EARTH_KM + 550.0) * math.sin(math.pi / 15))


def test_distance_decreases_with_density_and_grows_with_altitude():
    lo = ConstellationSpec(15, 50, 550.0).intra_plane_distance_km()
    hi = ConstellationSpec(15, 15, 550.0).intra_plane_distance_km()
    assert lo < hi
    low_alt = ConstellationSpec(15, 15, 160.0).intra_plane_distance_km()
    assert low_alt < hi


@given(a=sats, b=sats)
@settings(max_examples=200, deadline=None)
def test_hops_symmetric_and_triangle(a, b):
    assert SPEC.hops(a, b) == SPEC.hops(b, a)
    assert SPEC.hops(a, a) == 0
    c = Sat(0, 0)
    assert SPEC.hops(a, b) <= SPEC.hops(a, c) + SPEC.hops(c, b)


@given(a=sats, b=sats)
@settings(max_examples=200, deadline=None)
def test_torus_delta_minimal_and_consistent(a, b):
    dp, ds = SPEC.torus_delta(a, b)
    assert abs(dp) <= SPEC.num_planes // 2
    assert abs(ds) <= SPEC.sats_per_plane // 2
    assert SPEC.wrap(Sat(a.plane + dp, a.slot + ds)) == SPEC.wrap(b)


@given(a=sats, b=sats)
@settings(max_examples=100, deadline=None)
def test_greedy_route_length_equals_hops(a, b):
    path = SPEC.greedy_route(a, b)
    assert path[0] == SPEC.wrap(a)
    assert path[-1] == SPEC.wrap(b)
    assert len(path) - 1 == SPEC.hops(a, b)
    # each step is one ISL link
    for u, v in zip(path, path[1:]):
        assert SPEC.hops(u, v) == 1


def test_slant_range_eq4():
    # directly overhead: slant = altitude
    assert SPEC.slant_range_km(0.0) == pytest.approx(550.0)
    assert SPEC.slant_range_km(550.0) == pytest.approx(550.0 * math.sqrt(2))


def test_isl_latency_is_distance_over_c():
    a, b = Sat(0, 0), Sat(0, 1)
    assert SPEC.isl_latency_s(a, b) == pytest.approx(
        SPEC.intra_plane_distance_km() / C_KM_S
    )


def test_los_window_row_major_and_contains():
    w = LosWindow(Sat(7, 7), 3, 3)
    got = w.sats(SPEC)
    assert len(got) == 9
    assert got[0] == Sat(6, 6)      # top-left
    assert got[4] == Sat(7, 7)      # center is the middle element
    assert got[-1] == Sat(8, 8)
    for s in got:
        assert w.contains(SPEC, s)
    assert not w.contains(SPEC, Sat(10, 7))


def test_los_window_wraps_around_torus():
    w = LosWindow(Sat(0, 0), 3, 3)
    got = w.sats(SPEC)
    assert got[0] == Sat(14, 14)
    assert w.contains(SPEC, Sat(14, 14))


def test_window_shift_moves_along_plane():
    w = LosWindow(Sat(7, 7), 5, 5)
    w2 = w.shifted(SPEC, d_slot=1)
    assert w2.center == Sat(7, 8)
    # one column of satellites exits, one enters, per plane
    old = set(w.sats(SPEC))
    new = set(w2.sats(SPEC))
    assert len(old - new) == 5 and len(new - old) == 5
    exited = old - new
    assert all(s.slot == 5 for s in exited)  # the trailing row exits
