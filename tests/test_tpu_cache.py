"""Chip-scale SkyMemory placement (TPU torus adaptation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.mapping import Strategy
from repro.core.tpu_cache import (
    TorusGrid,
    gather_cost_s,
    migrate_shards,
    row_major_layout,
    shard_layout_permutation,
    strategy_cost_table,
)


def test_torus_hops_wraparound():
    g = TorusGrid(16, 16)
    assert g.hops((0, 0), (15, 15)) == 2  # wraps both axes
    assert g.hops((0, 0), (8, 8)) == 16
    assert g.hops((3, 3), (3, 3)) == 0


def test_ring_layout_hop_monotone():
    g = TorusGrid(16, 16)
    center = (8, 8)
    layout = g.ring_layout(49, center)
    hops = [g.hops(center, p) for p in layout]
    assert hops[0] == 0
    assert hops == sorted(hops)  # BFS rings: non-decreasing hop distance


def test_ring_beats_row_major_worst_hops():
    g = TorusGrid(16, 16)
    center = (8, 8)
    ring = g.worst_hops(g.ring_layout(49, center), center)
    rm = g.worst_hops(row_major_layout(g, 49), center)
    assert ring < rm


def test_strategy_cost_table_ordering():
    """The paper's Fig-16 ordering holds at chip scale: ring placements
    gather in fewer worst-case hops than row-major."""
    g = TorusGrid(16, 16)
    costs = strategy_cost_table(g, num_shards=64, bytes_per_shard=1 << 20)
    assert costs["hop(bfs-rings)"] <= costs["rotation(row-major)"]
    assert costs["rotation_hop(boxed-rings)"] <= costs["rotation(row-major)"]


def test_gather_cost_includes_serialization():
    g = TorusGrid(4, 4)
    layout = g.ring_layout(4, (0, 0))
    small = gather_cost_s(g, layout, (0, 0), bytes_per_shard=0)
    big = gather_cost_s(g, layout, (0, 0), bytes_per_shard=int(50e9))
    assert big == pytest.approx(small + 1.0, rel=1e-3)


def test_shard_layout_permutation_valid():
    g = TorusGrid(8, 8)
    perm = shard_layout_permutation(g, 16, (4, 4), Strategy.ROTATION_HOP)
    assert len(set(perm.tolist())) == 16
    assert perm.min() >= 0 and perm.max() < 64


def test_migrate_shards_single_device_identity():
    # On a 1-device mesh the cyclic shift is the identity; the multi-device
    # path is exercised by the dry-run lowering (launch/dryrun.py).
    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("data",))
    x = jnp.arange(8.0).reshape(4, 2)
    y = migrate_shards(x, mesh, axis="data", shift=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


def _abstract_mesh(n: int, name: str):
    """AbstractMesh across jax versions: (sizes, names) on new jax,
    a ((name, size), ...) shape tuple on 0.4.x."""
    try:
        return jax.sharding.AbstractMesh((n,), (name,))
    except TypeError:
        return jax.sharding.AbstractMesh(((name, n),))


def test_migrate_shards_lowering_multidevice():
    """lower() the migration collective against an abstract 4-device mesh."""
    mesh = _abstract_mesh(4, "data")
    x = jax.ShapeDtypeStruct((8, 2), jnp.float32)

    def fn(v):
        return migrate_shards(v, mesh, axis="data", shift=1)

    lowered = jax.jit(fn).lower(x)
    text = lowered.as_text()
    assert "collective_permute" in text
    # full cyclic ring over the 4 shard positions
    assert "[[0, 1], [1, 2], [2, 3], [3, 0]]" in text
