"""The versioned payload codec, end to end below the engines: container
formats and header rejection, dtype-true round trips (the bf16 asymmetry
fix), KVCManager delta-chain reassembly over a real priced fabric, and
the router's codec-derived size model.

The deterministic contract under test:

* payloads are self-describing -- decode never needs a codec, source
  dtypes are restored exactly (bf16 in -> bf16 out), integer pools are
  stored verbatim, and corrupt/truncated headers fail loudly;
* a delta chain reassembled by ``KVCManager`` decodes byte-identically
  to the full-prefix encode (scale chunks align with blocks), a missing
  mid-chain block shortens the resumable prefix to just before it, and
  re-adding recomputes only the broken tail;
* the router prices *encoded* bytes: registered blocks by their real
  ``payload_bytes`` (estimate == experienced-path estimate on a
  quantized fabric), unregistered ones by the codec's bytes-per-token
  model.
"""
import ml_dtypes
import numpy as np
import pytest

from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    IslTransport,
    KVCManager,
    LosWindow,
    Sat,
    Strategy,
    chain_hashes,
)
from repro.core.chunking import (
    PayloadCodec,
    arrays_to_bytes,
    bytes_to_dequantized,
    cat_payloads,
    decode_payload_arrays,
    delta_info,
    dequantize_int8,
    encode_arrays,
    is_delta_payload,
    make_delta_payload,
    payload_raw_bytes,
    quantize_int8,
    quantized_to_bytes,
    split_cat_payload,
)
from repro.serving import PrefixAffinityRouter, ReplicaHandle

_BF16 = np.dtype(ml_dtypes.bfloat16)
SPEC = ConstellationSpec(15, 15, 550.0)
BS = 8  # manager block size (tokens) in the fabric-level tests


def make_kvc(**kw):
    return ConstellationKVC(
        SPEC, LosWindow(Sat(7, 7), 9, 9), Strategy.ROTATION_HOP,
        num_servers=10, chunk_bytes=1024,
        transport=IslTransport(SPEC, chunk_processing_time_s=1e-4), **kw,
    )


# ---------------------------------------------------------------------------
# dtype-true round trips (the bf16 asymmetry fix)
# ---------------------------------------------------------------------------

def test_bf16_roundtrips_as_bf16():
    """quantized_to_bytes used to serialize bf16 inputs but dequantize to
    float32 -- doubling the restore's memory and breaking bit-compat with
    the pool it refills.  The codec header records the source dtype."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((2, 16, 8)).astype(np.float32).astype(_BF16)
    (back,) = bytes_to_dequantized(quantized_to_bytes([a]))
    assert back.dtype == _BF16
    assert back.shape == a.shape


def test_legacy_pair_payloads_still_decode():
    """Pre-codec SKYM [q, scale, ...] payloads written by old fabrics
    decode exactly as before (to float32 -- they never recorded dtype)."""
    rng = np.random.default_rng(1)
    a = rng.standard_normal((4, 6)).astype(np.float32)
    qa = quantize_int8(a)
    legacy = arrays_to_bytes([qa.q, qa.scale])
    (back,) = bytes_to_dequantized(legacy)
    assert back.dtype == np.float32
    assert np.array_equal(back, dequantize_int8(qa))


def test_integer_pools_stored_verbatim():
    """Already-quantized device pools (int8), block tables (int32) and
    masks (bool) pass through quantized codecs bit-exactly -- quantizing
    codes would corrupt them."""
    rng = np.random.default_rng(2)
    arrays = [
        rng.integers(-128, 128, (2, 9, 4), dtype=np.int8),
        rng.integers(0, 1 << 30, (7,), dtype=np.int32),
        rng.integers(0, 2, (3, 5)).astype(bool),
    ]
    for name in ("int8", "int4"):
        back = decode_payload_arrays(
            encode_arrays(arrays, PayloadCodec(name, 4)))
        for a, b in zip(arrays, back):
            assert b.dtype == a.dtype
            assert np.array_equal(a, b)


def test_empty_payloads_roundtrip():
    for name in ("f32", "int8", "int4"):
        enc = encode_arrays([], PayloadCodec(name, 4))
        assert decode_payload_arrays(enc) == []
        assert payload_raw_bytes(enc) == 0


def test_codec_parse_specs():
    assert PayloadCodec.parse(None, 16) == PayloadCodec("f32", 16)
    assert PayloadCodec.parse("int8", 16) == PayloadCodec("int8", 16)
    c = PayloadCodec.parse("int4+delta", 16)
    assert c.name == "int4" and c.delta and c.block_tokens == 16
    assert PayloadCodec.parse(c) is c
    with pytest.raises(ValueError):
        PayloadCodec.parse("int2", 16)
    with pytest.raises(ValueError):
        PayloadCodec("int8", 0, delta=True)   # delta needs block_tokens
    assert PayloadCodec("int8", 0).bytes_per_value(4) == 1.0
    assert PayloadCodec("int4", 0).bytes_per_value(4) == 0.5
    assert PayloadCodec("f32", 0).bytes_per_value(2) == 2.0


# ---------------------------------------------------------------------------
# header rejection: every decoder fails loudly on corrupt containers
# ---------------------------------------------------------------------------

def _enc(n_tok=8, seg=4, name="int8"):
    rng = np.random.default_rng(3)
    a = rng.standard_normal((2, n_tok, 3)).astype(np.float32)
    return a, encode_arrays([a], PayloadCodec(name, seg))


def test_rejects_unsupported_codec_version():
    _, enc = _enc()
    bad = enc[:4] + b"\x63\x00" + enc[6:]     # version 99
    with pytest.raises(ValueError, match="version"):
        decode_payload_arrays(bad)


def test_rejects_unknown_container_kind():
    _, enc = _enc()
    bad = enc[:6] + b"\x09" + enc[7:]         # kind 9
    with pytest.raises(ValueError, match="kind"):
        decode_payload_arrays(bad)


def test_rejects_unknown_codec_id():
    _, enc = _enc()
    bad = enc[:7] + b"\x2a" + enc[8:]         # codec id 42
    with pytest.raises(ValueError, match="codec id"):
        decode_payload_arrays(bad)


def test_rejects_tampered_scale_table_chunking():
    """Rewriting the scale-table chunk size in flight desynchronizes the
    table from the codes -- the decoder checks the shape it implies."""
    a, enc = _enc(n_tok=8, seg=4)             # 2 chunks of 4 tokens
    # ENC layout: magic(4) ver+kind+id(4) n(4) | dlen(1) "<f4"(3)
    # ndim(1) shape(24) store(1) -> seg int32 at offset 42
    off = 12 + 1 + 3 + 1 + 24 + 1
    bad = enc[:off] + (1).to_bytes(4, "little") + enc[off + 4:]
    with pytest.raises(ValueError, match="scale table"):
        decode_payload_arrays(bad)


def test_delta_and_cat_accessors_reject_wrong_kind():
    a, enc = _enc()
    with pytest.raises(ValueError):
        delta_info(enc)                       # ENC is not a delta
    with pytest.raises(ValueError):
        split_cat_payload(enc)                # ...nor a cat
    with pytest.raises(ValueError):
        cat_payloads([])                      # cat of nothing
    assert cat_payloads([enc]) is enc         # single segment: no wrapper


def test_legacy_odd_pair_count_rejected():
    q = np.zeros((2, 3), np.int8)
    with pytest.raises(ValueError):
        bytes_to_dequantized(arrays_to_bytes([q]))  # q without its scale


def test_raw_bytes_scan_is_best_effort():
    """Opaque test bytes stored on the fabric count at face value."""
    assert payload_raw_bytes(b"not a payload at all") == 20
    assert payload_raw_bytes(b"SKYM\x01\x00garbage") == 13


# ---------------------------------------------------------------------------
# KVCManager delta chains over a real priced fabric
# ---------------------------------------------------------------------------

_CODEC = PayloadCodec("int8", BS)


def _tokenize(prompt):
    return [ord(c) % 96 for c in prompt]


def _series(tokens):
    """The 'model state' for a token prefix: its cumulative sum, shaped
    [L, T, C] so the token axis (1) matches real KVC payloads."""
    return np.cumsum(np.asarray(tokens, np.float32)).reshape(1, -1, 1)


def _delta_kvc_fn(tokens, past, past_len):
    arr = _series(tokens)
    if past is None or past_len == 0:
        return encode_arrays([arr[:, :BS]], _CODEC)
    prev = chain_hashes(list(tokens[:past_len]), BS)[-1]
    inner = encode_arrays([arr[:, past_len:]], _CODEC)
    return make_delta_payload(inner, prev, past_len)


def test_manager_reassembles_delta_chains():
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _delta_kvc_fn, kvc, block_size=BS)
    tokens = _tokenize("delta chains over the constellation!")[: 4 * BS]
    assert mgr.add_blocks_tokens(tokens) == 4
    payload, n = mgr.get_cache_tokens(tokens)
    assert n == 4 * BS
    # the reassembled cat decodes EXACTLY like a one-shot aligned encode
    (got,) = decode_payload_arrays(payload)
    (want,) = decode_payload_arrays(encode_arrays([_series(tokens)], _CODEC))
    assert np.array_equal(got, want)
    # each stored block past the base is O(block) bytes, not O(prefix)
    hashes = chain_hashes(tokens, BS)
    sizes = [len(kvc.get_block(h)) for h in hashes]
    assert all(is_delta_payload(kvc.get_block(h)) for h in hashes[1:])
    assert max(sizes[1:]) <= sizes[0] + 64    # headers, not growth
    # a hit fetched every chain link with real priced Gets
    assert kvc.stats.block_hits >= 4


def test_manager_shortens_broken_delta_chain_and_recovers():
    """Evicting a mid-chain block behind the index's back makes every
    later block unreconstructible: the resumable prefix shrinks to just
    before the hole, and a re-add recomputes only the broken tail."""
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _delta_kvc_fn, kvc, block_size=BS)
    tokens = _tokenize("a chain with a hole punched in it....")[: 4 * BS]
    mgr.add_blocks_tokens(tokens)
    hashes = chain_hashes(tokens, BS)
    kvc.on_block_lost = None                  # evict without notifying
    kvc.purge_block(hashes[1])
    payload, n = mgr.get_cache_tokens(tokens)
    assert n == BS                            # shortened to the base block
    (got,) = decode_payload_arrays(payload)
    (want,) = decode_payload_arrays(
        encode_arrays([_series(tokens)[:, :BS]], _CODEC))
    assert np.array_equal(got, want)
    # re-adding resumes from the surviving base and repairs the chain
    kvc.on_block_lost = mgr._on_block_lost
    assert mgr.add_blocks_tokens(tokens) == 3
    payload, n = mgr.get_cache_tokens(tokens)
    assert n == 4 * BS
    (got,) = decode_payload_arrays(payload)
    (want,) = decode_payload_arrays(encode_arrays([_series(tokens)], _CODEC))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# router pricing on a quantized fabric
# ---------------------------------------------------------------------------

def test_estimator_agreement_on_quantized_fabric():
    """The hop signal on an int8 fabric prices the *encoded* payload the
    hit will fetch -- registered payload_bytes are encoded sizes, so the
    router's estimate equals the experienced-path estimate without any
    codec plumbed into the router at all."""
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _delta_kvc_fn, kvc, block_size=BS)
    tokens = _tokenize("hop aware routing over a quantized torus")[: 4 * BS]
    mgr.add_blocks_tokens(tokens)
    far, near = kvc.view(Sat(0, 0)), kvc.view(Sat(7, 7))
    handles = [ReplicaHandle(0, view=far), ReplicaHandle(1, view=near)]
    router = PrefixAffinityRouter(handles, manager=mgr)
    d = router.route(tokens)
    assert d.replica == 1 and d.cached_blocks == 4
    hashes = chain_hashes(tokens, BS)
    n, meta = mgr.index.longest_cached_prefix(hashes)
    assert d.hop_latency_s == near.estimate_get_latency_s(
        payload_bytes=meta.payload_bytes, block_hash=hashes[n - 1])
    # registered bytes are the ENCODED (delta) size: one int8 block +
    # headers, far below a raw f32 cumulative payload
    assert meta.payload_bytes == len(kvc.get_block(hashes[-1]))
    assert meta.payload_bytes < _series(tokens).nbytes


def test_router_codec_size_fallback_for_unregistered_blocks():
    """Blocks cached without registered payload_bytes are priced from
    the adapter's codec-derived bytes-per-token model; delta fabrics
    price one block, cumulative fabrics the whole prefix."""
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _delta_kvc_fn, kvc, block_size=BS)
    tokens = _tokenize("fallback pricing for unregistered blocks!")[: 3 * BS]
    mgr.add_blocks_tokens(tokens)
    hashes = chain_hashes(tokens, BS)
    # wipe the registered size, as a pre-codec index snapshot would have
    _, meta = mgr.index.longest_cached_prefix(hashes)
    meta.payload_bytes = 0
    view = kvc.view(Sat(7, 7))
    handles = [ReplicaHandle(0, view=view)]
    cumulative = PrefixAffinityRouter(
        handles, manager=mgr, bytes_per_token=4.0)
    blocks_n, est_bytes, tail = cumulative._cached_prefix(hashes)
    assert blocks_n == 3 and tail == hashes[2]
    assert est_bytes == 3 * BS * 4
    delta = PrefixAffinityRouter(
        [ReplicaHandle(0, view=view)], manager=mgr,
        bytes_per_token=4.0, delta_payloads=True)
    _, est_bytes_delta, _ = delta._cached_prefix(hashes)
    assert est_bytes_delta == BS * 4          # the tail Get ships one block
    # with neither registered bytes nor a size model, no estimate
    bare = PrefixAffinityRouter([ReplicaHandle(0, view=view)], manager=mgr)
    assert bare._cached_prefix(hashes)[1] is None
