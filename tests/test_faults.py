"""Chaos suite: k-replica placement, churn/outage injection, degraded
reads, repair, and serving under failure.

The fault-tolerance contract under test:

* replica homes are distinct, plane-diverse satellites; reads fall
  through dead replicas (charging the failed attempts) and a chunk with
  no live copy is a *clean* miss -- never an exception, at any layer;
* a seeded ``FaultPlan`` is deterministic: the same seed produces the
  same schedule and the same serve results;
* ``repair`` re-replicates surviving copies, purges unrecoverable
  blocks (pruning the radix index), and interleaves safely with
  rotation migration;
* link kills grade latency through rerouted detours instead of failing
  ops; only a genuine partition makes a satellite unreachable;
* a ``GroundStationTier`` keeps data servable (and repairable) after
  total orbital loss -- losses become ground hits, not recomputes;
* an ``EngineCluster`` under churn completes every request, in order.

Seed-generic tests offset their seeds by ``SKYMEM_CHAOS_SEED`` (CI runs
a small seed matrix); the default 0 reproduces the historical values.
"""
import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, smoke_config
from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    FaultInjector,
    FaultPlan,
    GroundStationTier,
    KVCManager,
    LosWindow,
    Sat,
    SimClock,
    Strategy,
    IslTransport,
    chain_hashes,
    plan_survivable_kills,
    stripe_of,
)
from repro.core.chunking import arrays_to_bytes
from repro.core.faults import FaultEvent, FaultState, link_key
from repro.models.model import Model
from repro.serving import (
    Engine,
    EngineCluster,
    Request,
    SamplingParams,
    TrafficGenerator,
    standard_tenants,
)

SPEC = ConstellationSpec(15, 15, 550.0)
SEED = int(os.environ.get("SKYMEM_CHAOS_SEED", "0"))


def make_kvc(clock=None, replication=1, **kw):
    transport = IslTransport(SPEC, clock=clock,
                             chunk_processing_time_s=1e-4)
    return ConstellationKVC(
        SPEC, LosWindow(Sat(7, 7), 9, 9), Strategy.ROTATION_HOP,
        num_servers=10, chunk_bytes=64, transport=transport,
        replication=replication, **kw,
    )


def isolate(state, sat):
    """Cut all four ISL links around ``sat``: a true partition."""
    for dp, ds in ((0, 1), (0, -1), (1, 0), (-1, 0)):
        state.kill_link(sat, SPEC.wrap(Sat(sat.plane + dp, sat.slot + ds)))


def kill_now(kvc, sats):
    """An armed injector with every kill due -- and applied -- now."""
    inj = FaultInjector(kvc, FaultPlan.outages(list(sats)))
    inj.arm()
    inj.advance()
    return inj


H = b"h" * 32
PAYLOAD = b"x" * 640          # 10 chunks of 64B: a full server stripe


# ---------------------------------------------------------------------------
# replica placement
# ---------------------------------------------------------------------------

def test_replica_homes_distinct_and_plane_diverse():
    kvc = make_kvc(replication=3)
    for sid in range(kvc.num_servers):
        homes = [kvc.replica_sat(sid, r) for r in range(3)]
        assert len(set(homes)) == 3
        assert len({s.plane for s in homes}) == 3   # k <= planes
        assert homes[0] == kvc.server_sat(sid)      # replica 0 = base


def test_replicated_set_stores_k_copies():
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    assert kvc.get_block(H) == PAYLOAD
    for cid in range(kvc.directory[H]):
        sid = cid % kvc.num_servers
        copies = sum(
            kvc.store_for(kvc.replica_sat(sid, r)).contains((H, cid))
            for r in range(2))
        assert copies == 2
    assert kvc.stats.degraded_reads == 0            # clean fabric


def test_replication_bounds_validated():
    with pytest.raises(ValueError):
        make_kvc(replication=0)
    with pytest.raises(ValueError):
        make_kvc(replication=SPEC.num_sats + 1)


# ---------------------------------------------------------------------------
# degraded reads / clean misses
# ---------------------------------------------------------------------------

def test_sat_death_k1_is_clean_miss():
    kvc = make_kvc(replication=1)
    kvc.set_block(H, PAYLOAD)
    inj = kill_now(kvc, [kvc.server_sat(3)])
    assert kvc.get_block(H) is None                 # no exception
    assert kvc.stats.block_misses == 1
    assert inj.stats.chunks_dropped == 1
    # the home is merely dead, not proven empty: directory keeps the
    # entry for a possible (it will not come) recovery
    assert H in kvc.directory
    assert kvc.stats.lost_blocks == 0
    # chunk-0 server death makes presence probes miss cleanly too
    kill_now(kvc, [kvc.server_sat(0)])
    assert kvc.has_block(H) is False
    assert kvc.lookup_longest([H]) == 0


def test_sat_death_k2_degraded_read_charges_detour():
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    kvc.get_block(H)
    clean_lat = kvc.transport.stats.last_latency_s
    kill_now(kvc, [kvc.server_sat(3)])
    assert kvc.get_block(H) == PAYLOAD              # replica 1 serves
    assert kvc.stats.degraded_reads == 1
    # the failed attempt's timed-out round trip is experienced
    assert kvc.transport.stats.last_latency_s > clean_lat
    # presence probes degrade the same way when chunk 0's server dies
    kill_now(kvc, [kvc.server_sat(0)])
    d0 = kvc.stats.degraded_reads
    assert kvc.has_block(H) is True
    assert kvc.stats.degraded_reads == d0 + 1


def test_estimate_get_latency_prices_dead_replica_detours():
    kvc = make_kvc(replication=2)
    anchor = kvc.center
    # the estimate is a max over chunk servers, so kill the dominant one:
    # its degraded path (timed-out probe + replica-1 fetch) must raise it
    worst_sid = max(
        range(kvc.num_servers),
        key=lambda sid: kvc.transport.op_latency_s(
            anchor, kvc.server_sat(sid), kvc.chunk_bytes, round_trip=True))
    before = kvc.estimate_get_latency_s(anchor)
    kill_now(kvc, [kvc.server_sat(worst_sid)])
    assert kvc.estimate_get_latency_s(anchor) > before


def test_get_in_flight_when_serving_sat_dies_mid_get():
    """A Get's payload is captured at issue; the flight completes on the
    clock.  Killing the serving satellite between issue and completion
    must not corrupt the in-flight payload, and the *next* Get falls
    through to the surviving replica (k=2) or misses cleanly (k=1)."""
    for k, expect in ((2, PAYLOAD), (1, None)):
        clock = SimClock(rate=200.0)
        kvc = make_kvc(clock=clock, replication=k)
        kvc.set_block(H, PAYLOAD)
        payload = kvc.get_block(H)                  # issued; in flight
        ready_at = kvc.transport.last_ready_at
        assert ready_at is not None and ready_at > clock.now()
        kill_now(kvc, [kvc.server_sat(3)])          # dies mid-flight
        clock.wait_until(ready_at)
        assert payload == PAYLOAD                   # flight unaffected
        assert kvc.get_block(H) == expect           # next Get degrades


def test_link_outage_detours_then_heals():
    """A dead ISL link on the greedy route does not fail the op: the
    fetch completes over the cheapest detour at +extra_hops latency,
    and healing the link restores the clean-path price."""
    kvc = make_kvc(replication=1)
    kvc.set_block(H, PAYLOAD)
    assert kvc.get_block(H) == PAYLOAD
    clean_lat = kvc.transport.stats.last_latency_s
    # sever the last greedy hop into chunk 3's server: the route is
    # down but the satellite (and its data) is alive
    target = kvc.server_sat(3)
    path = SPEC.greedy_route(kvc.center, target)
    inj = FaultInjector(kvc, FaultPlan(
        [FaultEvent(at_s=0.0, action="kill", link=(path[-2], path[-1]))]))
    inj.arm()
    assert kvc.get_block(H) == PAYLOAD              # detoured, not failed
    assert kvc.stats.detoured_ops >= 1
    assert kvc.stats.detour_hops >= 2               # around one cut link
    assert kvc.transport.stats.last_latency_s > clean_lat
    assert kvc.stats.degraded_reads == 0            # no replica fell over
    inj.state.heal_link(path[-2], path[-1])
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.transport.stats.last_latency_s == pytest.approx(clean_lat)


def test_link_partition_is_clean_miss_and_heals():
    """Only a genuine partition -- every live path to the endpoint cut
    -- makes a chunk unreachable, and even then it is a clean miss: the
    directory keeps the entry and healing restores the data."""
    kvc = make_kvc(replication=1)
    kvc.set_block(H, PAYLOAD)
    target = kvc.server_sat(3)
    inj = kill_now(kvc, [])                         # armed empty injector
    isolate(inj.state, target)
    assert not inj.state.reachable(SPEC, kvc.center, target)
    assert inj.state.route_hops(SPEC, kvc.center, target) is None
    assert kvc.get_block(H) is None                 # partitioned: miss
    assert H in kvc.directory                       # ...but NOT purged
    assert kvc.stats.lost_blocks == 0
    inj.state.heal_link(
        target, SPEC.wrap(Sat(target.plane, target.slot + 1)))
    assert kvc.get_block(H) == PAYLOAD              # data survived


def test_bounded_detour_search_budget():
    """``max_extra_hops`` bounds the search: a detour longer than the
    budget reads as unreachable, an unbounded search still finds it."""
    st_ = FaultState()
    a, b = Sat(0, 0), Sat(0, 1)
    st_.kill_link(a, b)
    assert st_.route_hops(SPEC, a, b) == (1, 2)     # around one plane
    assert st_.extra_hops(SPEC, a, b) == 2
    assert st_.route_hops(SPEC, a, b, max_extra_hops=1) is None
    assert st_.route_hops(SPEC, a, b, max_extra_hops=2) == (1, 2)


def test_probe_timeout_prices_unreachable_probes():
    """``IslTransport.probe_timeout_s`` is the flat charge for probing a
    dead/partitioned replica -- used identically by the Get fall-through
    and by ``estimate_get_latency_s``, so the router prices the same
    failure the fetch experiences."""
    kvc = make_kvc(replication=2)
    kvc.transport.probe_timeout_s = 0.25
    kvc.set_block(H, PAYLOAD)
    anchor = kvc.center
    est_clean = kvc.estimate_get_latency_s(anchor)
    kill_now(kvc, [kvc.server_sat(3)])
    assert kvc.transport.probe_latency_s(
        anchor, kvc.server_sat(3), faults=kvc.faults) == 0.25
    est_dead = kvc.estimate_get_latency_s(anchor)
    assert est_dead >= 0.25                         # the probe dominates
    assert est_dead > est_clean
    assert kvc.get_block(H) == PAYLOAD              # replica 1 serves
    assert kvc.transport.stats.last_latency_s >= 0.25


def test_failed_set_indexes_no_phantom_and_leaves_no_orphans():
    """When a Set cannot land one copy of some chunk, the KVC manager
    must not index the hash (a phantom entry no repair pass could ever
    prune -- the directory never learned of the block) and the chunks
    that did land must not linger as unindexed orphans."""
    kvc = make_kvc(replication=1)
    mgr = KVCManager(lambda p: [ord(c) % 96 for c in p],
                     lambda t, p, n: arrays_to_bytes(
                         [np.cumsum(np.asarray(t, np.int64))]),
                     kvc, block_size=4)
    kill_now(kvc, [kvc.server_sat(0)])      # chunk 0's home: all Sets fail
    assert mgr.add_blocks("abcdefgh") == 0
    hashes = chain_hashes(mgr.tokenize("abcdefgh"), 4)
    assert mgr.index.longest_cached_prefix(hashes)[0] == 0
    assert kvc.directory == {}
    assert all(len(store) == 0 for store in kvc._stores.values())
    assert mgr.get_cache("abcdefgh") == (None, 0)


def test_repair_on_heal_rereplicates_via_op_tick():
    """``repair_on_heal``: the heal event, applied from inside a chunk
    op's fault tick, triggers a repair pass (outside the injector lock)
    that refills the healed home."""
    clock = SimClock(rate=500.0)
    kvc = make_kvc(clock=clock, replication=2)
    kvc.set_block(H, PAYLOAD)
    inj = FaultInjector(
        kvc,
        FaultPlan.outages([kvc.server_sat(3)], kill_at_s=0.0,
                          downtime_s=0.2),
        repair_on_heal=True)
    inj.arm()
    inj.advance()
    assert kvc.get_block(H) == PAYLOAD      # degraded meanwhile
    clock.wait_until(clock.now() + 0.3)
    assert kvc.get_block(H) == PAYLOAD      # this op ticks the heal in
    assert inj.stats.sat_heals == 1
    assert kvc.stats.repaired_chunks >= 1
    assert kvc.store_for(kvc.server_sat(3)).contains((H, 3))


def test_set_block_with_no_landing_copy_is_not_registered():
    """A Set whose chunk could not land a single copy must not register
    the block: the directory would otherwise claim data that never
    existed (and repair would later count it as 'lost')."""
    kvc = make_kvc(replication=1)
    kill_now(kvc, [kvc.server_sat(4)])      # one stripe member dead
    kvc.set_block(H, PAYLOAD)
    assert H not in kvc.directory
    assert kvc.stats.blocks_set == 0
    assert kvc.get_block(H) is None
    # with k=2 the same outage still lands every chunk somewhere
    kvc = make_kvc(replication=2)
    kill_now(kvc, [kvc.server_sat(4)])
    kvc.set_block(H, PAYLOAD)
    assert kvc.directory[H] == 10
    assert kvc.get_block(H) == PAYLOAD


# ---------------------------------------------------------------------------
# repair / rotation interleavings
# ---------------------------------------------------------------------------

def test_migration_into_dead_destination_does_not_resurrect():
    """A migration whose destination is dead drops the copies in transit
    -- writing them would make data appear on heal that the dead
    satellite could never have received.  Surviving replicas keep the
    block readable and repair restores the full set afterwards."""
    from repro.core import plan_migration

    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    new = kvc.window
    for _ in range(5):
        new = new.shifted(SPEC, d_slot=1)
    moves = plan_migration(SPEC, kvc.window, new, kvc.server_map)
    assert moves
    mv = moves[0]
    inj = kill_now(kvc, [mv.dst])
    kvc.execute_move(mv)
    assert len(kvc.store_for(mv.dst)) == 0  # nothing written while dead
    inj.state.heal_sat(mv.dst)
    assert len(kvc.store_for(mv.dst)) == 0  # and nothing resurrected
    assert kvc.get_block(H) == PAYLOAD      # replica homes still serve
    assert kvc.stats.degraded_reads >= 1
    assert kvc.repair() >= 1                # healed home is refilled
    assert len(kvc.store_for(mv.dst)) > 0


def test_repair_is_readonly_when_replica_sets_are_full():
    """A repair pass over a healthy replicated fabric copies nothing and
    -- crucially for the shared LRU -- reads nothing: it must not stamp
    every block hot and scramble eviction recency."""
    kvc = make_kvc(replication=2)
    from repro.core.eviction import LRUClock

    policy = LRUClock()
    kvc.adopt_policy(policy)
    kvc.set_block(H, PAYLOAD)
    before = policy.recency(H)
    assert kvc.repair() == 0
    assert policy.recency(H) == before

def test_repair_restores_full_replica_set():
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    inj = kill_now(kvc, [kvc.server_sat(3)])
    assert kvc.get_block(H) == PAYLOAD              # degraded
    inj.state.heal_sat(kvc.server_sat(3))           # back, but empty
    repaired = kvc.repair()
    assert repaired >= 1
    assert kvc.stats.repaired_chunks == repaired
    d0 = kvc.stats.degraded_reads
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.stats.degraded_reads == d0           # clean again
    assert kvc.sweep_incomplete() == 0


def test_repair_purges_unrecoverable_and_prunes_index():
    kvc = make_kvc(replication=1)
    mgr = KVCManager(lambda p: [ord(c) % 96 for c in p],
                     lambda t, p, n: arrays_to_bytes(
                         [np.cumsum(np.asarray(t, np.int64))]),
                     kvc, block_size=4)
    mgr.add_blocks("abcdefgh")                      # 2 blocks
    hashes = chain_hashes(mgr.tokenize("abcdefgh"), 4)
    assert mgr.index.longest_cached_prefix(hashes)[0] == 2
    kill_now(kvc, list(kvc.server_map))             # total loss
    assert kvc.repair() == 0
    assert kvc.stats.lost_blocks == 2
    assert kvc.directory == {}
    # the radix index was pruned through on_block_lost: a lookup is a
    # clean miss, and re-adding recomputes without tripping over state
    assert mgr.get_cache("abcdefgh") == (None, 0)
    assert mgr.index.longest_cached_prefix(hashes)[0] == 0


def test_repair_then_rotate_interleavings():
    """Repair and rotation migration compose in any order: blocks stay
    readable, the directory stays consistent, and a rotation step itself
    repairs churn losses (replica homes follow their servers)."""
    # (a) kill -> heal -> repair -> rotate
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    inj = kill_now(kvc, [kvc.server_sat(2)])
    inj.state.heal_sat(kvc.server_sat(2))
    assert kvc.repair() >= 1
    kvc.rotate(3)
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.sweep_incomplete() == 0

    # (b) kill -> rotate while dead: migration drains the (empty) dead
    # store; once the server's new home is alive, rotate's own repair
    # pass re-replicates from the surviving copies
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    dead = kvc.server_sat(2)
    kill_now(kvc, [dead])
    kvc.rotate(6)                                   # server leaves `dead`
    assert kvc.server_sat(2) != dead
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.stats.repaired_chunks >= 1
    assert kvc.sweep_incomplete() == 0

    # (c) a purge racing the repair/rotate machinery stays consistent
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    h2 = b"i" * 32
    kvc.set_block(h2, b"y" * 320)
    kvc.purge_block(h2)
    kvc.rotate(2)
    assert kvc.repair() == 0
    assert h2 not in kvc.directory
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.get_block(h2) is None


# ---------------------------------------------------------------------------
# the ground-station tier (L3)
# ---------------------------------------------------------------------------

def make_ground_kvc(write="all", capacity_blocks=None, **kw):
    # a durable tier is bigger AND slower: give it a visible processing
    # cost so latency assertions reflect the tiering, not just hops
    return make_kvc(
        ground=GroundStationTier(SPEC, capacity_blocks=capacity_blocks,
                                 processing_time_s=0.05),
        ground_write=write, **kw)


def test_ground_write_through_registers_despite_dead_stripe_member():
    """``ground_write="all"``: a Set that cannot land one chunk's every
    orbital copy still registers -- the payload is durable below the
    constellation, and the Get fall-through serves it."""
    kvc = make_ground_kvc("all")
    kill_now(kvc, [kvc.server_sat(4)])      # one stripe member dead, k=1
    kvc.set_block(H, PAYLOAD)
    assert H in kvc.directory               # registered: ground holds it
    assert kvc.stats.blocks_set == 1
    assert len(kvc.ground) == 1
    assert kvc.get_block(H) == PAYLOAD      # ground answers the gap
    assert kvc.stats.ground_hits == 1
    assert kvc.stats.lost_blocks == 0


def test_ground_fallthrough_after_total_orbital_loss():
    """Total orbital loss with a ground tier: the Get falls through to
    ground (slower, never failing), nothing is purged, nothing lost."""
    kvc = make_ground_kvc("all")
    kvc.set_block(H, PAYLOAD)
    assert kvc.get_block(H) == PAYLOAD      # orbital hit
    orbital_lat = kvc.transport.stats.last_latency_s
    kill_now(kvc, list(kvc.server_map))     # every chunk home dead
    assert kvc.get_block(H) == PAYLOAD      # ground serves
    assert kvc.stats.ground_hits == 1
    assert kvc.stats.lost_blocks == 0
    assert H in kvc.directory
    # the durable tier is priced, not free: uplink round trip dominates
    assert kvc.transport.stats.last_latency_s > orbital_lat
    # without ground the same loss is a clean miss (PR-5 behavior)
    bare = make_kvc()
    bare.set_block(H, PAYLOAD)
    kill_now(bare, list(bare.server_map))
    assert bare.get_block(H) is None


def test_repair_rereplicates_from_ground():
    """No orbital copy survives, ground holds the payload: ``repair``
    re-replicates onto the healed homes and counts the block as
    ``repaired_from_ground`` -- PR-5's lost_blocks, recovered."""
    kvc = make_ground_kvc("all")
    kvc.set_block(H, PAYLOAD)
    inj = kill_now(kvc, list(kvc.server_map))
    for s in list(kvc.server_map):
        inj.state.heal_sat(s)               # back, but wiped
    assert kvc.repair() >= kvc.directory[H]
    assert kvc.stats.repaired_from_ground == 1
    assert kvc.stats.lost_blocks == 0
    g0 = kvc.stats.ground_hits
    assert kvc.get_block(H) == PAYLOAD      # orbital again
    assert kvc.stats.ground_hits == g0


def test_repair_keeps_ground_only_blocks_until_homes_heal():
    """While every home of a ground-held block is still dead, repair
    neither purges nor counts it -- ground keeps serving, and a later
    pass (homes healed) completes the re-replication."""
    kvc = make_ground_kvc("all")
    kvc.set_block(H, PAYLOAD)
    inj = kill_now(kvc, list(kvc.server_map))
    assert kvc.repair() == 0                # nowhere to put copies yet
    assert kvc.stats.repaired_from_ground == 0
    assert kvc.stats.lost_blocks == 0
    assert H in kvc.directory
    assert kvc.get_block(H) == PAYLOAD      # ground serves meanwhile
    for s in list(kvc.server_map):
        inj.state.heal_sat(s)
    assert kvc.repair() >= 1
    assert kvc.stats.repaired_from_ground == 1


def test_spill_demotes_evicted_blocks_to_ground():
    """``ground_write="spill"``: LRU eviction reassembles the victim and
    demotes it to ground -- the directory keeps the entry, Gets keep
    answering, and nothing is reported lost."""
    p1, p2, p3 = (bytes([48 + i]) * 640 for i in range(3))
    h1, h2, h3 = (bytes([65 + i]) * 32 for i in range(3))
    # per-store capacity of two 64B chunks: the third Set evicts h1
    kvc = make_ground_kvc("spill", per_sat_capacity_bytes=128)
    kvc.set_block(h1, p1)
    kvc.set_block(h2, p2)
    kvc.set_block(h3, p3)
    assert kvc.stats.ground_spills == 1
    assert h1 in kvc.directory              # demoted, not purged
    assert kvc.stats.lost_blocks == 0
    assert kvc.get_block(h1) == p1          # served from ground
    assert kvc.stats.ground_hits == 1
    assert kvc.get_block(h2) == p2 and kvc.get_block(h3) == p3
    # demoted blocks are ground-resident by design: repair leaves them
    assert kvc.repair() == 0
    g = kvc.stats.ground_hits
    kvc.set_block(h1, p1)                   # a fresh Set re-promotes
    assert kvc.get_block(h1) == p1
    assert kvc.stats.ground_hits == g       # orbital once more


def test_ground_tier_capacity_lru_and_validation():
    g = GroundStationTier(SPEC, capacity_blocks=2)
    g.put(b"a" * 32, b"x")
    g.put(b"b" * 32, b"y")
    assert g.get(b"a" * 32) == b"x"         # touch: b becomes LRU
    g.put(b"c" * 32, b"z")
    assert g.stats.evictions == 1
    assert b"b" * 32 not in g
    assert g.get(b"a" * 32) == b"x" and g.get(b"c" * 32) == b"z"
    assert g.delete(b"a" * 32) and not g.delete(b"a" * 32)
    assert len(g) == 1
    with pytest.raises(ValueError):
        GroundStationTier(SPEC, capacity_blocks=0)


def test_purge_removes_ground_copy_too():
    kvc = make_ground_kvc("all")
    kvc.set_block(H, PAYLOAD)
    assert len(kvc.ground) == 1
    assert kvc.purge_block(H) > 0
    assert len(kvc.ground) == 0
    assert kvc.get_block(H) is None


# ---------------------------------------------------------------------------
# the decentralized directory (striped replicated metadata)
# ---------------------------------------------------------------------------

def _hash_on_stripe(kvc, min_sid):
    """A deterministic hash whose directory stripe is >= ``min_sid`` --
    with a small payload its metadata homes are disjoint from its data
    homes, so a stripe kill is a pure metadata wipeout."""
    for i in range(256):
        h = bytes([i]) * 32
        if stripe_of(h, kvc.num_servers) >= min_sid:
            return h
    raise AssertionError("no hash found on a high stripe")


def test_directory_lookup_is_priced():
    """Resolving the entry on its stripe is a real op: a Get that must
    look the block up pays more than one handed ``n_chunks`` a priori,
    and the lookup is counted."""
    kvc = make_kvc(replication=1)
    kvc.set_block(H, PAYLOAD)
    kvc.get_block(H, kvc.directory[H])      # metadata known out-of-band
    known_lat = kvc.transport.stats.last_latency_s
    assert kvc.stats.dir_lookups == 0
    assert kvc.get_block(H) == PAYLOAD
    assert kvc.stats.dir_lookups == 1
    assert kvc.transport.stats.last_latency_s > known_lat


def test_dir_stripe_wipeout_k2_degrades_then_reconcile_rebuilds():
    """dir_replication=2: one dead stripe home degrades lookups (they
    fall through to the surviving copy), losing BOTH homes is a clean
    miss -- never an exception -- and ``reconcile`` rebuilds the wiped
    stripe once its homes heal."""
    kvc = make_kvc(replication=2, dir_replication=2)
    h = _hash_on_stripe(kvc, min_sid=2)
    p = b"x" * 128                          # 2 chunks: servers 0 and 1
    kvc.set_block(h, p)
    sid = stripe_of(h, kvc.num_servers)
    homes = [kvc.replica_sat(sid, r) for r in range(2)]
    # one home down: degraded lookup, still served
    inj = kill_now(kvc, [homes[0]])
    assert inj.stats.dir_entries_dropped >= 1
    d0 = kvc.stats.degraded_lookups
    assert kvc.get_block(h) == p
    assert kvc.stats.degraded_lookups == d0 + 1
    # both homes down: the stripe is gone -- clean miss, nothing purged
    inj = kill_now(kvc, homes)
    assert kvc.get_block(h) is None
    assert h in kvc.directory               # the client journal remembers
    assert kvc.stats.lost_blocks == 0
    # heal + reconcile: the stripe is rewritten and lookups are clean
    for s in homes:
        inj.state.heal_sat(s)
    kvc.reconcile()
    assert kvc.stats.dir_repaired_entries >= 2     # both copies rebuilt
    d1 = kvc.stats.degraded_lookups
    assert kvc.get_block(h) == p
    assert kvc.stats.degraded_lookups == d1        # clean again


def test_dir_k1_stripe_loss_is_clean_miss():
    """dir_replication=1 demonstrably loses the stripe's entries: while
    the single home is dead every lookup of its blocks misses cleanly
    (recompute upstream), even though the data plane still holds every
    chunk copy."""
    kvc = make_kvc(replication=2, dir_replication=1)
    h = _hash_on_stripe(kvc, min_sid=2)
    p = b"y" * 128
    kvc.set_block(h, p)
    sid = stripe_of(h, kvc.num_servers)
    kill_now(kvc, [kvc.replica_sat(sid, 0)])
    assert kvc.get_block(h) is None         # metadata lost, data intact
    assert kvc.stats.degraded_lookups >= 1
    assert kvc.stats.block_misses == 1
    assert h in kvc.directory               # journal view only
    # every chunk copy is still physically there
    for cid in range(2):
        for r in range(2):
            assert kvc.store_for(kvc.replica_sat(cid, r)).contains((h, cid))


def test_swarm_read_serves_cheapest_live_replica():
    """A healthy fabric no longer always reads replica 0: an anchor
    sitting on a chunk's replica-1 home reads that copy (0 hops beats
    any fall-through), with no degraded accounting."""
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    sid = next(s for s in range(kvc.num_servers)
               if kvc.replica_sat(s, 1) not in kvc.server_map)
    twin = kvc.replica_sat(sid, 1)
    view = kvc.view(twin)
    twin_store = kvc.store_for(twin)
    hits0 = twin_store.stats.hits
    assert view.get_block(H) == PAYLOAD
    assert twin_store.stats.hits == hits0 + 1      # chunk `sid` from here
    assert view.stats.degraded_reads == 0


def test_estimate_prices_directory_leg():
    """``block_hash`` adds the stripe-lookup leg to the estimate, and a
    dead stripe home raises it -- the router prices the same degraded
    walk the fetch will run."""
    kvc = make_kvc(replication=2, dir_replication=2)
    h = _hash_on_stripe(kvc, min_sid=2)
    kvc.set_block(h, b"z" * 128)
    anchor = kvc.center
    plain = kvc.estimate_get_latency_s(anchor, payload_bytes=128)
    with_dir = kvc.estimate_get_latency_s(
        anchor, payload_bytes=128, block_hash=h)
    assert with_dir > plain
    sid = stripe_of(h, kvc.num_servers)
    kill_now(kvc, [kvc.replica_sat(sid, 0)])
    assert kvc.estimate_get_latency_s(
        anchor, payload_bytes=128, block_hash=h) > with_dir


def test_has_block_probes_tail_chunk():
    """The pre-PR-7 false positive: chunk 0 alive, a *later* chunk dead
    with all its homes -- ``has_block`` must answer False, and
    ``lookup_longest`` must not promise the prefix."""
    kvc = make_kvc(replication=1)
    kvc.set_block(H, PAYLOAD)               # 10 chunks; tail on server 9
    assert kvc.has_block(H) is True
    kill_now(kvc, [kvc.server_sat(9)])
    assert kvc.has_block(H) is False
    assert kvc.lookup_longest([H]) == 0


def test_kv_manager_shortens_prefix_when_tail_chunk_lost():
    """The radix index promises 2 blocks; block 2's tail chunk died with
    its only home: the Get walks back to the longest servable boundary
    and counts the shortened prefix -- never a crash, never corruption."""
    kvc = make_kvc(replication=1)
    mgr = KVCManager(lambda p: [ord(c) % 96 for c in p],
                     lambda t, p, n: arrays_to_bytes(
                         [np.cumsum(np.asarray(t, np.int64))]),
                     kvc, block_size=4)
    # block 1: 63B (1 chunk, server 0); block 2: 95B (chunks on 0 and 1)
    assert mgr.add_blocks("abcdefgh") == 2
    kill_now(kvc, [kvc.server_sat(1)])      # block 2's tail chunk home
    payload, n = mgr.get_cache("abcdefgh")
    assert n == 4                           # shortened to block 1
    assert payload is not None
    assert kvc.stats.shortened_prefixes == 1


def test_reconcile_reconstructs_from_inventory_and_sweeps_orphans():
    """Total metadata loss (stripes AND client journal): inventories
    rebuild entries whose tail chunk is provable (shorter than
    ``chunk_bytes``), and sweep the rest out as counted orphans rather
    than registering a truncated -- corrupt -- entry."""
    kvc = make_kvc(replication=2, dir_replication=2)
    h_tail, h_full = b"T" * 32, b"F" * 32
    p_tail = b"x" * 130                     # 3 chunks, 2-byte tail: provable
    p_full = b"y" * 128                     # 2 full chunks: unprovable
    kvc.set_block(h_tail, p_tail)
    kvc.set_block(h_full, p_full)
    for sat in list(kvc._dir._shards):
        kvc._dir.drop(sat)
    kvc._known_blocks.clear()
    assert kvc.get_block(h_tail) is None    # the fabric forgot everything
    kvc.reconcile()
    assert kvc.directory[h_tail] == 3       # rebuilt from inventory alone
    assert kvc.get_block(h_tail) == p_tail
    assert h_full not in kvc.directory
    assert kvc.get_block(h_full) is None
    assert kvc.stats.orphaned_chunks == 4   # 2 chunks x 2 replica copies
    assert kvc.stats.dir_repaired_entries >= 2
    assert all((h_full, cid) not in [k for s in kvc._stores.values()
                                     for k in s.keys()] for cid in range(2))


def test_prefetch_prepositions_all_k_homes_and_skips_dead():
    """``prefetch_for_rotation`` pre-positions every replica home of the
    future placement, and a currently-dead destination is skipped --
    nothing resurrects when it heals (the migration rule, applied to
    prefetch)."""
    from repro.core import migration as mig

    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    future_window, future_map = kvc.window, list(kvc.server_map)
    for _ in range(5):
        nw = future_window.shifted(SPEC, d_slot=1)
        for mv in mig.plan_migration(SPEC, future_window, nw, future_map):
            future_map[mv.server_id - 1] = mv.dst
        future_window = nw
    moved = [sid for sid in range(kvc.num_servers)
             if future_map[sid] != kvc.server_sat(sid)]
    assert moved
    dead = kvc._offset_sat(future_map[moved[0]], 1)
    inj = kill_now(kvc, [dead])
    assert kvc.prefetch_for_rotation(H, steps=5) > 0
    assert len(kvc.store_for(dead)) == 0    # nothing written while dead
    inj.state.heal_sat(dead)
    assert len(kvc.store_for(dead)) == 0    # and nothing resurrected
    # every OTHER future home -- all k of them -- is pre-positioned
    for sid in moved:
        for r in range(2):
            dst = kvc._offset_sat(future_map[sid], r)
            if dst == kvc.replica_sat(sid, r) or dst == dead:
                continue
            assert kvc.store_for(dst).contains((H, sid))


def test_seeded_dir_stripe_chaos_degrade_reconcile_recover():
    """Seeded end-to-end arc on the fabric clock: staggered kills of one
    stripe's homes mid-traffic -> degraded lookups -> clean misses ->
    heal + reconcile -> full recovery, byte-identical throughout."""
    import random as _random

    rng = _random.Random(31 + SEED)
    clock = SimClock(rate=50.0)
    kvc = make_kvc(clock=clock, replication=2, dir_replication=2)
    blocks = {}
    for _ in range(5):
        while True:
            h = bytes(rng.randrange(256) for _ in range(32))
            if stripe_of(h, kvc.num_servers) >= 2 and h not in blocks:
                break
        p = bytes([rng.randrange(256)]) * 128
        kvc.set_block(h, p)
        blocks[h] = p
    victim = min(blocks)                    # deterministic pick
    sid = stripe_of(victim, kvc.num_servers)
    homes = [kvc.replica_sat(sid, r) for r in range(2)]
    inj = FaultInjector(kvc, FaultPlan.outages(
        homes, kill_at_s=0.0, stagger_s=0.5, downtime_s=1e9))
    inj.arm()
    t0 = clock.now()
    while clock.now() < t0 + 1.2:
        got = kvc.get_block(victim)
        assert got in (blocks[victim], None)    # degrades, never corrupts
        clock.wait_until(clock.now() + 0.05)
    assert kvc.stats.degraded_lookups > 0
    inj.drain()
    for s in homes:
        inj.state.heal_sat(s)
    kvc.reconcile()
    assert kvc.stats.dir_repaired_entries >= 1
    for h, p in blocks.items():
        assert kvc.get_block(h) == p            # full recovery
    assert kvc.sweep_incomplete() == 0


def test_rotation_migrates_directory_stripes():
    """Rotation keeps the metadata plane resolvable: after the server
    map moves, lookups answer through the migrated shard homes with no
    degraded accounting."""
    kvc = make_kvc(replication=2, dir_replication=2)
    h = _hash_on_stripe(kvc, min_sid=2)
    kvc.set_block(h, b"m" * 128)
    sid = stripe_of(h, kvc.num_servers)
    old_home = kvc.replica_sat(sid, 0)
    kvc.rotate(6)
    assert kvc.server_sat(sid) != old_home  # the stripe actually moved
    assert kvc.dir_shard_len(kvc.replica_sat(sid, 0)) >= 1
    assert kvc.get_block(h) == b"m" * 128
    assert kvc.stats.degraded_lookups == 0


# ---------------------------------------------------------------------------
# fault plans / injector determinism
# ---------------------------------------------------------------------------

def test_seeded_churn_is_deterministic():
    sats = list(SPEC.all_sats())[:40]
    mk = lambda seed: FaultPlan.seeded_churn(  # noqa: E731
        sats, seed=seed, n_outages=5, window_s=2.0, downtime_s=1.0)
    assert mk(7 + SEED).events == mk(7 + SEED).events
    assert mk(7 + SEED).events != mk(8 + SEED).events
    plan = mk(7 + SEED)
    assert [e.at_s for e in plan.events] == sorted(
        e.at_s for e in plan.events)
    assert sum(e.action == "kill" for e in plan.events) == 5
    assert sum(e.action == "heal" for e in plan.events) == 5


def test_injector_fires_on_the_fabric_clock():
    clock = SimClock(rate=500.0)
    kvc = make_kvc(clock=clock, replication=1)
    kvc.set_block(H, PAYLOAD)
    inj = FaultInjector(kvc, FaultPlan.outages(
        [kvc.server_sat(3)], kill_at_s=0.5))
    inj.arm()
    t0 = clock.now()
    assert kvc.get_block(H) == PAYLOAD              # not yet due
    clock.wait_until(t0 + 0.6)
    assert kvc.get_block(H) is None                 # op ticked the plan
    assert inj.stats.sat_kills == 1


def test_injector_drain_applies_outstanding_heals():
    kvc = make_kvc(replication=2)
    kvc.set_block(H, PAYLOAD)
    inj = FaultInjector(kvc, FaultPlan.outages(
        [kvc.server_sat(1)], kill_at_s=0.0, downtime_s=1e9))
    inj.arm()
    kvc.get_block(H)
    assert not inj.state.sat_alive(kvc.server_sat(1))
    inj.drain()
    assert inj.state.sat_alive(kvc.server_sat(1))
    assert kvc.repair() >= 1


def test_survivable_kills_never_complete_a_home_set():
    kvc = make_kvc(replication=2)
    kills = set(plan_survivable_kills(kvc, 4, seed=3 + SEED))
    assert len(kills) >= 1
    for sid in range(kvc.num_servers):
        homes = {kvc.replica_sat(sid, r) for r in range(2)}
        assert not homes <= kills
    assert plan_survivable_kills(
        kvc, 4, seed=3 + SEED) == plan_survivable_kills(
        kvc, 4, seed=3 + SEED)


def test_fault_state_copy_on_write_reads():
    state = FaultState()
    a, b = Sat(0, 0), Sat(0, 1)
    state.kill_link(a, b)
    assert not state.link_alive(a, b) and state.link_alive(b, Sat(0, 2))
    snapshot = state.dead_sats
    state.kill_sat(a)
    assert snapshot == frozenset()                  # old view unchanged
    assert not state.sat_alive(a)
    state.heal_sat(a)
    state.heal_link(a, b)
    assert state.clean


# ---------------------------------------------------------------------------
# FaultState properties (hypothesis)
# ---------------------------------------------------------------------------

_sats = st.builds(Sat, st.integers(0, SPEC.num_planes - 1),
                  st.integers(0, SPEC.sats_per_plane - 1))
_faults = st.lists(st.tuples(st.booleans(), _sats, _sats), max_size=24)


@given(a=_sats, b=_sats)
@settings(max_examples=100, deadline=None)
def test_link_key_symmetric(a, b):
    """ISL links are undirected: key, kill, heal, and liveness are all
    orientation-blind."""
    assert link_key(a, b) == link_key(b, a)
    state = FaultState()
    state.kill_link(a, b)
    assert not state.link_alive(b, a)
    state.heal_link(b, a)
    assert state.clean


@given(ops=_faults)
@settings(max_examples=100, deadline=None)
def test_kill_heal_round_trip_restores_empty_state(ops):
    """Healing every kill (in any order, duplicates and all) restores
    FaultState to empty -- no residue to leak into later route pricing."""
    state = FaultState()
    for sat_kill, a, b in ops:
        if sat_kill:
            state.kill_sat(a)
        else:
            state.kill_link(a, b)
    assert state.clean == (not ops)
    for sat_kill, a, b in reversed(ops):
        if sat_kill:
            state.heal_sat(a)
        else:
            state.heal_link(b, a)               # reversed orientation too
    assert state.clean
    assert state.dead_sats == frozenset()
    assert state.dead_links == frozenset()


@given(ops=_faults)
@settings(max_examples=100, deadline=None)
def test_copy_on_write_snapshots_never_see_later_kills(ops):
    """A reader's snapshot taken before a kill never sees it: every
    mutation replaces the frozensets wholesale, so views captured
    earlier are frozen at their capture-time contents."""
    state = FaultState()
    expected_sats: set = set()
    expected_links: set = set()
    snapshots = []          # (dead_sats view, dead_links view, expected)
    for sat_kill, a, b in ops:
        snapshots.append((state.dead_sats, state.dead_links,
                          frozenset(expected_sats),
                          frozenset(expected_links)))
        if sat_kill:
            state.kill_sat(a)
            expected_sats.add(a)
        else:
            state.kill_link(a, b)
            expected_links.add(link_key(a, b))
        # every earlier snapshot still shows exactly what was dead when
        # it was taken -- this kill did not leak into it
        for dsat, dlink, want_sats, want_links in snapshots:
            assert dsat == want_sats and dlink == want_links
    assert state.dead_sats == frozenset(expected_sats)
    assert state.dead_links == frozenset(expected_links)


# ---------------------------------------------------------------------------
# serving under churn (tiny model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, kvc):
    return Engine(model, params, kvc=kvc, block_size=16,
                  max_seq_len=256, max_batch=2)


def _reqs(n=4, groups=2, max_new=5):
    base = "fault tolerant constellation keeps serving under churn. "
    return [Request(prompt=f"[doc {i % groups}] " + base * 2,
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i in range(n)]


def test_engine_recomputes_lost_blocks_never_crashes(dense_setup):
    """k=1 total loss between two serves of the same prompt: the second
    serve must recompute (cached_tokens == 0), complete, and emit the
    same tokens as an unfaulted engine."""
    _, model, params = dense_setup
    eng_ref = _engine(model, params, make_kvc(replication=1))
    ref = [eng_ref.generate(_reqs(n=2, groups=1)) for _ in range(2)][1]

    kvc = make_kvc(replication=1)
    eng = _engine(model, params, kvc)
    eng.generate(_reqs(n=2, groups=1))              # populate + compile
    kill_now(kvc, list(kvc.server_map))
    out = eng.generate(_reqs(n=2, groups=1))
    assert all(len(r.token_ids) > 0 for r in out)
    assert all(r.cached_tokens == 0 for r in out)
    assert eng.stats.lost_blocks >= 1
    assert [r.token_ids for r in out] == [r.token_ids for r in ref]


def test_engine_degraded_hits_under_partial_outage(dense_setup):
    """k=2 with a few chunk servers dead: lookups still hit through the
    surviving replicas and the engine attributes the degraded reads."""
    _, model, params = dense_setup
    kvc = make_kvc(replication=2)
    eng = _engine(model, params, kvc)
    eng.generate(_reqs(n=2, groups=1))              # populate + compile
    kill_now(kvc, plan_survivable_kills(kvc, 3, seed=5 + SEED))
    out = eng.generate(_reqs(n=2, groups=1))
    assert all(len(r.token_ids) > 0 for r in out)
    assert sum(r.cached_tokens for r in out) > 0    # still hitting
    assert eng.stats.degraded_reads >= 1


def test_cluster_chaos_serve_in_order(dense_setup):
    """Cluster serve with kills landing mid-serve on the fabric clock:
    every request completes, in request order, and post-run drain+repair
    settles the fabric."""
    _, model, params = dense_setup
    clock = SimClock(rate=5.0)
    kvc = make_kvc(clock=clock, replication=2)
    cluster = EngineCluster(
        model, params, kvc, num_replicas=2, block_size=16,
        max_seq_len=256, max_batch=4)
    reqs = _reqs(n=6, groups=2)
    cluster.serve(reqs, parallel=False)             # populate + compile
    cluster.reset_stats()
    inj = FaultInjector(kvc, FaultPlan.outages(
        plan_survivable_kills(kvc, 3, seed=5 + SEED),
        kill_at_s=0.0, stagger_s=0.05, downtime_s=1e9))
    inj.arm()
    out = cluster.serve(reqs, parallel=True)
    assert len(out) == len(reqs)
    for req, res in zip(reqs, out):
        assert res.request_id == req.request_id
        assert len(res.token_ids) > 0
    fabric = cluster.fabric_stats()
    assert fabric["degraded_reads"] >= 1
    inj.drain()
    assert kvc.repair() >= 1
    assert cluster.fabric_stats()["repaired_chunks"] >= 1
    assert kvc.sweep_incomplete() == 0


def test_chaos_same_seed_same_serve_results(dense_setup):
    """The chaos harness is reproducible: the same FaultPlan seed over
    the same stream yields identical serve results."""
    _, model, params = dense_setup

    def run():
        kvc = make_kvc(replication=2)
        cluster = EngineCluster(
            model, params, kvc, num_replicas=2, router_seed=0,
            block_size=16, max_seq_len=256, max_batch=4)
        reqs = _reqs(n=6, groups=2)
        cluster.serve(reqs, parallel=False)
        inj = FaultInjector(kvc, FaultPlan.seeded_churn(
            plan_survivable_kills(kvc, 4, seed=11 + SEED), seed=11 + SEED,
            n_outages=3, window_s=0.0))             # due at arm time
        inj.arm()
        out = cluster.serve(reqs, parallel=False)
        return [(r.request_id is not None, tuple(r.token_ids),
                 r.cached_tokens) for r in out]

    assert run() == run()


def test_chaos_arc_under_sustained_load_replays(dense_setup):
    """Seed-generic composite arc (sat kills + link cut + heals) driven
    through the deterministic serve_stream interleave: for ANY chaos
    seed the run replays byte-identically -- same records, same fault
    counters, same phase-tagged goodput timeline -- and the arc's kills
    and heals all land mid-stream."""
    _, model, params = dense_setup
    tenants = standard_tenants(2, 4.0, max_new_tokens=4,
                               prompt_chars=(24, 48))
    arrivals = TrafficGenerator(tenants, seed=7 + SEED).take(8)
    span = arrivals[-1].t_s

    def run():
        kvc = make_kvc(replication=2)
        cluster = EngineCluster(
            model, params, kvc, num_replicas=2, router_seed=0,
            block_size=16, max_seq_len=256, max_batch=4,
            rotate_every_s=span / 4)
        plan = FaultPlan.chaos_arc(
            kvc, seed=13 + SEED, churn_start_s=span * 0.25,
            churn_window_s=span * 0.2, heal_s=span * 0.7,
            n_sat_kills=2, n_link_cuts=1)
        report = cluster.serve_stream(arrivals, parallel=False,
                                      faults=plan, slo_window_s=span / 4)
        fp = [(r.arrival.tenant, r.shed,
               tuple(r.result.token_ids) if r.result else None)
              for r in report.records]
        return fp, report.faults, [w["phase"] for w in
                                   report.slo["windows"]]

    fp_a, faults_a, phases_a = run()
    fp_b, faults_b, phases_b = run()
    assert fp_a == fp_b
    assert faults_a == faults_b
    assert phases_a == phases_b
    assert faults_a["sat_kills"] >= 2 and faults_a["sat_heals"] >= 2
    assert faults_a["link_kills"] >= 1 and faults_a["link_heals"] >= 1
    assert "pre_churn" in phases_a and "post_heal" in phases_a
    assert all(t is not None and len(t) > 0 for _, _, t in fp_a)
