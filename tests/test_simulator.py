"""Latency simulator vs the paper's §4 claims (Figs 1, 2, 16)."""
import dataclasses

import pytest

from repro.core.mapping import Strategy
from repro.core.simulator import (
    MEMORY_HIERARCHY_S,
    SimConfig,
    intra_plane_latency_s,
    isl_latency_grid,
    memory_tier_for_latency,
    required_sats_per_plane_for,
    sweep,
    worst_case_latency,
)

CFG = SimConfig()


def test_fig16_rotation_hop_is_lowest_across_altitudes():
    """Paper: 'the hop- and rotation-aware approach results in lower latency
    than the hop-aware and the rotation-aware approaches across different
    altitudes'."""
    for h in (160.0, 550.0, 1000.0, 2000.0):
        for s in (9, 25, 81):
            cfg = dataclasses.replace(CFG, altitude_km=h, num_servers=s)
            rh = worst_case_latency(Strategy.ROTATION_HOP, cfg).worst_latency_s
            rot = worst_case_latency(Strategy.ROTATION, cfg).worst_latency_s
            hop = worst_case_latency(Strategy.HOP, cfg).worst_latency_s
            assert rh <= rot, (h, s)
            assert rh <= hop, (h, s)


def test_fig16_more_servers_about_90pct_reduction():
    """Paper: 'An 8x increase in servers results in about 90% reduction in
    latency' (9 -> 81 servers)."""
    lo = worst_case_latency(
        Strategy.ROTATION_HOP, dataclasses.replace(CFG, num_servers=9)
    ).worst_latency_s
    hi = worst_case_latency(
        Strategy.ROTATION_HOP, dataclasses.replace(CFG, num_servers=81)
    ).worst_latency_s
    reduction = 1.0 - hi / lo
    assert 0.80 <= reduction <= 0.95


def test_latency_grows_with_altitude():
    prev = 0.0
    for h in (160.0, 550.0, 1000.0, 2000.0):
        cfg = dataclasses.replace(CFG, altitude_km=h)
        cur = worst_case_latency(Strategy.ROTATION_HOP, cfg).worst_latency_s
        assert cur > prev
        prev = cur


def test_processing_term_scales_inversely_with_servers():
    r9 = worst_case_latency(Strategy.HOP, dataclasses.replace(CFG, num_servers=9))
    r81 = worst_case_latency(Strategy.HOP, dataclasses.replace(CFG, num_servers=81))
    assert r9.worst_processing_s == pytest.approx(
        9 * r81.worst_processing_s, rel=0.05
    )


def test_figs1_2_intra_plane_latency_shape():
    # latency decreases with M, increases with h (paper Figs 1-2)
    assert intra_plane_latency_s(50, 550) < intra_plane_latency_s(15, 550)
    assert intra_plane_latency_s(15, 2000) > intra_plane_latency_s(15, 160)
    grid = isl_latency_grid()
    assert len(grid) == 7 * 5
    assert all(lat > 0 for _, _, lat in grid)


def test_50plus_sats_reaches_ssd_hdd_band():
    """Paper §2: 'roughly a latency between SSD and HDD with about 50+
    satellites in a plane' (<2 ms is their extrapolation)."""
    hdd_lo = MEMORY_HIERARCHY_S["HDD"][0]  # 2 ms
    m = required_sats_per_plane_for(2e-3, altitude_km=550.0)
    assert 40 <= m <= 110  # the paper's 'about 50+' extrapolation
    assert intra_plane_latency_s(m, 550.0) <= hdd_lo


def test_memory_tier_classifier():
    assert memory_tier_for_latency(12e-9) == "CPU"
    assert memory_tier_for_latency(3e-3) in ("HDD", "LEO (theoretical Laser)")
    assert "between" in memory_tier_for_latency(1e-3) or memory_tier_for_latency(1e-3)


def test_sweep_covers_fig16_grid():
    rows = sweep()
    assert len(rows) == 3 * 4 * 4
    strategies = {r.strategy for r in rows}
    assert strategies == {"rotation", "hop", "rotation_hop"}
    assert all(r.worst_latency_s > 0 for r in rows)
