"""End-to-end behaviour tests for the SkyMemory system.

The full story: a prompt's KV cache is block-hashed, chunked, striped over a
rotating LEO constellation, survives migration and eviction pressure, and
feeds generation that is bit-identical to cache-less generation -- while the
latency simulator reproduces the paper's §4 findings.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config, smoke_config
from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    IslTransport,
    LosWindow,
    Sat,
    Strategy,
)
from repro.core.mapping import layout_grid
from repro.core.simulator import SimConfig, worst_case_latency
from repro.models.model import Model
from repro.serving import Engine, Request, SamplingParams

PROMPT = ("SkyMemory is a LEO edge cache for transformer inference "
          "optimization and scale out, striping KV chunks across "
          "satellites. ") * 3


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _kvc(strategy=Strategy.ROTATION_HOP, **kw):
    spec = ConstellationSpec(15, 15, 550.0)
    transport = IslTransport(spec, ground_hosted=True,
                             chunk_processing_time_s=0.002)
    return ConstellationKVC(
        spec, LosWindow(Sat(7, 7), 9, 9), strategy, num_servers=10,
        chunk_bytes=6 * 1024, transport=transport, **kw,
    )


def test_full_serving_story(engine_setup):
    """Cold miss -> warm hit -> rotation -> still hits -> identical output."""
    cfg, model, params = engine_setup
    kvc = _kvc()
    eng = Engine(model, params, kvc=kvc, block_size=16, max_seq_len=256)
    sp = SamplingParams(max_new_tokens=6)

    r1 = eng.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert r1.cached_tokens == 0 and kvc.stats.blocks_set > 0

    r2 = eng.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert r2.cached_tokens > 0
    assert r2.token_ids == r1.token_ids  # cache must not change outputs

    kvc.rotate(steps=4)
    r3 = eng.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert r3.cached_tokens > 0
    assert r3.token_ids == r1.token_ids

    # transport actually modeled ISL latencies
    assert kvc.transport.stats.messages > 0
    assert max(kvc.transport.stats.op_latencies_s) > 0


def test_eviction_pressure_keeps_consistency(engine_setup):
    cfg, model, params = engine_setup
    kvc = _kvc(per_sat_capacity_bytes=16 * 1024)  # tight per-sat memory
    eng = Engine(model, params, kvc=kvc, block_size=16, max_seq_len=256)
    sp = SamplingParams(max_new_tokens=4)
    outs = []
    for i in range(4):
        r = eng.generate([Request(prompt=PROMPT + str(i), sampling=sp)])[0]
        outs.append(r.token_ids)
    # evictions occurred, yet regenerating the first prompt is consistent
    r = eng.generate([Request(prompt=PROMPT + "0", sampling=sp)])[0]
    assert r.token_ids == outs[0]


def test_paper_figures_reproduced():
    """The §4 claims in one place (details in test_simulator/test_mapping)."""
    # Fig 15 (3x3 published grid)
    assert layout_grid(Strategy.ROTATION_HOP, 3) == [
        [7, 2, 6], [5, 1, 3], [9, 4, 8]]
    # Fig 16: rotation+hop lowest; ~90% reduction for 9x servers
    base = SimConfig()
    lat = {
        s: worst_case_latency(s, base).worst_latency_s for s in Strategy
    }
    assert lat[Strategy.ROTATION_HOP] <= min(lat.values()) + 1e-12
    lo = worst_case_latency(
        Strategy.ROTATION_HOP, dataclasses.replace(base, num_servers=9))
    reduction = 1 - lat[Strategy.ROTATION_HOP] / lo.worst_latency_s
    assert 0.8 <= reduction <= 0.95


def test_cross_strategy_consistency(engine_setup):
    """All three placements serve identical content (placement is a pure
    latency/locality decision, never a correctness one)."""
    cfg, model, params = engine_setup
    sp = SamplingParams(max_new_tokens=4)
    outs = {}
    for strat in Strategy:
        eng = Engine(model, params, kvc=_kvc(strat), block_size=16,
                     max_seq_len=256)
        eng.generate([Request(prompt=PROMPT, sampling=sp)])
        outs[strat] = eng.generate(
            [Request(prompt=PROMPT, sampling=sp)])[0].token_ids
    assert len({tuple(v) for v in outs.values()}) == 1
