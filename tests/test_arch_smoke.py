"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each assigned architecture: one forward + one train step on the reduced
variant (2 layers, d_model<=512, <=4 experts), asserting output shapes and
no NaNs; plus decode-vs-forward and prefix-resume consistency (the paths
SkyMemory feeds).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, smoke_config
from repro.models.model import Model

ARCHS = list_configs()
B, S = 2, 32


def _setup(name, dtype="float32"):
    cfg = smoke_config(get_config(name)).replace(dtype=dtype)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = {}
    if cfg.arch_type == "vlm":
        kw["image_embeds"] = (
            jax.random.normal(
                jax.random.PRNGKey(5), (B, cfg.num_image_tokens, cfg.d_model)
            ) * 0.1
        )
    if cfg.is_encoder_decoder:
        kw["frames"] = jax.random.normal(
            jax.random.PRNGKey(6), (B, S, cfg.d_model)
        ) * 0.5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    return cfg, model, params, toks, kw


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, model, params, toks, kw = _setup(name, dtype="bfloat16")
    logits, aux, _ = model.forward(params, toks, **kw)
    n_img = cfg.num_image_tokens if cfg.arch_type == "vlm" else 0
    assert logits.shape == (B, S + n_img, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_finite_grads(name):
    cfg, model, params, toks, kw = _setup(name, dtype="float32")
    batch = {"tokens": toks, "targets": toks, **kw}
    loss, metrics = model.train_loss(params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    cfg, model, params, toks, kw = _setup(name)
    if cfg.num_experts:
        # as in test_prefix_resume_matches_full_forward: raise capacity so
        # no token drops -- a 1-token decode group routes differently from
        # the 33-token forward group, which legitimately changes outputs
        # under capacity-based dropping (a property of dropping MoE, not
        # of the decode cache)
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
        model = Model(cfg)
    logits, _, state = model.forward(params, toks, collect_state=True, **kw)
    n_img = cfg.num_image_tokens if cfg.arch_type == "vlm" else 0
    total = S + n_img
    cache = model.init_cache(B, total + 8, src_len=S)
    if "kv" in state:
        cache["kv"]["k"] = cache["kv"]["k"].at[:, :, :total].set(state["kv"]["k"])
        cache["kv"]["v"] = cache["kv"]["v"].at[:, :, :total].set(state["kv"]["v"])
    if "mla" in state:
        cache["mla"]["ckv"] = cache["mla"]["ckv"].at[:, :, :total].set(
            state["mla"]["ckv"])
        cache["mla"]["kr"] = cache["mla"]["kr"].at[:, :, :total].set(
            state["mla"]["kr"])
    if "ssm" in state:
        cache["ssm"] = {
            "conv": state["ssm"]["conv"],
            "state": state["ssm"]["state"].astype(jnp.float32),
        }
    if "cross" in state:
        cache["cross"] = state["cross"]
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    lg, _ = model.decode_step(params, cache, nxt, jnp.int32(total))
    full = jnp.concatenate([toks, nxt], 1)
    lg_full, _, _ = model.forward(params, full, **kw)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(lg_full[:, -1]), atol=2e-4, rtol=2e-3
    )


@pytest.mark.parametrize(
    "name", [a for a in ARCHS if get_config(a).arch_type
             in ("dense", "ssm", "hybrid", "moe")]
)
def test_prefix_resume_matches_full_forward(name):
    """The SkyMemory path: restore the block state for the first S/2 tokens
    and run a chunked prefill of the rest -> identical logits.

    MoE capacity is raised so no token drops: capacity-based dropping
    depends on the group composition (a 16-token suffix forms different
    groups than the 32-token full pass), which would legitimately change
    outputs -- that is a property of dropping MoE, not of the cache."""
    cfg, model, params, toks, kw = _setup(name)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
        model = Model(cfg)
    half = S // 2
    _, _, state = model.forward(params, toks[:, :half], collect_state=True)
    logits_resumed, _, _ = model.forward(
        params, toks[:, half:], q_offset=half, prefix_state=state
    )
    logits_full, _, _ = model.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(logits_resumed),
        np.asarray(logits_full[:, half:]),
        atol=2e-4, rtol=2e-3,
    )


@pytest.mark.parametrize("name", ARCHS)
def test_two_train_steps_reduce_loss(name):
    """A couple of SGD steps on a repeated batch should reduce the loss."""
    cfg, model, params, toks, kw = _setup(name)
    batch = {"tokens": toks, "targets": toks, **kw}

    @jax.jit
    def step(p):
        loss, _ = model.train_loss(p, batch)
        g = jax.grad(lambda q: model.train_loss(q, batch)[0])(p)
        p = jax.tree.map(lambda a, b: a - 0.05 * b.astype(a.dtype), p, g)
        return p, loss

    params, l0 = step(params)
    for _ in range(3):
        params, l1 = step(params)
    assert float(l1) < float(l0)


def test_int8_kvc_decode_quality():
    """Paper §3.3/§5: 8-bit quantized KVC trades accuracy for memory --
    greedy argmax must survive the quantization on a smoke model."""
    cfg = smoke_config(get_config("yi-9b")).replace(
        dtype="float32", kvc_dtype="int8")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 24), 0,
                              cfg.vocab_size)
    lg_full, _, _ = model.forward(params, toks)
    cache = model.init_cache(B, 32)
    assert cache["kv"]["k"].dtype == jnp.int8
    for t in range(24):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
    rel = float(jnp.max(jnp.abs(lg[:, 0] - lg_full[:, -1]))) / float(
        jnp.max(jnp.abs(lg_full[:, -1])))
    assert rel < 0.1
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(lg[:, 0], -1)),
        np.asarray(jnp.argmax(lg_full[:, -1], -1)))
