"""``core.migration.plan_migration`` invariants (PR-4 left these
untested): multi-step window shifts land every server inside the new
window, per-plane moves never collide, and a purge racing a planned
move leaves the directory consistent."""
import pytest

from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    LosWindow,
    Sat,
    Strategy,
    migration_planes,
    plan_migration,
)

SPEC = ConstellationSpec(15, 15, 550.0)
WINDOW = LosWindow(Sat(7, 7), 9, 9)


def make_kvc(**kw):
    return ConstellationKVC(SPEC, WINDOW, Strategy.ROTATION_HOP,
                            num_servers=10, chunk_bytes=64, **kw)


@pytest.mark.parametrize("d_slot", [1, 2, 5, 9, 14, 15, 23])
def test_multi_step_shift_lands_every_server_in_window(d_slot):
    kvc = make_kvc()
    old = kvc.window
    new = old
    for _ in range(d_slot):
        new = new.shifted(SPEC, d_slot=1)
    moves = plan_migration(SPEC, old, new, kvc.server_map)
    moved = {mv.server_id - 1: mv.dst for mv in moves}
    for sid0, sat in enumerate(kvc.server_map):
        final = moved.get(sid0, sat)
        assert new.contains(SPEC, final), (d_slot, sid0, final)
    # servers already inside the shifted window are never moved
    for mv in moves:
        assert not new.contains(SPEC, mv.src)


@pytest.mark.parametrize("d_slot", [1, 3, 9])
def test_per_plane_moves_never_collide(d_slot):
    """Within each orbital plane the parallel moves must be pairwise
    disjoint -- distinct destinations, and no destination stealing the
    satellite of a server that did not move -- so the final server map
    stays a bijection onto distinct satellites."""
    kvc = make_kvc()
    old = kvc.window
    new = old
    for _ in range(d_slot):
        new = new.shifted(SPEC, d_slot=1)
    moves = plan_migration(SPEC, old, new, kvc.server_map)
    for plane, group in migration_planes(moves).items():
        assert all(mv.src.plane == mv.dst.plane == plane for mv in group)
        dsts = [mv.dst for mv in group]
        assert len(set(dsts)) == len(dsts)
    # globally: applying the moves keeps all server sats distinct
    final = list(kvc.server_map)
    for mv in moves:
        final[mv.server_id - 1] = mv.dst
    assert len(set(final)) == len(final)


def test_purge_racing_planned_move_keeps_directory_consistent():
    """A block purged between planning and executing a migration (a
    capacity eviction's gossip can land exactly there): executing the
    stale plan must neither resurrect the purged block nor corrupt the
    surviving ones."""
    kvc = make_kvc()
    h_keep, h_gone = b"k" * 32, b"g" * 32
    kvc.set_block(h_keep, b"x" * 640)
    kvc.set_block(h_gone, b"y" * 640)
    new = kvc.window
    for _ in range(5):                      # far enough to evict servers
        new = new.shifted(SPEC, d_slot=1)
    moves = plan_migration(SPEC, kvc.window, new, kvc.server_map)
    assert moves
    kvc.purge_block(h_gone)                 # the race: purge after plan
    for mv in moves:
        kvc.execute_move(mv)
    kvc.window = new
    assert h_gone not in kvc.directory
    assert kvc.get_block(h_gone) is None
    assert kvc.get_block(h_keep) == b"x" * 640
    assert kvc.sweep_incomplete() == 0
    # no orphan chunks of the purged block survived the move
    for sat in SPEC.all_sats():
        store = kvc._stores.get(sat)
        if store is not None:
            assert all(key[0] != h_gone for key in store.keys())


def test_purge_racing_planned_move_replicated():
    """Same race under k=2 replication: the selective per-server move
    path must stay consistent too."""
    kvc = make_kvc(replication=2)
    h_keep, h_gone = b"k" * 32, b"g" * 32
    kvc.set_block(h_keep, b"x" * 640)
    kvc.set_block(h_gone, b"y" * 640)
    new = kvc.window
    for _ in range(5):
        new = new.shifted(SPEC, d_slot=1)
    moves = plan_migration(SPEC, kvc.window, new, kvc.server_map)
    assert moves
    kvc.purge_block(h_gone)
    for mv in moves:
        kvc.execute_move(mv)
    kvc.window = new
    assert kvc.get_block(h_keep) == b"x" * 640
    assert kvc.get_block(h_gone) is None
    assert kvc.repair() == 0                # full replica sets survived
    assert kvc.sweep_incomplete() == 0
