"""Tiered KV fabric: Scheduler/Executor/KVManager layering, lazy page
growth, preemption-by-offload, restore equivalence, and the shared LRU
policy across tiers.

The core guarantee under test: a preempted-and-resumed sequence emits
byte-identical tokens to an uninterrupted run -- across paged families
(dense and MoE), both pool modes (contiguous slot regions and free-list
oversubscription), and every restore flavor (bit-exact host-tier import,
constellation block prefix + tail replay, full recompute).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import ConstellationKVC, ConstellationSpec, LosWindow, Sat, Strategy
from repro.core.eviction import LRUClock
from repro.core.hashing import chain_hashes
from repro.core.radix import BlockMeta, RadixBlockIndex
from repro.core.store import SatelliteStore
from repro.models.cache import PagedKVCache
from repro.models.model import Model
from repro.serving import Engine, Request, SamplingParams, SeqState

PROMPT = "SkyMemory stripes KV cache chunks across LEO satellites. " * 3


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def moe_setup():
    cfg = smoke_config(get_config("granite-moe-3b-a800m")).replace(
        dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_kvc():
    return ConstellationKVC(
        ConstellationSpec(15, 15, 550.0), LosWindow(Sat(7, 7), 9, 9),
        Strategy.ROTATION_HOP, num_servers=10, chunk_bytes=6 * 1024,
    )


def grow_reqs(max_new=100, n=4):
    """Short prompts that co-admit into every slot and then grow: the
    workload that exercises lazy allocation and growth-pressure
    preemption (long prompts serialize at admission instead)."""
    sp = SamplingParams(max_new_tokens=max_new)
    return [Request(prompt=f"grow {i} " + "x" * 24, sampling=sp)
            for i in range(n)]


# ---------------------------------------------------------------------------
# layering: the three modules are separately importable, engine is a facade
# ---------------------------------------------------------------------------

def test_layers_importable_and_engine_is_a_facade():
    from repro.serving.executor import DenseRuntime, PagedExecutor  # noqa
    from repro.serving.kv_manager import HostPageCache, TieredKVManager  # noqa
    from repro.serving.scheduler import Scheduler, chunk_spans  # noqa

    import repro.serving.engine as engine_mod
    with open(engine_mod.__file__) as f:
        n_lines = len(f.readlines())
    assert n_lines < 300, "engine.py must stay an orchestration facade"


def test_engine_wires_layers(dense_setup):
    cfg, model, params = dense_setup
    from repro.serving.executor import PagedExecutor
    from repro.serving.kv_manager import TieredKVManager
    from repro.serving.scheduler import Scheduler

    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2)
    assert isinstance(eng.scheduler, Scheduler)
    assert isinstance(eng.executor, PagedExecutor)
    assert isinstance(eng.kv, TieredKVManager)
    assert eng.kv.pool is eng.cache
    # one stats object across the layers; reassignment re-points all
    assert eng.scheduler.stats is eng.stats and eng.kv.stats is eng.stats
    from repro.serving import EngineStats
    eng.stats = EngineStats()
    assert eng.scheduler.stats is eng.stats and eng.kv.stats is eng.stats


def test_preempted_state_in_lifecycle():
    assert SeqState.PREEMPTED.value == "preempted"


# ---------------------------------------------------------------------------
# page export/import views
# ---------------------------------------------------------------------------

def test_export_import_pages_bit_identical(dense_setup):
    cfg, _, _ = dense_setup
    c = PagedKVCache(cfg, num_slots=2, page_size=16, max_seq_len=64,
                     num_pages=1 + 8)
    c.ensure_capacity(0, 48)
    la, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((la, 3, 16, hkv, hd)), jnp.float32)
    c.write_pages(0, 0, k, k + 1)
    ek, ev = c.export_pages(0, 3)
    c.free_slot(0)
    c.ensure_capacity(1, 48)                 # different physical pages
    c.write_pages(1, 0, ek, ev)
    ek2, ev2 = c.export_pages(1, 3)
    np.testing.assert_array_equal(ek, np.asarray(k))
    np.testing.assert_array_equal(ek2, ek)
    np.testing.assert_array_equal(ev2, ev)
    with pytest.raises(RuntimeError):
        c.export_pages(1, 4)                 # beyond allocated


def test_pages_payload_roundtrip(dense_setup):
    """pages -> payload -> pages is exact: the L2 spill path writes a
    preempted sequence's literal pool pages, never a recompute."""
    cfg, model, params = dense_setup
    from repro.serving.skycache import SkyKVCAdapter
    adapter = SkyKVCAdapter(model, params)
    la, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    rng = np.random.default_rng(1)
    k = rng.standard_normal((la, 2, 16, hkv, hd)).astype(np.float32)
    v = rng.standard_normal((la, 2, 16, hkv, hd)).astype(np.float32)
    payload = adapter.pages_to_payload(k, v, 32)
    k2, v2 = adapter.payload_to_pages(payload, 32, 16)
    np.testing.assert_array_equal(np.asarray(k2), k)
    np.testing.assert_array_equal(np.asarray(v2), v)


# ---------------------------------------------------------------------------
# preempt/restore equivalence (the satellite's core requirement)
# ---------------------------------------------------------------------------

def test_growth_preemption_free_list_byte_identical(dense_setup):
    """Oversubscribed free-list pool: sequences co-admit lazily, growth
    exhausts the pool, the scheduler preempts by offload, and every
    request still completes with byte-identical tokens (host-tier
    restore: nothing replayed)."""
    cfg, model, params = dense_setup
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4)
    want = [r.token_ids for r in ref.generate(grow_reqs())]
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4,
                 num_pages=1 + 16)
    res = eng.generate(grow_reqs())
    assert eng.stats.preemptions > 0
    assert eng.stats.restores == eng.stats.preemptions
    assert eng.stats.offloaded_pages > 0
    assert eng.stats.replayed_tokens == 0      # L1 restores are bit-exact
    assert sum(r.preemptions for r in res) == eng.stats.preemptions
    assert [r.token_ids for r in res] == want
    assert eng.cache.free_pages == eng.cache.num_pages - 1


def test_recompute_restore_byte_identical(dense_setup):
    """host_cache_pages=0 disables L1 and there is no constellation, so
    every restore is a full chunked-prefill recompute of the sequence --
    tokens must still match the uninterrupted run."""
    cfg, model, params = dense_setup
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4)
    want = [r.token_ids for r in ref.generate(grow_reqs())]
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4,
                 num_pages=1 + 16, host_cache_pages=0)
    res = eng.generate(grow_reqs())
    assert eng.stats.preemptions > 0
    assert eng.stats.replayed_tokens > 0       # the whole span recomputes
    assert [r.token_ids for r in res] == want


def test_l2_spill_restore_byte_identical(dense_setup):
    """A tiny host cache spills block-aligned prefixes to the
    constellation (exact-page payloads, no model recompute); restores
    fetch them back through Get KVC and replay at most the unaligned
    tail."""
    cfg, model, params = dense_setup
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4)
    want = [r.token_ids for r in ref.generate(grow_reqs())]
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=4, num_pages=1 + 16,
                 host_cache_pages=4)
    res = eng.generate(grow_reqs())
    assert eng.stats.preemptions > 0
    assert eng.stats.spilled_blocks > 0
    assert [r.token_ids for r in res] == want


def test_priority_preemption_contiguous_byte_identical(dense_setup):
    """Contiguous pools never run out of pages, but slots are scarce: a
    strictly higher-priority request evicts the lowest-priority running
    sequence, which resumes later with unchanged output."""
    cfg, model, params = dense_setup
    sp_long = SamplingParams(max_new_tokens=40)
    sp_hi = SamplingParams(max_new_tokens=8)
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=1)
    w_lo = ref.generate(
        [Request(prompt=PROMPT + "low", sampling=sp_long)])[0].token_ids
    w_hi = ref.generate(
        [Request(prompt=PROMPT + "high", sampling=sp_hi)])[0].token_ids
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=1)
    res = eng.generate([
        Request(prompt=PROMPT + "low", sampling=sp_long, priority=0),
        Request(prompt=PROMPT + "high", sampling=sp_hi, priority=5),
    ])
    assert eng.cache.contiguous
    assert eng.stats.preemptions >= 1
    assert res[0].preemptions >= 1
    assert res[0].token_ids == w_lo
    assert res[1].token_ids == w_hi


def test_equal_priority_never_preempts(dense_setup):
    """Plain FIFO streams must not thrash: equal priorities queue, they
    do not evict each other (preemption needs growth pressure or a
    strictly higher priority)."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=1)
    sp = SamplingParams(max_new_tokens=6)
    res = eng.generate([Request(prompt=f"{PROMPT} {i}", sampling=sp)
                        for i in range(3)])
    assert eng.stats.preemptions == 0
    assert all(len(r.token_ids) == 6 for r in res)


def test_moe_preemption_byte_identical(moe_setup):
    """MoE families (stop-the-world admission) swap through the same
    tiers; the host-tier restore is bit-exact, so capacity routing sees
    identical K/V and outputs are unchanged."""
    cfg, model, params = moe_setup
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4)
    assert not ref.chunked                     # MoE forces chunk_tokens=0
    want = [r.token_ids for r in ref.generate(grow_reqs(max_new=60))]
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4,
                 num_pages=1 + 16)
    res = eng.generate(grow_reqs(max_new=60))
    assert eng.stats.preemptions > 0
    assert eng.stats.replayed_tokens == 0      # restored from L1, bit-exact
    assert [r.token_ids for r in res] == want


def test_moe_offloads_pinned_in_host_tier(moe_setup):
    """A tail replay would run the replayed tokens as one chunk group
    and re-route experts (capacity routing is group-composition
    dependent), so MoE offloads are PINNED in the host tier: even with
    the cache nominally disabled, restores stay bit-exact and outputs
    unchanged."""
    cfg, model, params = moe_setup
    ref = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4)
    want = [r.token_ids for r in ref.generate(grow_reqs(max_new=60))]
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4,
                 num_pages=1 + 16, host_cache_pages=0)
    res = eng.generate(grow_reqs(max_new=60))
    assert eng.stats.preemptions > 0
    assert eng.stats.replayed_tokens == 0      # pinned: never recomputed
    assert [r.token_ids for r in res] == want


def test_oversubscribed_pool_completes_every_request(dense_setup):
    """Pool sized for roughly half the live sequences: every request
    completes via preemption-by-offload -- no admission refusal, no pool
    exhaustion, all pages recycled."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=4,
                 num_pages=1 + 16)
    res = eng.generate(grow_reqs(max_new=80, n=8))
    assert len(res) == 8
    assert all(len(r.token_ids) == 80 for r in res)
    assert eng.stats.preemptions > 0
    assert eng.cache.free_pages == eng.cache.num_pages - 1


def test_preemption_with_skymemory_prefix_hits(dense_setup):
    """Preemption composes with the prefix cache: warm blocks still hit
    at (re)admission, and generations match the unpressured engine."""
    cfg, model, params = dense_setup
    sp = SamplingParams(max_new_tokens=60)
    reqs = lambda: [Request(prompt=PROMPT + f" q{i}", sampling=sp)
                    for i in range(3)]
    ref = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=3)
    ref.generate(reqs())
    want = [r.token_ids for r in ref.generate(reqs())]
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=3, num_pages=1 + 16)
    eng.generate(reqs())
    res = eng.generate(reqs())
    assert all(r.cached_tokens > 0 for r in res)
    assert [r.token_ids for r in res] == want


# ---------------------------------------------------------------------------
# shared LRU policy across tiers
# ---------------------------------------------------------------------------

def test_lru_clock_victim_and_forget():
    c = LRUClock()
    c.touch("a"), c.touch("b"), c.touch("c")
    assert c.victim(["a", "b", "c"]) == "a"
    c.touch("a")
    assert c.victim(["a", "b", "c"]) == "b"
    c.forget("c")
    assert c.recency("c") == 0
    assert c.victim(["a", "c"]) == "c"         # forgotten = oldest
    assert c.victim([]) is None


def test_radix_hits_touch_shared_policy():
    policy = LRUClock()
    idx = RadixBlockIndex(policy=policy)
    hashes = chain_hashes(list(range(64)), 16)
    metas = [BlockMeta(n_chunks=1, set_time=0.0) for _ in hashes]
    idx.insert(hashes, metas)
    base = [policy.recency(h) for h in hashes]
    n, _ = idx.longest_cached_prefix(hashes[:2])
    assert n == 2
    after = [policy.recency(h) for h in hashes]
    assert after[0] > base[0] and after[1] > base[1]
    assert after[2] == base[2] and after[3] == base[3]
    idx.remove(hashes[:4])
    assert policy.recency(hashes[3]) == 0


def test_store_eviction_uses_shared_policy():
    policy = LRUClock()
    store = SatelliteStore(capacity_bytes=3 * 10, policy=policy)
    for name in (b"h1", b"h2", b"h3"):
        store.set((name, 0), b"x" * 10)
    policy.touch(b"h1")                        # e.g. a radix hit elsewhere
    store.set((b"h4", 0), b"x" * 10)           # forces one eviction
    assert store.contains((b"h1", 0))          # hot via the shared clock
    assert not store.contains((b"h2", 0))      # coldest cross-tier stamp


def test_has_block_probe_refreshes_lru():
    """The staleness fix: a block repeatedly confirmed present by
    ``has_block`` probes must age as *used*, not as untouched."""
    kvc = make_kvc()
    from repro.core.protocol import KVCManager
    mgr = KVCManager(lambda s: [ord(c) % 7 for c in s],
                     lambda t, p, n: b"payload", kvc, block_size=4,
                     use_radix=False)
    assert kvc.policy is mgr.policy            # adopted at manager init
    h1 = chain_hashes(list(range(4)), 4)[0]
    h2 = chain_hashes(list(range(1, 5)), 4)[0]
    kvc.set_block(h1, b"a" * 8)
    kvc.set_block(h2, b"b" * 8)
    r_before = mgr.policy.recency(h1)
    assert kvc.has_block(h1)
    assert mgr.policy.recency(h1) > r_before
    assert mgr.policy.recency(h1) > mgr.policy.recency(h2)


def test_engine_tiers_share_one_policy(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=2)
    assert eng.kv.policy is eng.manager.policy
    assert eng.manager.cache.policy is eng.kv.policy
    assert eng.kv.host.policy is eng.kv.policy


# ---------------------------------------------------------------------------
# host page cache behavior
# ---------------------------------------------------------------------------

def test_host_cache_capacity_and_spill():
    from repro.serving.kv_manager import HostEntry, HostPageCache
    policy = LRUClock()
    spilled = []
    cache = HostPageCache(4, policy, spill=lambda k, e: spilled.append(k))

    def entry(n_pages, n_tokens):
        k = np.zeros((1, n_pages, 4, 1, 1), np.float32)
        return HostEntry(k=k, v=k, tokens=list(range(n_tokens)))

    cache.put("a", entry(2, 8))
    cache.put("b", entry(2, 8))
    assert cache.used_pages == 4 and not spilled
    cache.put("c", entry(2, 8))                # over: evicts oldest ("a")
    assert spilled == ["a"] and len(cache) == 2
    assert cache.pop("a") is None
    assert cache.pop("b") is not None          # pop removes
    assert len(cache) == 1
    cache.put("big", entry(9, 36))             # alone over capacity:
    assert "big" in spilled                    # spilled through, not kept
    assert "c" in spilled
