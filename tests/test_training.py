"""Optimizer, data pipeline, train loop, checkpoint tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, smoke_config
from repro.models.model import Model
from repro.training import (
    AdamWConfig,
    DataConfig,
    TrainConfig,
    adamw_update,
    init_opt_state,
    load_checkpoint,
    lr_at,
    make_dataset,
    save_checkpoint,
    train,
)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    # monotone decay after warmup
    vals = [float(lr_at(cfg, s)) for s in range(10, 101, 10)]
    assert vals == sorted(vals, reverse=True)


@given(scale=st.floats(0.1, 10.0))
@settings(max_examples=10, deadline=None)
def test_grad_clip_bounds_update(scale):
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), scale)}
    state = init_opt_state(params)
    new, state, metrics = adamw_update(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(scale * 4.0, rel=1e-4)
    assert bool(jnp.isfinite(new["w"]).all())


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_synthetic_data_deterministic_and_shaped():
    dcfg = DataConfig(vocab_size=100, seq_len=32, batch_size=4, seed=7)
    b1 = next(make_dataset(dcfg).batches())
    b2 = next(make_dataset(dcfg).batches())
    assert b1["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 100


def test_textfile_data(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("the quick brown fox jumps over the lazy dog " * 50)
    dcfg = DataConfig(vocab_size=512, seq_len=64, batch_size=2, path=str(p))
    b = next(make_dataset(dcfg).batches())
    assert b["tokens"].shape == (2, 64)


def test_train_reduces_loss_and_checkpoints(tmp_path):
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    ds = make_dataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                 batch_size=4))
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=30), log_every=5)
    params, opt, hist = train(model, ds, tcfg, num_steps=30)
    assert hist[-1]["ce"] < hist[0]["ce"]
    save_checkpoint(str(tmp_path / "ck"), params, opt, step=30,
                    metadata={"arch": cfg.name})
    p2, o2, meta = load_checkpoint(str(tmp_path / "ck"), params, opt)
    assert meta["step"] == 30 and meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
    assert int(o2["step"]) == 30


def test_train_with_remat_matches_no_remat():
    cfg = smoke_config(get_config("yi-9b")).replace(dtype="float32")
    model = Model(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    params = model.init(jax.random.PRNGKey(1))
    l0, _ = model.train_loss(params, batch, remat=None)
    l1, _ = model.train_loss(params, batch, remat="full")
    l2, _ = model.train_loss(params, batch, remat="dots")
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
    assert float(l0) == pytest.approx(float(l2), rel=1e-5)
    g0 = jax.grad(lambda p: model.train_loss(p, batch, remat=None)[0])(params)
    g1 = jax.grad(lambda p: model.train_loss(p, batch, remat="full")[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
