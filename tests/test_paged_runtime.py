"""Paged decode runtime: page allocator, vectorized sampler, continuous
batching, and equivalence with the pre-paged dense decode loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import ConstellationKVC, ConstellationSpec, LosWindow, Sat, Strategy
from repro.models.cache import PagedKVCache, supports_paged_decode
from repro.models.model import Model
from repro.serving import (
    Engine,
    Request,
    SamplingParams,
    sample,
    sample_batch,
    stack_sampling,
)

PROMPT = "SkyMemory stripes KV cache chunks across LEO satellites. " * 3


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# PagedKVCache allocator
# ---------------------------------------------------------------------------

def _cache(cfg, slots=2, page=16, max_seq=64, contiguous=False):
    # explicit num_pages -> the general free-list allocator; default
    # (num_pages=None) -> contiguous slot regions
    pages = None if contiguous else 1 + slots * (max_seq // page)
    return PagedKVCache(cfg, num_slots=slots, page_size=page,
                        max_seq_len=max_seq, num_pages=pages)


def test_contiguous_regions_default(dense_setup):
    """Default pool: fixed slot regions, no scratch page, stable tables."""
    cfg, _, _ = dense_setup
    c = _cache(cfg, contiguous=True)
    assert c.contiguous and c.num_pages == 2 * 4
    assert list(c.block_tables[0]) == [0, 1, 2, 3]
    assert list(c.block_tables[1]) == [4, 5, 6, 7]
    assert c.free_pages == 8
    assert c.ensure_capacity(0, 64) is False      # table never changes
    assert c.free_pages == 4 and c.can_admit(64)
    c.ensure_capacity(1, 16)
    assert not c.can_admit(16)                     # no free slot left
    c.free_slot(0)
    assert c.free_pages == 4 and c.can_admit(64)
    with pytest.raises(RuntimeError):
        c.ensure_capacity(0, 65)                   # > pages_per_seq


def test_allocator_scratch_page_reserved(dense_setup):
    cfg, _, _ = dense_setup
    c = _cache(cfg)
    assert not c.contiguous
    c.ensure_capacity(0, 64)
    c.ensure_capacity(1, 64)
    assert 0 not in c.block_tables[np.nonzero(c.block_tables)]  # real pages
    used = {pid for row in c.block_tables for pid in row if pid}
    assert 0 not in used and len(used) == 8


def test_allocator_free_and_reuse(dense_setup):
    cfg, _, _ = dense_setup
    c = _cache(cfg)
    c.ensure_capacity(0, 33)                    # 3 pages of 16
    pages = list(c.block_tables[0, :3])
    assert c.free_pages == c.num_pages - 1 - 3
    c.free_slot(0)
    assert c.free_pages == c.num_pages - 1
    assert (c.block_tables[0] == 0).all()       # repointed at scratch
    c.ensure_capacity(1, 48)
    assert set(c.block_tables[1, :3]) == set(pages)  # pages recycled


def test_allocator_limits(dense_setup):
    cfg, _, _ = dense_setup
    c = _cache(cfg)
    with pytest.raises(RuntimeError):
        c.ensure_capacity(0, 65)                # > pages_per_seq
    assert c.can_admit(63) and not c.can_admit(200)


def test_write_pages_roundtrip(dense_setup):
    cfg, _, _ = dense_setup
    c = _cache(cfg)
    c.ensure_capacity(0, 32)
    la, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    k = jnp.asarray(np.random.default_rng(0).standard_normal(
        (la, 2, 16, hkv, hd)), jnp.float32)
    c.write_pages(0, 0, k, k + 1)
    ids = c.block_tables[0, :2]
    np.testing.assert_allclose(np.asarray(c.k_pool[:, ids]), np.asarray(k))
    np.testing.assert_allclose(np.asarray(c.v_pool[:, ids]),
                               np.asarray(k + 1))


def test_supports_paged_decode_families():
    assert supports_paged_decode(get_config("internlm2-1.8b"))
    assert supports_paged_decode(get_config("skymemory-tinyllama"))
    assert not supports_paged_decode(get_config("mamba2-1.3b"))
    assert not supports_paged_decode(get_config("zamba2-1.2b"))
    assert not supports_paged_decode(get_config("deepseek-v3-671b"))
    assert not supports_paged_decode(get_config("seamless-m4t-large-v2"))


# ---------------------------------------------------------------------------
# Vectorized sampler
# ---------------------------------------------------------------------------

def test_sample_batch_greedy_rows_are_argmax():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    t, k, p = stack_sampling([SamplingParams()] * 4)
    out = sample_batch(logits, jax.random.PRNGKey(0), t, k, p)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_batch_heterogeneous_params():
    """One call serves a mixed batch: greedy rows are exact argmax; top-k=1
    rows are argmax even at high temperature; top-p ~ 0 rows too."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((3, 128)) * 3, jnp.float32)
    params = [
        SamplingParams(temperature=0.0),
        SamplingParams(temperature=5.0, top_k=1),
        SamplingParams(temperature=5.0, top_p=1e-6),
    ]
    t, k, p = stack_sampling(params)
    out = np.asarray(sample_batch(logits, jax.random.PRNGKey(3), t, k, p))
    np.testing.assert_array_equal(out, np.asarray(jnp.argmax(logits, -1)))


def test_sample_batch_topk_stays_in_support():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.standard_normal((2, 256)), jnp.float32)
    params = [SamplingParams(temperature=1.0, top_k=5)] * 2
    t, k, p = stack_sampling(params)
    topk_sets = [set(np.argsort(np.asarray(logits[i]))[-5:]) for i in range(2)]
    for seed in range(20):
        out = np.asarray(
            sample_batch(logits, jax.random.PRNGKey(seed), t, k, p))
        assert out[0] in topk_sets[0] and out[1] in topk_sets[1]


def test_sample_wrapper_matches_batch_semantics():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.standard_normal((2, 32)), jnp.float32)
    sp = SamplingParams(temperature=0.0)
    np.testing.assert_array_equal(
        np.asarray(sample(logits, jax.random.PRNGKey(0), sp)),
        np.asarray(jnp.argmax(logits, -1)))


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_continuous_batching_admits_mid_decode(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2)
    reqs = [Request(prompt=f"{PROMPT} {i}",
                    sampling=SamplingParams(max_new_tokens=3 + 2 * i))
            for i in range(5)]
    res = eng.generate(reqs)
    assert len(res) == 5
    assert [r.request_id for r in res] == [q.request_id for q in reqs]
    for i, r in enumerate(res):
        assert 1 <= len(r.token_ids) <= 3 + 2 * i
        assert r.ttft_s >= 0.0 and r.finish_reason
    # more requests than slots forces mid-decode admissions
    assert eng.stats.mid_decode_admissions > 0
    assert eng.stats.requests == 5
    # all pages returned to the pool after the loop drains
    assert eng.cache.free_pages == eng.cache.num_pages


def test_paged_engine_matches_dense_decode_loop(dense_setup):
    """Greedy generations from the paged continuous-batching runtime match
    a dense (pre-paged) decode loop over model.decode_step."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2)
    assert eng.paged
    max_new = 6
    prompts = [f"{PROMPT} alpha", f"{PROMPT} beta"]
    res = eng.generate(
        [Request(prompt=p, sampling=SamplingParams(max_new_tokens=max_new))
         for p in prompts])

    # dense reference loop (the seed engine's hot path)
    from repro.serving.tokenizer import ByteTokenizer
    tok = ByteTokenizer(cfg.vocab_size)
    decode = jax.jit(model.decode_step)
    for p, r in zip(prompts, res):
        ids = tok.encode(p)[: 256 - 64]
        lg, _, st = model.forward(
            params, jnp.asarray(ids, jnp.int32)[None], collect_state=True)
        cache = model.init_cache(1, 256)
        n = len(ids)
        cache["kv"]["k"] = cache["kv"]["k"].at[:, 0, :n].set(
            st["kv"]["k"][:, 0, :n])
        cache["kv"]["v"] = cache["kv"]["v"].at[:, 0, :n].set(
            st["kv"]["v"][:, 0, :n])
        logits = lg[0, -1][None]
        pos = jnp.asarray([n], jnp.int32)
        want = []
        for _ in range(max_new):
            tid = int(jnp.argmax(logits[0]))
            want.append(tid)
            if tid == tok.eos_id:
                break
            lg2, cache = decode(params, cache,
                                jnp.asarray([[tid]], jnp.int32), pos)
            logits = lg2[:, 0]
            pos = pos + 1
        assert r.token_ids == want


def test_paged_engine_prefix_blocks_drop_into_pages(dense_setup):
    """SkyMemory hit path: fetched blocks land in pool pages and greedy
    output is unchanged vs the cache-less engine."""
    cfg, model, params = dense_setup
    spec = ConstellationSpec(15, 15, 550.0)
    kvc = ConstellationKVC(spec, LosWindow(Sat(7, 7), 9, 9),
                           Strategy.ROTATION_HOP, num_servers=10,
                           chunk_bytes=6 * 1024)
    eng_c = Engine(model, params, kvc=kvc, block_size=16, max_seq_len=256,
                   max_batch=2)
    eng_n = Engine(model, params, max_seq_len=256, max_batch=2)
    sp = SamplingParams(max_new_tokens=6)
    eng_c.generate([Request(prompt=PROMPT, sampling=sp)])
    rc = eng_c.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    rn = eng_n.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert rc.cached_tokens > 0 and rc.cached_tokens % 16 == 0  # page-aligned
    assert rc.token_ids == rn.token_ids


def test_contiguous_and_free_list_engines_agree(dense_setup):
    """The zero-gather slot-region layout and the general block-table
    layout are the same cache semantics: identical greedy generations."""
    cfg, model, params = dense_setup
    sp = SamplingParams(max_new_tokens=5)
    eng_c = Engine(model, params, block_size=16, max_seq_len=256,
                   max_batch=2)
    eng_f = Engine(model, params, block_size=16, max_seq_len=256,
                   max_batch=2, num_pages=1 + 2 * 16)
    assert eng_c.cache.contiguous and not eng_f.cache.contiguous
    reqs = [Request(prompt=f"{PROMPT} {i}", sampling=sp) for i in range(3)]
    rc = eng_c.generate(reqs)
    rf = eng_f.generate([Request(prompt=f"{PROMPT} {i}", sampling=sp)
                         for i in range(3)])
    assert [r.token_ids for r in rc] == [r.token_ids for r in rf]


def test_same_wave_duplicate_contexts_hit_cache(dense_setup):
    """Regression: requests submitted together must still benefit from
    write-back of earlier wave members (Set KVC happens per sequence
    before the next lookup, as in the sequential admission path)."""
    cfg, model, params = dense_setup
    kvc = ConstellationKVC(ConstellationSpec(15, 15, 550.0),
                           LosWindow(Sat(7, 7), 9, 9),
                           Strategy.ROTATION_HOP, num_servers=10,
                           chunk_bytes=6 * 1024)
    eng = Engine(model, params, kvc=kvc, block_size=16, max_seq_len=256,
                 max_batch=4)
    sp = SamplingParams(max_new_tokens=2)
    res = eng.generate([Request(prompt=PROMPT, sampling=sp)
                        for _ in range(3)])
    assert res[0].cached_tokens == 0
    assert res[1].cached_tokens > 0 and res[2].cached_tokens > 0


def test_free_list_wave_does_not_over_admit(dense_setup):
    """Regression: a multi-request admission wave on an oversubscribed
    free-list pool must reserve pages as it admits -- never exhaust the
    pool mid-serve."""
    cfg, model, params = dense_setup
    # pages for ~1.5 worst-case sequences, 4 slots, 4 concurrent requests
    eng = Engine(model, params, block_size=16, max_seq_len=256,
                 max_batch=4, num_pages=1 + 24)
    sp = SamplingParams(max_new_tokens=30)
    res = eng.generate([Request(prompt="wave pressure " * 12, sampling=sp)
                        for _ in range(4)])
    assert [len(r.token_ids) for r in res] == [30] * 4
    assert eng.cache.free_pages == eng.cache.num_pages - 1


def test_paged_engine_int8_kvc_pool():
    """Quantized KVC (paper's 8-bit memory trade-off) rides the page pool:
    writes quantize, reads dequantize, generation still runs."""
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(
        dtype="float32", kvc_dtype="int8")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, block_size=16, max_seq_len=128, max_batch=2)
    assert eng.cache.k_pool.dtype == jnp.int8
    res = eng.generate([Request(prompt=PROMPT,
                                sampling=SamplingParams(max_new_tokens=4))])
    assert 1 <= len(res[0].token_ids) <= 4


def test_payload_to_pages_matches_dense_state(dense_setup):
    cfg, model, params = dense_setup
    from repro.serving.skycache import SkyKVCAdapter
    adapter = SkyKVCAdapter(model, params)
    tokens = list(range(3, 35))
    payload = adapter.kvc_fn(tokens, None, 0)
    k_blocks, v_blocks = adapter.payload_to_pages(payload, 32, 16)
    state = adapter.payload_to_state(payload)
    la, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    np.testing.assert_allclose(
        np.asarray(k_blocks.reshape(la, 32, hkv, hd)),
        np.asarray(state["kv"]["k"][:, 0, :32]))
    np.testing.assert_allclose(
        np.asarray(v_blocks.reshape(la, 32, hkv, hd)),
        np.asarray(state["kv"]["v"][:, 0, :32]))
