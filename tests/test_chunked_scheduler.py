"""Chunked-prefill scheduler: invariants, equivalence with stop-the-world
admission, SkyMemory paged prefix reads, and the fetch-ahead hook.

Property tests (hypothesis; skip cleanly under the conftest fallback
stub) pin the pure planner invariants -- budget respected, page-aligned
splits, exact coverage; engine-level tests then check the same invariants
on real runs plus token-for-token equivalence with the pre-chunked
baseline (``chunk_tokens=0``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, smoke_config
from repro.core import ConstellationKVC, ConstellationSpec, LosWindow, Sat, Strategy
from repro.models.model import Model
from repro.serving import Engine, Request, SamplingParams, SeqState
from repro.serving.engine import chunk_spans

PROMPT = "SkyMemory stripes KV cache chunks across LEO satellites. " * 3


@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_kvc():
    return ConstellationKVC(
        ConstellationSpec(15, 15, 550.0), LosWindow(Sat(7, 7), 9, 9),
        Strategy.ROTATION_HOP, num_servers=10, chunk_bytes=6 * 1024,
    )


# ---------------------------------------------------------------------------
# planner invariants (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(
    n_pages=st.integers(1, 64),
    cached_pages=st.integers(0, 63),
    budget_pages=st.integers(1, 8),
    page=st.sampled_from([16, 64, 128]),
    ragged=st.integers(0, 127),
)
def test_chunk_spans_cover_budget_and_alignment(n_pages, cached_pages,
                                                budget_pages, page, ragged):
    """Spans partition [start, n) in order; each is <= budget; every
    split lands on a page boundary (only the final span may be ragged)."""
    n = n_pages * page - (ragged % page)
    start = min(cached_pages * page, (n // page) * page)
    budget = budget_pages * page
    spans = chunk_spans(n, start, budget)
    assert sum(v for _, v in spans) == n - start
    cursor = start
    for i, (s, v) in enumerate(spans):
        assert s == cursor and 1 <= v <= budget
        assert s % page == 0
        if i < len(spans) - 1:
            assert v == budget          # only the last span may be ragged
        cursor += v
    assert cursor == n


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 4096), start=st.integers(0, 4095),
       budget=st.integers(1, 512))
def test_chunk_spans_cover_any_offsets(n, start, budget):
    """Even unaligned starts (the whole-prompt-cached replay) are covered
    exactly, with no span past the prompt end."""
    start = min(start, n - 1)
    spans = chunk_spans(n, start, budget)
    assert spans[0][0] == start
    assert sum(v for _, v in spans) == n - start
    assert all(v <= budget for _, v in spans)
    end, _ = spans[-1]
    assert end + spans[-1][1] == n


def test_chunk_buf_is_bounded_and_sufficient(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2,
                 chunk_tokens=64)
    for v in (1, 2, 31, 32, 33, 63, 64):
        b = eng._chunk_buf(v)
        assert v <= b <= 64


# ---------------------------------------------------------------------------
# engine-level invariants
# ---------------------------------------------------------------------------

def test_chunked_matches_stop_the_world(dense_setup):
    """Token-for-token greedy equivalence between the chunked scheduler
    (several budgets, incl. a non-power-of-two page multiple) and
    stop-the-world admission, across cold waves AND mid-decode chunks."""
    cfg, model, params = dense_setup
    sp = SamplingParams(max_new_tokens=6)
    reqs = lambda: [Request(prompt=f"{PROMPT} {i}", sampling=sp)
                    for i in range(5)]
    ref_eng = Engine(model, params, block_size=16, max_seq_len=256,
                     max_batch=2, chunk_tokens=0)
    assert not ref_eng.chunked
    want = [r.token_ids for r in ref_eng.generate(reqs())]
    for ct in (16, 48, 64):
        eng = Engine(model, params, block_size=16, max_seq_len=256,
                     max_batch=2, chunk_tokens=ct)
        assert eng.chunked
        got = [r.token_ids for r in eng.generate(reqs())]
        assert got == want
        assert eng.stats.prefill_chunks > 0


def test_chunk_log_budget_alignment_coverage(dense_setup):
    """Real runs respect the planner invariants: every chunk <= budget,
    every fresh chunk page-aligned, and each admission's spans cover its
    prompt contiguously."""
    cfg, model, params = dense_setup
    budget, page = 32, 16
    eng = Engine(model, params, block_size=page, max_seq_len=256,
                 max_batch=2, chunk_tokens=budget)
    sp = SamplingParams(max_new_tokens=4)
    res = eng.generate([Request(prompt=f"{PROMPT} {i}", sampling=sp)
                        for i in range(4)])
    assert len(eng.chunk_log) > 0
    per_slot: dict[int, list[list[tuple[int, int]]]] = {}
    for slot, start, v in eng.chunk_log:
        assert 1 <= v <= budget
        assert start % page == 0            # no SkyMemory manager: all fresh
        runs = per_slot.setdefault(slot, [])
        if start == 0:                      # a new admission on this slot
            runs.append([])
        runs[-1].append((start, v))
    prompt_lens = {r.prompt_tokens for r in res}
    for runs in per_slot.values():
        for spans in runs:
            cursor = 0
            for start, v in spans:
                assert start == cursor      # contiguous, in order
                cursor += v
            assert cursor in prompt_lens    # covered exactly one prompt


def test_no_decode_starvation_during_admission(dense_setup):
    """While a long prompt admits mid-decode, the running sequence keeps
    producing a token every step: the admission-window ITL sample count
    proves tokens were decoded during every chunk-riding step."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2,
                 chunk_tokens=16)
    reqs = [
        Request(prompt=f"{PROMPT} runner",
                sampling=SamplingParams(max_new_tokens=24)),
        Request(prompt="short", sampling=SamplingParams(max_new_tokens=2)),
        Request(prompt=PROMPT * 2,       # long prompt, admitted mid-decode
                sampling=SamplingParams(max_new_tokens=4)),
    ]
    res = eng.generate(reqs)
    assert eng.stats.mid_decode_admissions > 0
    # the long prompt's chunks are the entries after the LAST start==0
    # (the first two prompts prefilled together in the cold wave)
    last_admission = max(i for i, c in enumerate(eng.chunk_log)
                         if c[1] == 0)
    n_long_chunks = len(eng.chunk_log) - last_admission
    assert n_long_chunks >= 5, "long prompt should take several chunks"
    # every one of those chunk steps also decoded the running sequence:
    # one admission-window ITL sample per runner per chunk-riding step
    assert len(eng.stats.itl_admission_s) >= n_long_chunks
    assert len(res[0].token_ids) == 24


def test_mid_decode_admission_does_not_change_running_output(dense_setup):
    """A long admission riding the decode steps must not perturb the
    running sequence's greedy output (KV pages fully isolated)."""
    cfg, model, params = dense_setup
    sp_run = SamplingParams(max_new_tokens=16)
    alone = Engine(model, params, block_size=16, max_seq_len=256,
                   max_batch=2)
    want = alone.generate(
        [Request(prompt=f"{PROMPT} runner", sampling=sp_run)])[0].token_ids
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2)
    res = eng.generate([
        Request(prompt=f"{PROMPT} runner", sampling=sp_run),
        Request(prompt="tiny", sampling=SamplingParams(max_new_tokens=1)),
        Request(prompt=PROMPT * 2, sampling=SamplingParams(max_new_tokens=2)),
    ])
    assert eng.stats.mid_decode_admissions > 0
    assert res[0].token_ids == want


def test_whole_prompt_cached_replays_one_token(dense_setup):
    """A whole-prompt SkyMemory hit keeps every restored block and
    recomputes exactly ONE token through the paged chunk path -- not a
    full page through a dense prefill."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=2)
    prompt = "x" * 63                     # + bos = 64 tokens = 4 blocks
    sp = SamplingParams(max_new_tokens=6)
    eng.generate([Request(prompt=prompt, sampling=sp)])
    eng.chunk_log = []
    rc = eng.generate([Request(prompt=prompt, sampling=sp)])[0]
    assert rc.prompt_tokens == 64
    assert rc.cached_tokens == 63 and rc.prefill_tokens == 1
    assert eng.chunk_log == [(0, 63, 1)]  # the only chunk: 1-token replay
    rn = Engine(model, params, max_seq_len=256, max_batch=2).generate(
        [Request(prompt=prompt, sampling=sp)])[0]
    assert rc.token_ids == rn.token_ids


def test_partial_prefix_hit_chunks_only_suffix(dense_setup):
    """A partial hit restores its blocks into pages and chunks only the
    uncached suffix, starting exactly at the cached boundary."""
    cfg, model, params = dense_setup
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=2, chunk_tokens=32)
    sp = SamplingParams(max_new_tokens=4)
    eng.generate([Request(prompt=PROMPT, sampling=sp)])
    eng.chunk_log = []
    r = eng.generate([Request(prompt=PROMPT + " more text afterwards",
                              sampling=sp)])[0]
    assert 0 < r.cached_tokens < r.prompt_tokens
    assert r.cached_tokens % 16 == 0
    starts = [c[1] for c in eng.chunk_log]
    assert starts[0] == r.cached_tokens   # suffix starts at the boundary
    assert sum(c[2] for c in eng.chunk_log) == r.prefill_tokens


def test_chunked_free_list_pool_matches_contiguous(dense_setup):
    """The chunk path resolves pages through block tables identically in
    slot-region and free-list pools."""
    cfg, model, params = dense_setup
    sp = SamplingParams(max_new_tokens=5)
    reqs = lambda: [Request(prompt=f"{PROMPT} {i}", sampling=sp)
                    for i in range(3)]
    eng_c = Engine(model, params, block_size=16, max_seq_len=256,
                   max_batch=2, chunk_tokens=32)
    eng_f = Engine(model, params, block_size=16, max_seq_len=256,
                   max_batch=2, chunk_tokens=32, num_pages=1 + 2 * 16)
    assert eng_c.cache.contiguous and not eng_f.cache.contiguous
    rc = [r.token_ids for r in eng_c.generate(reqs())]
    rf = [r.token_ids for r in eng_f.generate(reqs())]
    assert rc == rf


def test_moe_families_fall_back_to_stop_the_world(dense_setup):
    """Chunk splits would change capacity-based expert routing, so MoE
    engines disable chunking regardless of the requested budget."""
    cfg = smoke_config(get_config("granite-moe-3b-a800m")).replace(
        dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2,
                 chunk_tokens=32)
    assert not eng.chunked
    res = eng.generate([Request(prompt=PROMPT,
                                sampling=SamplingParams(max_new_tokens=3))])
    assert 1 <= len(res[0].token_ids) <= 3


def test_fetch_ahead_hook_matches_sync_decode(dense_setup):
    """pages_async (worker-thread payload decode) returns the exact pages
    payload_to_pages produces synchronously."""
    cfg, model, params = dense_setup
    from repro.serving.skycache import SkyKVCAdapter
    adapter = SkyKVCAdapter(model, params)
    tokens = list(range(3, 35))
    payload = adapter.kvc_fn(tokens, None, 0)
    want_k, want_v = adapter.payload_to_pages(payload, 32, 16)
    got_k, got_v = adapter.pages_async(payload, 32, 16).result()
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_engine_stats_latency_percentiles(dense_setup):
    cfg, model, params = dense_setup
    eng = Engine(model, params, block_size=16, max_seq_len=256, max_batch=2)
    eng.generate([Request(prompt=f"{PROMPT} {i}",
                          sampling=SamplingParams(max_new_tokens=5))
                  for i in range(3)])
    assert len(eng.stats.ttft_s) == 3
    assert len(eng.stats.itl_s) > 0
    pct = eng.stats.latency_percentiles()
    for key in ("ttft_s", "itl_s", "itl_admission_s"):
        assert set(pct[key]) == {"p50", "p95", "p99"}
        assert pct[key]["p50"] <= pct[key]["p99"]


def test_prefilling_state_visible_in_lifecycle():
    assert SeqState.PREFILLING.value == "prefilling"
