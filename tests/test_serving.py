"""Serving engine + SkyMemory integration tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.core import ConstellationKVC, ConstellationSpec, LosWindow, Sat, Strategy
from repro.models.model import Model
from repro.serving import ByteTokenizer, Engine, Request, SamplingParams


def make_kvc(chunk_bytes=6 * 1024):
    spec = ConstellationSpec(15, 15, 550.0)
    return ConstellationKVC(
        spec, LosWindow(Sat(7, 7), 9, 9), Strategy.ROTATION_HOP,
        num_servers=10, chunk_bytes=chunk_bytes,
    )


def make_engine(arch="internlm2-1.8b", *, kvc=None, block_size=16, seed=0):
    cfg = smoke_config(get_config(arch)).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return Engine(model, params, kvc=kvc, block_size=block_size,
                  max_seq_len=256, max_batch=4), params, model


PROMPT = "SkyMemory stripes KV cache chunks across LEO satellites. " * 3


def test_tokenizer_roundtrip():
    tk = ByteTokenizer(512)
    ids = tk.encode("hello world")
    assert ids[0] == 1  # bos
    assert tk.decode(ids) == "hello world"


def test_engine_generates_batched():
    eng, _, _ = make_engine()
    reqs = [Request(prompt=f"{PROMPT} {i}",
                    sampling=SamplingParams(max_new_tokens=6))
            for i in range(3)]
    res = eng.generate(reqs)
    assert len(res) == 3
    for r in res:
        assert 1 <= len(r.token_ids) <= 6
        assert r.prompt_tokens > 0


def test_prefix_cache_hits_and_skip_prefill():
    kvc = make_kvc()
    eng, _, _ = make_engine(kvc=kvc)
    r1 = eng.generate([Request(prompt=PROMPT,
                               sampling=SamplingParams(max_new_tokens=4))])[0]
    assert r1.cached_tokens == 0
    r2 = eng.generate([Request(prompt=PROMPT + " more text afterwards",
                               sampling=SamplingParams(max_new_tokens=4))])[0]
    assert r2.cached_tokens > 0
    assert r2.prefill_tokens < r2.prompt_tokens
    assert kvc.stats.block_hits > 0


def test_greedy_identical_with_and_without_cache():
    """The paper's §5 validation: generations must be unchanged by the
    cache; only latency changes."""
    kvc = make_kvc()
    eng_c, params, model = make_engine(kvc=kvc)
    eng_n = Engine(model, params, kvc=None, max_seq_len=256)
    sp = SamplingParams(max_new_tokens=8)
    # warm the cache, then re-request
    eng_c.generate([Request(prompt=PROMPT, sampling=sp)])
    rc = eng_c.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert rc.cached_tokens > 0
    rn = eng_n.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert rc.token_ids == rn.token_ids


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b",
                                  "deepseek-v3-671b"])
def test_cache_applies_to_nondense_families(arch):
    """SSM snapshots / MLA latents ride the same protocol (DESIGN.md §4)."""
    kvc = make_kvc()
    eng, _, _ = make_engine(arch, kvc=kvc)
    sp = SamplingParams(max_new_tokens=4)
    eng.generate([Request(prompt=PROMPT, sampling=sp)])
    r = eng.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert r.cached_tokens > 0
    assert kvc.stats.block_hits > 0


def test_rotation_migration_preserves_serving_hits():
    kvc = make_kvc()
    eng, _, _ = make_engine(kvc=kvc)
    sp = SamplingParams(max_new_tokens=4)
    eng.generate([Request(prompt=PROMPT, sampling=sp)])
    kvc.rotate(steps=3)  # satellites drift; chunks migrate
    r = eng.generate([Request(prompt=PROMPT, sampling=sp)])[0]
    assert r.cached_tokens > 0


def test_sampling_params_topk_topp():
    eng, _, _ = make_engine()
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.9,
                        max_new_tokens=5)
    res = eng.generate([Request(prompt="abc def", sampling=sp)])[0]
    assert 1 <= len(res.token_ids) <= 5


def test_truncated_prompt_cache_consistency():
    """Regression: prompts longer than the engine's max_seq_len must still
    produce identical greedy outputs with a warm cache (the manager must
    look up the engine's *truncated* token sequence, or the restored prefix
    overshoots the mask/rope offsets)."""
    kvc = make_kvc()
    eng_c, params, model = make_engine(kvc=kvc)
    eng_n = Engine(model, params, kvc=None, max_seq_len=256)
    long_prompt = PROMPT * 8  # well beyond max_seq_len tokens
    sp = SamplingParams(max_new_tokens=8)
    eng_c.generate([Request(prompt=long_prompt, sampling=sp)])
    rc = eng_c.generate([Request(prompt=long_prompt, sampling=sp)])[0]
    rn = eng_n.generate([Request(prompt=long_prompt, sampling=sp)])[0]
    assert rc.cached_tokens > 0
    assert rc.cached_tokens < rc.prompt_tokens + 1
    assert rc.token_ids == rn.token_ids
