"""Streaming tier: traffic generator determinism, SLO/goodput
arithmetic, admission control, bounded stat reservoirs, per-request vs
end-of-run router release, worker-loop lifecycle, and deterministic
replay of an open arrival stream through a real cluster.

The contract under test:

* every arrival process (poisson / diurnal / bursty) is a pure function
  of ``(seed, tenant spec)``: same seed => byte-identical streams,
  different seeds differ, and the merged stream is time-ordered;
* SLO attainment is per-request (TTFT AND the request's own ITL p95),
  goodput counts only attained tokens, and the admission controller
  never sheds protected priorities;
* ``SampleReservoir`` is exact below its cap (existing percentile tests
  keep their meaning) and bounded above it;
* per-request release returns each request's committed tokens the moment
  it finishes, while end-of-run release holds them -- so mid-stream load
  differs and the post-run state agrees;
* engine worker loops drain cleanly on ``stop()`` with requests still in
  flight, and ``serve_stream(parallel=False)`` replays byte-identically.
* the deterministic pump budget accumulates fractionally across arrival
  gaps (service rate is a function of elapsed virtual time, not arrival
  granularity), the realtime rotation ticker holds its period under a
  slow rotate (deadline scheduling), and a seeded chaos arc driven
  through ``serve_stream`` replays byte-identically with its windowed
  goodput timeline tagged by fault phase.
"""
import threading
import time
import types

import jax
import pytest

from repro.configs import get_config, smoke_config
from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    FaultInjector,
    FaultPlan,
    IslTransport,
    LosWindow,
    Sat,
    SimClock,
    Strategy,
)
from repro.models.model import Model
from repro.serving import (
    SLO,
    AdmissionController,
    Arrival,
    Engine,
    EngineCluster,
    EngineStats,
    FaultPhases,
    Request,
    SampleReservoir,
    SamplingParams,
    SLOTracker,
    TenantSpec,
    TrafficGenerator,
    itl_tail,
    standard_tenants,
)

SPEC = ConstellationSpec(15, 15, 550.0)


def make_kvc(clock=None, **kw):
    transport = IslTransport(SPEC, clock=clock,
                             chunk_processing_time_s=1e-4)
    return ConstellationKVC(
        SPEC, LosWindow(Sat(7, 7), 9, 9), Strategy.ROTATION_HOP,
        num_servers=10, chunk_bytes=1024, transport=transport, **kw,
    )


# ---------------------------------------------------------------------------
# traffic generator: seeded determinism
# ---------------------------------------------------------------------------

def _one_tenant(process, **kw):
    return TenantSpec(name=f"t-{process}", rate_rps=20.0,
                      process=process, **kw)


def _fingerprint(arrivals):
    return [(a.t_s, a.tenant, a.request.prompt, a.request.priority,
             a.request.sampling.max_new_tokens)
            for a in arrivals]


@pytest.mark.parametrize("process", ["poisson", "diurnal", "bursty"])
def test_arrival_process_deterministic_per_seed(process):
    spec = _one_tenant(process)
    a = TrafficGenerator([spec], seed=3).take(40)
    b = TrafficGenerator([spec], seed=3).take(40)
    c = TrafficGenerator([spec], seed=4).take(40)
    assert _fingerprint(a) == _fingerprint(b)          # same seed: identical
    assert _fingerprint(a) != _fingerprint(c)          # different seed: not
    ts = [x.t_s for x in a]
    assert ts == sorted(ts)                            # monotone times
    assert all(x.t_s >= 0.0 for x in a)
    assert len({x.request.request_id for x in a}) == 40


def test_diurnal_rate_actually_modulates():
    """Thinning must keep arrivals denser near the peak of the cycle
    than in the trough (statistically, with a fixed seed)."""
    spec = _one_tenant("diurnal", diurnal_period_s=8.0,
                       diurnal_amplitude=0.9)
    arrivals = TrafficGenerator([spec], seed=0).until(64.0)
    phase = [(a.t_s % 8.0) / 8.0 for a in arrivals]
    near_peak = sum(1 for p in phase if p < 0.5)       # sin peaks at 0.25
    near_trough = len(phase) - near_peak
    assert near_peak > near_trough * 1.5


def test_bursty_clusters_arrivals():
    spec = _one_tenant("bursty", burst_size=5, burst_spread_s=0.01)
    arrivals = TrafficGenerator([spec], seed=1).take(60)
    gaps = [b.t_s - a.t_s for a, b in zip(arrivals, arrivals[1:])]
    tight = sum(1 for g in gaps if g < 0.02)
    assert tight > len(gaps) // 2                      # mostly intra-burst


def test_merged_multi_tenant_stream_ordered_and_deterministic():
    tenants = standard_tenants(3, 30.0, max_new_tokens=4)
    a = TrafficGenerator(tenants, seed=9).until(2.0)
    b = TrafficGenerator(tenants, seed=9).until(2.0)
    assert _fingerprint(a) == _fingerprint(b)
    ts = [x.t_s for x in a]
    assert ts == sorted(ts)
    assert {x.tenant for x in a} == {t.name for t in tenants}
    # the protected tenant carries its priority into the Request
    assert all(x.request.priority == 1 for x in a if x.tenant == "pro")
    assert all(x.request.tenant == x.tenant for x in a)


def test_prefix_reuse_duplicates_document_prefixes():
    spec = _one_tenant("poisson", prefix_reuse_p=1.0, num_documents=2)
    arrivals = TrafficGenerator([spec], seed=5).take(20)
    prefixes = {a.request.prompt[:40] for a in arrivals}
    assert len(prefixes) <= 2                          # shared documents


# ---------------------------------------------------------------------------
# SLO accounting + admission control
# ---------------------------------------------------------------------------

def test_itl_tail_is_per_request_percentile():
    assert itl_tail([]) == 0.0
    assert itl_tail([0.01] * 19 + [1.0]) < 1.0         # p95 clips one spike
    assert itl_tail([0.01] * 19 + [1.0], q=100.0) == pytest.approx(1.0)


def test_slo_tracker_attainment_and_goodput():
    tracker = SLOTracker({"pro": SLO(ttft_s=0.1, itl_p95_s=0.05)},
                         default=SLO(ttft_s=1.0))
    for _ in range(3):
        tracker.note_offered("pro")
    tracker.note_offered("free")
    tracker.note_shed("free")
    ok = tracker.observe("pro", ttft_s=0.05,
                         itl_samples_s=[0.01, 0.02], new_tokens=10)
    late = tracker.observe("pro", ttft_s=0.5,           # TTFT blown
                           itl_samples_s=[0.01], new_tokens=10)
    jitter = tracker.observe("pro", ttft_s=0.05,        # ITL tail blown
                             itl_samples_s=[0.2] * 4, new_tokens=10)
    assert ok and not late and not jitter
    rep = tracker.report(elapsed_s=2.0)
    assert rep["offered"] == 4 and rep["shed"] == 1
    assert rep["completed"] == 3 and rep["attained"] == 1
    assert rep["attainment"] == pytest.approx(1 / 3)
    assert rep["tokens_per_s"] == pytest.approx(15.0)
    assert rep["goodput_tokens_per_s"] == pytest.approx(5.0)
    assert rep["per_tenant"]["pro"]["attained_tokens"] == 10
    assert rep["per_tenant"]["free"]["shed"] == 1


def test_admission_controller_protects_priority():
    adm = AdmissionController(capacity_tokens=100, protect_priority=1)
    assert adm.admit(0, load_tokens=50)                # under capacity
    assert not adm.admit(0, load_tokens=150)           # overload: shed
    assert adm.admit(1, load_tokens=150)               # protected: never
    assert adm.admit(2, load_tokens=10**9)
    assert adm.shed_count == 1


# ---------------------------------------------------------------------------
# bounded engine-stat samples
# ---------------------------------------------------------------------------

def test_sample_reservoir_exact_below_cap_bounded_above():
    r = SampleReservoir(cap=16)
    r.extend(float(i) for i in range(10))
    assert list(r) == [float(i) for i in range(10)]    # exact, in order
    r.extend(float(i) for i in range(10, 5000))
    assert len(r) == 16                                # bounded forever
    assert r.n_seen == 5000
    assert all(0.0 <= x < 5000.0 for x in r)
    # seeded: two reservoirs fed identically agree
    r2 = SampleReservoir(cap=16)
    r2.extend(float(i) for i in range(5000))
    assert list(r) == list(r2)


def test_engine_stats_samples_are_bounded():
    st = EngineStats(ttft_s=[0.1, 0.2])                # plain-list kwargs
    assert isinstance(st.ttft_s, SampleReservoir)
    assert st.ttft_s == [0.1, 0.2]                     # exact while short
    for i in range(20000):
        st.itl_s.append(i * 1e-6)
    assert len(st.itl_s) <= 8192
    merged = EngineStats.merged([st, EngineStats(itl_s=[1.0])])
    assert len(merged.itl_s) <= 8192
    assert 0.0 < merged.latency_percentiles()["itl_s"]["p99"] <= 1.0


# ---------------------------------------------------------------------------
# streaming through a real tiny cluster
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cluster(model, params, **kw):
    kw.setdefault("num_replicas", 2)
    return EngineCluster(
        model, params, make_kvc(), policy="prefix_affinity",
        block_size=16, max_seq_len=256, max_batch=4, **kw,
    )


def _arrivals(n=6, max_new=4, rate=50.0):
    tenants = standard_tenants(2, rate, max_new_tokens=max_new,
                               prompt_chars=(24, 48))
    return TrafficGenerator(tenants, seed=11).take(n)


def test_worker_loop_drains_in_flight_requests(dense_setup):
    """stop(drain=True) with requests still queued finishes every one:
    all futures resolve, nothing is cancelled, the backlog is empty."""
    _, model, params = dense_setup
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=2)
    eng.start()
    with pytest.raises(RuntimeError):
        eng.generate([Request(prompt="closed batch while streaming",
                              sampling=SamplingParams(max_new_tokens=1))])
    futs = [eng.submit(Request(prompt=f"stream request {i}",
                               sampling=SamplingParams(max_new_tokens=3)))
            for i in range(5)]
    eng.stop(drain=True)                   # requests still in flight
    assert not eng.backlog and not eng.running
    for f in futs:
        res = f.result(timeout=0)          # already resolved
        assert len(res.token_ids) == 3
        assert res.finish_reason == "max_new_tokens"
    assert eng.stats.requests == 5
    # stopped engine accepts closed batches again
    out = eng.generate([Request(prompt="after the stream",
                                sampling=SamplingParams(max_new_tokens=2))])
    assert len(out[0].token_ids) == 2


def test_worker_stop_without_drain_cancels_queued(dense_setup):
    _, model, params = dense_setup
    eng = Engine(model, params, kvc=make_kvc(), block_size=16,
                 max_seq_len=256, max_batch=2)
    # no worker running: queued seqs sit in the inbox until stop()
    futs = [eng.submit(Request(prompt=f"doomed {i}",
                               sampling=SamplingParams(max_new_tokens=4)))
            for i in range(3)]
    eng.stop(drain=False)
    assert all(f.cancelled() for f in futs)
    assert not eng.backlog


def test_per_request_release_vs_end_of_run(dense_setup):
    """Per-request release returns committed tokens as each request
    finishes; the end-of-run baseline holds every commitment until the
    stream is over.  Observed at the router: with release=False the load
    survives the futures resolving, with release=True it drains."""
    _, model, params = dense_setup
    cluster = _cluster(model, params)
    req = Request(prompt="hold my committed tokens",
                  sampling=SamplingParams(max_new_tokens=2))

    fut, d = cluster.submit(req, release=False)
    cluster.start_workers()
    cluster.stop_workers(drain=True)
    assert fut.result(timeout=0) is not None
    assert cluster.router.total_load() == d.committed_tokens  # still held
    cluster.router.release(d.replica, d.committed_tokens)
    assert cluster.router.total_load() == 0

    fut2, d2 = cluster.submit(req, release=True)
    assert cluster.router.total_load() == d2.committed_tokens
    cluster.start_workers()
    cluster.stop_workers(drain=True)
    assert fut2.result(timeout=0) is not None
    assert cluster.router.total_load() == 0            # released per request


def test_serve_stream_realtime_with_admission(dense_setup):
    _, model, params = dense_setup
    cluster = _cluster(model, params)
    arrivals = _arrivals(n=6)
    report = cluster.serve_stream(
        arrivals, parallel=True,
        slos={"pro": SLO(ttft_s=60.0)},
        admission=AdmissionController(capacity_tokens=10**9))
    assert len(report.records) == 6
    assert not report.shed()                           # capacity is huge
    assert all(len(r.token_ids) > 0 for r in report.results())
    assert report.slo["completed"] == 6
    assert report.slo["tokens_per_s"] > 0.0
    assert cluster.router.total_load() == 0            # all released
    assert cluster.merged_stats().requests == 6


def test_serve_stream_sheds_low_priority_only(dense_setup):
    """With zero capacity every unprotected arrival is shed and every
    protected one completes."""
    _, model, params = dense_setup
    cluster = _cluster(model, params, num_replicas=1)
    arrivals = _arrivals(n=8)
    report = cluster.serve_stream(
        arrivals, parallel=False,
        admission=AdmissionController(capacity_tokens=0,
                                      protect_priority=1))
    shed = report.shed()
    assert shed and all(r.arrival.request.priority == 0 for r in shed)
    done = report.results()
    assert done and all(r.tenant == "pro" for r in done)
    assert report.slo["shed"] == len(shed)
    per = report.slo["per_tenant"]
    assert per["pro"]["shed"] == 0


def test_serve_stream_deterministic_replays_byte_identical(dense_setup):
    _, model, params = dense_setup

    def run():
        cluster = _cluster(model, params, rotate_every_s=0.05)
        report = cluster.serve_stream(_arrivals(n=6), parallel=False)
        return ([(r.arrival.tenant, r.shed,
                  r.decision.replica if r.decision else None,
                  tuple(r.result.token_ids) if r.result else None)
                 for r in report.records], report.rotations)

    recs_a, rot_a = run()
    recs_b, rot_b = run()
    assert recs_a == recs_b                            # byte-identical
    assert rot_a == rot_b and rot_a > 0                # rotation replayed
    assert any(t for t, *_ in recs_a)


def test_cluster_serve_aggregates_replica_failures(dense_setup):
    """The closed-batch path reports EVERY failed replica, not just the
    first: the aggregate names each one and chains a cause."""
    _, model, params = dense_setup
    cluster = EngineCluster(
        model, params, make_kvc(), policy="random",
        block_size=16, max_seq_len=256, max_batch=4, num_replicas=2)

    def boom(reqs, **kw):
        raise RuntimeError("replica exploded")

    for e in cluster.engines:
        e.generate = boom
    reqs = [Request(prompt=f"doomed request {i} with its own prefix",
                    sampling=SamplingParams(max_new_tokens=2))
            for i in range(4)]
    with pytest.raises(RuntimeError) as ei:
        cluster.serve(reqs, parallel=True)
    msg = str(ei.value)
    assert "2 replica failures" in msg
    assert "replica 0" in msg and "replica 1" in msg
    assert isinstance(ei.value.__cause__, RuntimeError)


def test_submit_concurrent_from_many_threads(dense_setup):
    """The front door is thread-safe: concurrent submits all route,
    all complete, and the load accounting balances to zero."""
    _, model, params = dense_setup
    cluster = _cluster(model, params)
    cluster.start_workers()
    futs = []
    lock = threading.Lock()

    def feed(i):
        f, _ = cluster.submit(Request(
            prompt=f"concurrent stream {i}",
            sampling=SamplingParams(max_new_tokens=2)))
        with lock:
            futs.append(f)

    threads = [threading.Thread(target=feed, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    cluster.stop_workers(drain=True)
    assert len(futs) == 6
    assert all(len(f.result(timeout=0).token_ids) == 2 for f in futs)
    deadline = time.perf_counter() + 2.0
    while cluster.router.total_load() and time.perf_counter() < deadline:
        time.sleep(0.01)                   # done-callbacks are async
    assert cluster.router.total_load() == 0


def test_arrival_is_frozen_record():
    req = Request(prompt="x", sampling=SamplingParams(max_new_tokens=1))
    a = Arrival(t_s=1.0, tenant="t", request=req)
    with pytest.raises(AttributeError):
        a.t_s = 2.0


def test_tenant_spec_rejects_corrupting_parameters():
    """Parameters that would silently corrupt (amplitude > 1: negative
    instantaneous rate, thinned into a hidden traffic hole) or crash
    deep in a draw (rate <= 0: expovariate) fail at construction."""
    with pytest.raises(ValueError, match="rate_rps"):
        TenantSpec(name="bad", rate_rps=0.0)
    with pytest.raises(ValueError, match="rate_rps"):
        TenantSpec(name="bad", rate_rps=-1.0)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        TenantSpec(name="bad", rate_rps=1.0, diurnal_amplitude=1.5)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        TenantSpec(name="bad", rate_rps=1.0, diurnal_amplitude=-0.1)
    # the closed boundaries stay legal
    TenantSpec(name="ok", rate_rps=1e-6, diurnal_amplitude=1.0)
    TenantSpec(name="ok", rate_rps=1.0, diurnal_amplitude=0.0)


# ---------------------------------------------------------------------------
# deterministic pump budget + rotation ticker timing
# ---------------------------------------------------------------------------

class _TimingProbe:
    """A minimal stand-in for EngineCluster's timing surface: counts
    pump rounds / rotations without engines, so the two timing-bug
    regression tests measure the loop arithmetic itself."""

    def __init__(self, rotate_every_s=None, clock_rate=1.0,
                 rotate_cost_s=0.0):
        self.rotate_every_s = rotate_every_s
        self.clock = types.SimpleNamespace(rate=clock_rate)
        self.rotations = 0
        self.rounds = 0
        self.done = False
        self.manager = types.SimpleNamespace(lock=threading.RLock())

        def rotate(n):
            if rotate_cost_s:
                time.sleep(rotate_cost_s)
        self.kvc = types.SimpleNamespace(rotate=rotate)

    def _pump_all(self):
        if self.done:
            return False
        self.rounds += 1
        return True

    def _settle_write_backs(self):
        pass


def _det_pump_rounds(gaps, pump_steps_per_s=200.0):
    """Pump rounds the deterministic interleave spends across ``gaps``
    (final idle drain excluded via the probe's ``done`` latch)."""
    probe = _TimingProbe()
    req = Request(prompt="x", sampling=SamplingParams(max_new_tokens=1))
    arrs, t = [], 0.0
    for g in gaps:
        t += g
        arrs.append(Arrival(t_s=t, tenant="t", request=req))

    def admit(arr):
        if arr is arrs[-1]:
            probe.done = True

    EngineCluster._serve_stream_deterministic(
        probe, arrs, admit, pump_steps_per_s)
    return probe.rounds


def test_pump_budget_carries_fraction_across_gaps():
    """Regression (pump-budget truncation): N small gaps must buy the
    same total service as one large gap of the same virtual span.  The
    pre-fix code truncated each gap's budget independently -- 100 gaps
    of 4ms at 200 steps/s bought 0 rounds instead of 80."""
    many = _det_pump_rounds([0.004] * 100)   # 0.8 rounds per gap
    one = _det_pump_rounds([0.4])            # same span, one gap
    assert one == 80
    assert abs(many - one) <= 1
    # granularity in between agrees too
    assert abs(_det_pump_rounds([0.016] * 25) - one) <= 1


def test_rotation_ticker_holds_period_with_slow_rotate():
    """Regression (ticker drift): with a rotate that costs 50% of the
    period, deadline scheduling must still land ~elapsed/period
    rotations (the pre-fix sleep-after-work ticker realized a period of
    rotate_every_s/rate + rotate_cost and lost ~1/3 of them), matching
    the deterministic mode's virtual-time crossing count +-1."""
    period = 0.06
    probe = _TimingProbe(rotate_every_s=period, clock_rate=1.0,
                         rotate_cost_s=0.03)
    t0 = time.perf_counter()
    stopper = EngineCluster._start_rotation_ticker(probe)
    time.sleep(10.5 * period)
    elapsed = time.perf_counter() - t0
    stopper()
    realtime = probe.rotations
    assert abs(realtime - elapsed / period) <= 1.0

    # the deterministic mode's crossings over the same virtual span
    det = _TimingProbe(rotate_every_s=period)
    det.done = True                          # no service, just crossings
    req = Request(prompt="x", sampling=SamplingParams(max_new_tokens=1))
    EngineCluster._serve_stream_deterministic(
        det, [Arrival(t_s=elapsed, tenant="t", request=req)],
        lambda arr: None, 0.0)
    assert abs(det.rotations - realtime) <= 1


# ---------------------------------------------------------------------------
# windowed goodput timeline + fault-phase tagging
# ---------------------------------------------------------------------------

def test_slo_tracker_windows_tag_fault_phases():
    """Fixed virtual-time windows keyed by arrival t_s, tagged from the
    churn span: a window is pre_churn only when it ends before the
    first kill, post_heal only when it starts at/after the last heal,
    churn otherwise (boundary-straddlers included)."""
    tracker = SLOTracker(
        window_s=1.0, phases=FaultPhases(churn_start_s=2.0, heal_s=4.0))
    tracker.note_offered("a", t_s=0.5)
    tracker.observe("a", ttft_s=0.0, itl_samples_s=[],
                    new_tokens=5, t_s=0.5)
    tracker.note_offered("a", t_s=2.5)
    tracker.note_shed("a", t_s=2.5)
    tracker.note_offered("b", t_s=4.5)
    tracker.observe("b", ttft_s=0.0, itl_samples_s=[],
                    new_tokens=7, t_s=4.5)
    rows = tracker.timeline()
    assert [r["phase"] for r in rows] == [
        "pre_churn", "pre_churn", "churn", "churn", "post_heal"]
    assert rows[0]["attained_tokens"] == 5
    assert rows[0]["goodput_tokens_per_s"] == pytest.approx(5.0)
    assert rows[1]["offered"] == 0                     # empty window kept
    assert rows[2]["shed"] == 1 and rows[2]["attained_tokens"] == 0
    assert rows[4]["attained_tokens"] == 7
    phases = tracker.phase_report()
    assert phases["pre_churn"]["goodput_tokens_per_s"] == pytest.approx(2.5)
    assert phases["churn"]["shed"] == 1
    assert phases["churn"]["goodput_tokens_per_s"] == pytest.approx(0.0)
    assert phases["post_heal"]["goodput_tokens_per_s"] == pytest.approx(7.0)
    rep = tracker.report(elapsed_s=1.0)
    assert rep["windows"] == rows and rep["phases"] == phases
    # a window straddling the churn boundary is churn, conservatively
    assert FaultPhases(2.5, 4.0).tag(2.0, 3.0) == "churn"
    # no heal ever landing: nothing is post_heal
    assert FaultPhases(1.0).tag(100.0, 101.0) == "churn"
    # per-tenant totals are unaffected by windowing
    assert rep["offered"] == 3 and rep["completed"] == 2
    # and an unwindowed tracker reports no timeline block
    assert "windows" not in SLOTracker().report(1.0)


# ---------------------------------------------------------------------------
# chaos arcs driven through serve_stream (deterministic + realtime)
# ---------------------------------------------------------------------------

def _chaos_cluster(model, params, kvc, **kw):
    kw.setdefault("num_replicas", 2)
    return EngineCluster(
        model, params, kvc, policy="prefix_affinity", router_seed=0,
        block_size=16, max_seq_len=256, max_batch=4, **kw,
    )


def test_serve_stream_chaos_arc_replays_byte_identical(dense_setup):
    """The tentpole contract: the same (traffic seed, fault seed) run
    twice through the deterministic pump-budget mode with a mid-run
    kill->heal arc yields a byte-identical record stream, identical
    fault counters, and identical rotation/heal/repair interleave."""
    _, model, params = dense_setup

    def run():
        kvc = make_kvc(replication=2)
        cluster = _chaos_cluster(model, params, kvc, rotate_every_s=0.4)
        arrs = _arrivals(n=8, rate=4.0)
        span = arrs[-1].t_s
        plan = FaultPlan.chaos_arc(
            kvc, seed=5, churn_start_s=span * 0.25,
            churn_window_s=span * 0.2, heal_s=span * 0.7,
            n_sat_kills=2, n_link_cuts=1)
        report = cluster.serve_stream(arrs, parallel=False, faults=plan,
                                      slo_window_s=span / 4)
        fp = [(r.arrival.tenant, r.shed,
               r.decision.replica if r.decision else None,
               tuple(r.result.token_ids) if r.result else None)
              for r in report.records]
        return fp, report.faults, report.rotations, report.slo["windows"]

    fp_a, faults_a, rot_a, win_a = run()
    fp_b, faults_b, rot_b, win_b = run()
    assert fp_a == fp_b                                # byte-identical
    assert faults_a == faults_b                        # same degradation
    assert rot_a == rot_b
    assert win_a == win_b                              # same timeline
    # the arc really ran mid-stream: kills applied AND heals crossed
    assert faults_a["sat_kills"] >= 2 and faults_a["sat_heals"] >= 2
    assert faults_a["link_kills"] >= 1
    # every phase appears in the tagged timeline
    assert {w["phase"] for w in win_a} == {
        "pre_churn", "churn", "post_heal"}
    assert any(len(t) > 0 for _, _, _, t in fp_a if t is not None)


def test_protected_tenant_zero_loss_through_chaos_arc(dense_setup):
    """Through a mid-run kill/heal arc under hard overload (capacity 0),
    the protected tenant is never shed and completes every request;
    every shed arrival is low-priority."""
    _, model, params = dense_setup
    kvc = make_kvc(replication=2)
    cluster = _chaos_cluster(model, params, kvc)
    arrs = _arrivals(n=10, rate=4.0)
    span = arrs[-1].t_s
    plan = FaultPlan.chaos_arc(
        kvc, seed=7, churn_start_s=span * 0.2,
        churn_window_s=span * 0.3, heal_s=span * 0.8, n_sat_kills=2)
    report = cluster.serve_stream(
        arrs, parallel=False, faults=plan,
        admission=AdmissionController(capacity_tokens=0,
                                      protect_priority=1))
    assert report.faults["sat_kills"] >= 2             # the arc bit
    pro = report.slo["per_tenant"]["pro"]
    assert pro["shed"] == 0
    assert pro["completed"] == pro["offered"] > 0
    assert all(len(r.token_ids) > 0 for r in report.results())
    shed = report.shed()
    assert shed and all(r.arrival.request.priority == 0 for r in shed)


def test_serve_stream_realtime_accepts_fault_injector(dense_setup):
    """Realtime mode composes with a prebuilt injector: events fire on
    the fabric clock from inside chunk ops, every request completes,
    and the report carries the stream's fault-counter block."""
    _, model, params = dense_setup
    kvc = make_kvc(clock=SimClock(rate=50.0), replication=2)
    cluster = _chaos_cluster(model, params, kvc, clock=kvc.transport.clock)
    arrs = _arrivals(n=6)
    span = arrs[-1].t_s
    inj = FaultInjector(kvc, FaultPlan.chaos_arc(
        kvc, seed=3, churn_start_s=span * 0.1,
        churn_window_s=span * 0.4, n_sat_kills=2), repair_on_heal=True)
    report = cluster.serve_stream(arrs, parallel=True, faults=inj)
    assert len(report.results()) == 6
    assert all(len(r.token_ids) > 0 for r in report.results())
    assert "degraded_reads" in report.faults
    inj.drain()                                        # park the heals
    assert inj.stats.sat_kills >= 2
