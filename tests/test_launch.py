"""Launch-layer unit tests that do not need 512 host devices.

(The full 40-combo x 2-mesh lowering is exercised by
``python -m repro.launch.dryrun --all``; results live in
benchmarks/results/dryrun/.)
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.probe import ProbeSet, probe_set, solve_linear
from repro.launch.roofline import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    Roofline,
    model_flops,
    parse_collectives,
    streaming_attn_correction,
)
from repro.launch.specs import input_specs
from repro.models.config import INPUT_SHAPES


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ag = bf16[8,1024,128]{2,1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[256,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups=[8,2]<=[16], dimensions={0}
  %cp = bf16[2,2]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %a2a = s32[16,16]{1,0} all-to-all(%v), replica_groups=[4,4]<=[16], dimensions={0}
"""


def test_parse_collectives_types_and_magnitudes():
    out = parse_collectives(HLO_SAMPLE)
    assert set(out) == {"all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"}
    ag_result = 8 * 1024 * 128 * 2
    assert out["all-gather"] == pytest.approx(ag_result * 15 / 16)
    ar_result = 256 * 1024 * 4
    assert out["all-reduce"] == pytest.approx(2 * ar_result * 3 / 4)
    rs_result = 64 * 64 * 4
    assert out["reduce-scatter"] == pytest.approx(rs_result * 1)  # n=2
    assert out["collective-permute"] == pytest.approx(2 * 2 * 2)
    assert out["all-to-all"] == pytest.approx(16 * 16 * 4 * 3 / 4)


def test_parse_collectives_empty():
    assert parse_collectives("%x = f32[2] add(%a, %b)") == {}


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def test_roofline_terms_and_dominance():
    r = Roofline(
        arch="a", shape="train_4k", mesh="16x16", step="train_step",
        flops_per_device=PEAK_FLOPS,            # 1 s of compute
        bytes_per_device=HBM_BW / 2,            # 0.5 s of memory
        collective_bytes=LINK_BW / 4,           # 0.25 s of collectives
        model_flops=0.5 * PEAK_FLOPS * 256,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.dominant == "compute"
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_conventions():
    cfg = get_config("yi-9b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)


def test_streaming_correction_only_for_long_prefill():
    cfg = get_config("yi-9b")
    assert streaming_attn_correction(cfg, INPUT_SHAPES["train_4k"],
                                     "full") == 0.0
    assert streaming_attn_correction(cfg, INPUT_SHAPES["decode_32k"],
                                     "full") == 0.0
    c = streaming_attn_correction(cfg, INPUT_SHAPES["prefill_32k"], "full")
    # 15/16 of the analytic attention flops
    expect = 4 * 32 * 32 * 128 * 32768**2 * 48 * 15 / 16
    assert c == pytest.approx(expect, rel=1e-6)
    ssm = get_config("mamba2-1.3b")
    assert streaming_attn_correction(ssm, INPUT_SHAPES["prefill_32k"],
                                     "full") == 0.0


# ---------------------------------------------------------------------------
# Linear probing
# ---------------------------------------------------------------------------

def test_probe_sets_cover_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ps = probe_set(cfg)
        assert len(ps.variants) >= len(ps.var_names) + 1 or (
            len(ps.var_names) == 1 and len(ps.variants) == 2
        )
        # full counts match the architecture
        if cfg.is_encoder_decoder:
            assert ps.full_counts == {"enc": 24, "dec": 24}
        elif cfg.arch_type == "hybrid":
            assert ps.full_counts == {"mamba": 38, "attn": 6}
        elif cfg.use_mla:
            assert ps.full_counts == {"dense": 3, "moe": 58}
        else:
            assert ps.full_counts == {"block": cfg.num_layers}


def test_solve_linear_recovers_exact_model():
    ps = ProbeSet(
        ("a", "b"),
        {"a": 10, "b": 5},
        (
            ({}, {"a": 1, "b": 1}),
            ({}, {"a": 2, "b": 1}),
            ({}, {"a": 1, "b": 2}),
        ),
    )
    out, xa, xb = 7.0, 3.0, 11.0

    def metric(counts):
        return out + xa * counts["a"] + xb * counts["b"]

    measured = [{"flops": metric(c)} for _, c in ps.variants]
    solved = solve_linear(ps, measured)
    assert solved["flops"] == pytest.approx(out + 10 * xa + 5 * xb)


def test_solve_linear_homogeneous():
    ps = ProbeSet(("block",), {"block": 48},
                  (({}, {"block": 1}), ({}, {"block": 2})))
    measured = [{"flops": 100 + 7}, {"flops": 100 + 14}]
    solved = solve_linear(ps, measured)
    assert solved["flops"] == pytest.approx(100 + 48 * 7)


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    if shape.is_decode:
        assert specs["tokens"].shape == (shape.global_batch, 1)
        return
    b = shape.global_batch
    if cfg.is_encoder_decoder:
        assert specs["tokens"].shape == (b, shape.seq_len // 2)
        assert specs["frames"].shape == (b, shape.seq_len // 2, cfg.d_model)
    elif cfg.arch_type == "vlm":
        s_text = shape.seq_len - cfg.num_image_tokens
        assert specs["tokens"].shape == (b, s_text)
        assert specs["image_embeds"].shape == (
            b, cfg.num_image_tokens, cfg.d_model)
    else:
        assert specs["tokens"].shape == (b, shape.seq_len)
    # total positions = the assigned seq_len
    total = specs["tokens"].shape[1] + (
        specs["image_embeds"].shape[1] if "image_embeds" in specs else 0)
    if not cfg.is_encoder_decoder:
        assert total == shape.seq_len


def test_make_rules_on_tiny_mesh():
    from repro.launch.mesh import make_rules

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("yi-9b")
    tr = make_rules(mesh, cfg, INPUT_SHAPES["train_4k"])
    assert tr.attn_tp and tr.fsdp and not tr.seq_shard_cache
    dc = make_rules(mesh, cfg, INPUT_SHAPES["decode_32k"])
    assert not dc.attn_tp
    lg = make_rules(mesh, cfg, INPUT_SHAPES["long_500k"])
    assert not lg.attn_tp


def test_grad_accum_equivalent_params():
    """Microbatched gradient accumulation == single-batch step."""
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.launch.mesh import make_rules
    from repro.launch.specs import make_plan
    from repro.models.config import InputShape
    from repro.training.optimizer import init_opt_state

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    shape = InputShape("t", 32, 4, "train")
    rules = make_rules(mesh, cfg, shape)
    with mesh:
        p1 = make_plan(cfg, shape, rules, remat=None, unroll=False,
                       grad_accum=1)
        p4 = make_plan(cfg, shape, rules, remat=None, unroll=False,
                       grad_accum=4)
        params = p1.model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
        batch["targets"] = batch["tokens"]
        r1 = jax.jit(p1.fn)(params, opt, batch)
        r4 = jax.jit(p4.fn)(params, opt, batch)
    for a, b in zip(jax.tree.leaves(r1[0]), jax.tree.leaves(r4[0])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-6, rtol=1e-4)
