"""Scale-out cluster: SimClock / anchored views / deferred fetches vs
rotation, router scoring, stats merging, and the EngineCluster itself.

The deterministic contract under test:

* a clocked fabric gives every Get KVC a completion time; payloads
  captured at issue survive rotation between issue and completion, and a
  purge between lookup and Get is a *clean* miss;
* the prefix-affinity router keeps duplicated-prefix groups on one
  replica, prefers near anchors for constellation-cached prefixes, and
  breaks ties by load -- while the random baseline spreads groups;
* cluster serving over N replicas returns every result in request order
  with true merged percentiles, and *experiences* nonzero L2 wait.
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    ConstellationView,
    IslTransport,
    KVCManager,
    LosWindow,
    Sat,
    SimClock,
    Strategy,
    chain_hashes,
)
from repro.core.chunking import arrays_to_bytes
from repro.core.protocol import TransportStats
from repro.models.model import Model
from repro.serving import (
    EngineCluster,
    EngineStats,
    PrefixAffinityRouter,
    RandomRouter,
    ReplicaHandle,
    Request,
    SamplingParams,
)

SPEC = ConstellationSpec(15, 15, 550.0)


def make_kvc(clock=None, **kw):
    transport = IslTransport(SPEC, clock=clock,
                             chunk_processing_time_s=1e-4)
    return ConstellationKVC(
        SPEC, LosWindow(Sat(7, 7), 9, 9), Strategy.ROTATION_HOP,
        num_servers=10, chunk_bytes=1024, transport=transport, **kw,
    )


def _tokenize(prompt: str) -> list[int]:
    return [ord(c) % 96 for c in prompt]


def _fake_kvc_fn(tokens, past, past_len):
    return arrays_to_bytes([np.cumsum(np.asarray(tokens, np.int64))])


# ---------------------------------------------------------------------------
# SimClock + bounded transport stats
# ---------------------------------------------------------------------------

def test_sim_clock_monotone_and_waits():
    clock = SimClock(rate=100.0)
    t0 = clock.now()
    assert clock.wait_until(t0 - 1.0) == 0.0          # past: no wait
    waited = clock.wait_until(clock.now() + 0.5)      # 0.5 virtual = 5ms wall
    assert waited > 0.0
    assert clock.waits == 1 and clock.waited_s == pytest.approx(waited)
    assert clock.now() >= t0 + 0.5


def test_sim_clock_rejects_bad_rate():
    with pytest.raises(ValueError):
        SimClock(rate=0.0)


def test_transport_stats_reservoir_bounded():
    ts = TransportStats(reservoir_size=64)
    for i in range(5000):
        ts.record((i + 1) * 1e-6)
    assert len(ts.op_latencies_s) == 64               # bounded
    assert ts.ops == 5000
    assert ts.last_latency_s == 5000e-6               # exact extremes
    assert ts.max_latency_s == 5000e-6
    pct = ts.latency_percentiles()
    assert 0 < pct["p50"] < pct["p95"] <= pct["p99"] <= 5000e-6
    # short runs keep every sample in arrival order (legacy probes)
    short = TransportStats()
    for lat in (3e-3, 1e-3, 2e-3):
        short.record(lat)
    assert short.op_latencies_s == [3e-3, 1e-3, 2e-3]


def test_transport_stats_past_cap_keeps_exact_counters():
    """Overflowing the reservoir loses samples, never facts: ``ops`` and
    ``max_latency_s`` stay exact, the sample list stays bounded, and the
    percentiles stay inside the observed [min, max] envelope."""
    rng = np.random.default_rng(42)
    ts = TransportStats(reservoir_size=32)
    lats = rng.uniform(1e-4, 5e-2, size=1000)
    for lat in lats:
        ts.record(float(lat))
    assert ts.ops == 1000                             # exact, not sampled
    assert ts.max_latency_s == pytest.approx(float(lats.max()))
    assert ts.last_latency_s == pytest.approx(float(lats[-1]))
    assert len(ts.op_latencies_s) <= 32               # bounded forever
    assert all(float(lats.min()) <= x <= float(lats.max())
               for x in ts.op_latencies_s)
    pct = ts.latency_percentiles()
    assert float(lats.min()) <= pct["p50"] <= pct["p95"] <= pct["p99"]
    assert pct["p99"] <= float(lats.max())


def test_transport_op_completion_time_on_clock():
    clock = SimClock(rate=1000.0)
    t = IslTransport(SPEC, clock=clock)
    ready = t.record_op(0.25)
    assert ready is not None and ready > clock.now()
    assert t.last_ready_at == ready
    unclocked = IslTransport(SPEC)
    assert unclocked.record_op(0.25) is None


# ---------------------------------------------------------------------------
# anchored views over one shared store
# ---------------------------------------------------------------------------

def test_views_share_storage_but_not_transport():
    kvc = make_kvc()
    near = kvc.view(Sat(7, 7))       # the window center
    far = kvc.view(Sat(0, 0))        # across the torus
    assert isinstance(near, ConstellationView)
    h = chain_hashes(list(range(8)), 8)[0]
    near.set_block(h, b"x" * 4096)
    # storage is shared: the far view reads what the near view wrote
    assert far.get_block(h) == b"x" * 4096
    assert kvc.get_block(h) == b"x" * 4096
    # hop costs are not: the far anchor pays more for the same block
    assert (far.transport.stats.last_latency_s
            > near.transport.stats.last_latency_s)
    assert far.estimate_get_latency_s() > near.estimate_get_latency_s()
    # stats attribution is per view (set on near, get on far + base)
    assert near.stats.blocks_set == 1 and far.stats.blocks_set == 0
    assert far.stats.block_hits == 1 and near.stats.block_hits == 0
    assert kvc.stats.block_hits == 1 and kvc.stats.blocks_set == 0


def test_view_purge_and_rotate_delegate_to_base():
    kvc = make_kvc()
    view = kvc.view(Sat(3, 3))
    h = chain_hashes(list(range(8)), 8)[0]
    view.set_block(h, b"y" * 2048)
    moves = view.rotate(1)
    assert view.window.center == kvc.window.center    # one shared window
    assert view.get_block(h) == b"y" * 2048           # survived migration
    assert isinstance(moves, list)
    view.purge_block(h)
    assert kvc.get_block(h) is None


# ---------------------------------------------------------------------------
# deferred fetches vs rotation / purge (satellite: in-flight semantics)
# ---------------------------------------------------------------------------

def test_deferred_get_survives_rotation_between_issue_and_completion():
    """A block that migrates between Get issue and completion must still
    deliver its payload: the Get captured the chunks at issue time, and
    rotation is copy-then-delete, so the flight is unaffected."""
    clock = SimClock(rate=1000.0)
    kvc = make_kvc(clock=clock)
    mgr = KVCManager(_tokenize, _fake_kvc_fn, kvc, block_size=8)
    tokens = _tokenize("rotate me around the torus!!")
    mgr.add_blocks_tokens(tokens)

    view = kvc.view(Sat(5, 5))
    sib = mgr.sibling(view)
    view.transport.last_ready_at = None
    payload, cached = sib.get_cache_tokens(tokens)    # Get issued here
    ready_at = view.transport.last_ready_at
    assert payload is not None and cached >= 8
    assert ready_at is not None and ready_at > clock.now()
    kvc.rotate(3)                                     # block moves in flight
    clock.wait_until(ready_at)                        # flight completes
    again, cached2 = sib.get_cache_tokens(tokens)     # post-rotation Get
    assert again == payload and cached2 == cached


def test_deferred_get_cleanly_misses_when_block_purged_in_flight():
    """Losing the block between lookup and a later Get must degrade to a
    clean (shorter or empty) result, never a corrupt payload."""
    clock = SimClock(rate=1000.0)
    kvc = make_kvc(clock=clock)
    mgr = KVCManager(_tokenize, _fake_kvc_fn, kvc, block_size=8)
    tokens = _tokenize("purge the tail block under me")
    mgr.add_blocks_tokens(tokens)
    hashes = chain_hashes(tokens, 8)
    payload, cached = mgr.get_cache_tokens(tokens)
    assert cached == len(hashes) * 8
    kvc.purge_block(hashes[-1])                       # lost mid-flight
    payload2, cached2 = mgr.get_cache_tokens(tokens)
    assert cached2 == (len(hashes) - 1) * 8           # clean shorter prefix
    assert payload2 is not None
    for h in hashes:
        kvc.purge_block(h)
    assert mgr.get_cache_tokens(tokens) == (None, 0)  # clean full miss


def test_sibling_managers_share_index_and_lock():
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _fake_kvc_fn, kvc, block_size=8)
    view = kvc.view(Sat(0, 0))
    sib = mgr.sibling(view)
    assert sib.index is mgr.index
    assert sib.policy is mgr.policy
    assert sib.lock is mgr.lock
    tokens = _tokenize("shared radix index across replicas")
    mgr.add_blocks_tokens(tokens)
    # the sibling sees the insert through the shared index...
    payload, cached = sib.get_cache_tokens(tokens)
    assert payload is not None and cached > 0
    # ...and concurrent sibling writers do not corrupt it
    def writer(m, salt):
        for i in range(12):
            m.add_blocks_tokens(_tokenize(f"writer {salt} row {i} " * 3))
    threads = [threading.Thread(target=writer, args=(m, s))
               for m, s in ((mgr, "a"), (sib, "b"))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    p2, c2 = sib.get_cache_tokens(tokens)
    assert p2 == payload and c2 == cached


# ---------------------------------------------------------------------------
# router scoring
# ---------------------------------------------------------------------------

def _handles(n, views=None):
    views = views or [None] * n
    return [ReplicaHandle(i, v) for i, v in enumerate(views)]


def test_affinity_router_keeps_duplicate_groups_together():
    router = PrefixAffinityRouter(_handles(4), block_size=8)
    groups = {g: _tokenize(f"group {g} shared context " * 4)
              for g in range(6)}
    # interleave group members the way a shared stream would
    assigned: dict[int, set[int]] = {g: set() for g in groups}
    for _round in range(3):
        for g, toks in groups.items():
            assigned[g].add(router.route(toks).replica)
    for g, replicas in assigned.items():
        assert len(replicas) == 1, f"group {g} split across {replicas}"
    # ...and the 6 groups spread over the 4 replicas via the load
    # tie-break instead of piling on replica 0
    used = {next(iter(r)) for r in assigned.values()}
    assert len(used) == 4


def test_random_router_spreads_duplicate_groups():
    router = RandomRouter(_handles(4), block_size=8, seed=0)
    toks = _tokenize("one duplicated context " * 4)
    replicas = {router.route(toks).replica for _ in range(16)}
    assert len(replicas) > 1          # the baseline has no affinity


def test_affinity_router_ties_broken_by_load():
    handles = _handles(3)
    handles[0].load_tokens = 100
    handles[1].load_tokens = 10      # emptiest
    handles[2].load_tokens = 50
    router = PrefixAffinityRouter(handles, block_size=8)
    d = router.route(_tokenize("fresh request, no affinity anywhere"))
    assert d.replica == 1
    assert d.load_tokens == 10


def test_affinity_router_is_hop_aware():
    """Equal affinity + constellation-cached prefix: the replica whose
    anchor is nearer the blocks' home satellites wins."""
    kvc = make_kvc()
    mgr = KVCManager(_tokenize, _fake_kvc_fn, kvc, block_size=8)
    tokens = _tokenize("hop aware routing over the torus " * 2)
    mgr.add_blocks_tokens(tokens)     # prefix is in the shared index
    far, near = kvc.view(Sat(0, 0)), kvc.view(Sat(7, 7))
    router = PrefixAffinityRouter(_handles(2, [far, near]), manager=mgr)
    d = router.route(tokens)
    assert d.cached_blocks > 0
    assert d.replica == 1             # near anchor despite higher index
    # the hop signal prices the Get the hit will actually issue: the
    # cached prefix's cumulative payload plus the directory-stripe
    # lookup for its tail block, not a full stripe
    hashes = chain_hashes(tokens, 8)
    n, meta = mgr.index.longest_cached_prefix(hashes)
    assert d.hop_latency_s == near.estimate_get_latency_s(
        payload_bytes=meta.payload_bytes, block_hash=hashes[n - 1])
    assert d.hop_latency_s > 0.0
    # the metadata leg is real: pricing it makes the estimate strictly
    # larger than the payload-only figure
    assert d.hop_latency_s > near.estimate_get_latency_s(
        payload_bytes=meta.payload_bytes)
    # without a cached prefix the hop term vanishes -> load tie-break
    d2 = router.route(_tokenize("never seen before, fresh tokens"))
    assert d2.replica == 0
    assert d2.hop_latency_s == 0.0


def test_router_release_and_reset():
    router = PrefixAffinityRouter(_handles(2), block_size=8)
    toks = _tokenize("bookkeeping " * 4)
    d = router.route(toks, est_new_tokens=16)
    h = router.handles[d.replica]
    assert d.committed_tokens == len(toks) + 16
    assert h.load_tokens == d.committed_tokens
    router.release(d.replica, d.committed_tokens)
    assert h.load_tokens == 0
    router.route(toks)
    router.reset()
    assert all(not h.seen_blocks and h.load_tokens == 0
               for h in router.handles)


def test_router_affinity_memory_is_bounded():
    """A long-lived router must not accrete every hash it ever routed:
    seen_blocks is FIFO-bounded and old entries stop matching."""
    router = PrefixAffinityRouter(_handles(1), block_size=8,
                                  max_seen_blocks=32)
    first = _tokenize("the very first routed context " * 2)
    router.route(first)
    for i in range(50):
        router.route(_tokenize(f"unique filler stream row {i:03d} " * 2))
    h = router.handles[0]
    assert len(h.seen_blocks) <= 32
    assert h.affinity_blocks(chain_hashes(first, 8)) == 0  # aged out


# ---------------------------------------------------------------------------
# stats merging
# ---------------------------------------------------------------------------

def test_engine_stats_merge_counters_and_samples():
    a = EngineStats(requests=2, decoded_tokens=10, l2_wait_s=0.5,
                    ttft_s=[0.1, 0.2], itl_s=[0.01])
    b = EngineStats(requests=3, decoded_tokens=5, l2_fetch_waits=2,
                    ttft_s=[0.3], itl_s=[0.02, 0.03])
    m = EngineStats.merged([a, b])
    assert m.requests == 5 and m.decoded_tokens == 15
    assert m.l2_wait_s == 0.5 and m.l2_fetch_waits == 2
    assert sorted(m.ttft_s) == [0.1, 0.2, 0.3]
    assert m.latency_percentiles()["ttft_s"]["p50"] == pytest.approx(0.2)
    # parts unchanged
    assert a.ttft_s == [0.1, 0.2] and b.requests == 3


# ---------------------------------------------------------------------------
# EngineCluster end-to-end (tiny model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cluster(model, params, *, clock=None, policy="prefix_affinity",
             num_replicas=2, rotate_every_s=None):
    kvc = make_kvc(clock=clock)
    return EngineCluster(
        model, params, kvc, num_replicas=num_replicas, policy=policy,
        block_size=16, max_seq_len=256, max_batch=4,
        rotate_every_s=rotate_every_s,
    )


def _reqs(n=8, dup_groups=2):
    base = "SkyMemory routes repeated contexts to one replica. "
    return [Request(prompt=base * 2 + f"question {i % dup_groups}",
                    sampling=SamplingParams(max_new_tokens=6))
            for i in range(n)]


def test_cluster_serves_in_request_order(dense_setup):
    _, model, params = dense_setup
    cluster = _cluster(model, params)
    reqs = _reqs()
    out = cluster.serve(reqs, parallel=False)
    assert len(out) == len(reqs)
    for req, res in zip(reqs, out):
        assert res.request_id == req.request_id
        assert len(res.token_ids) > 0
    merged = cluster.merged_stats()
    assert merged.requests == len(reqs)
    assert merged.requests == sum(e.stats.requests for e in cluster.engines)
    # duplicated contexts hit the shared constellation
    assert merged.cached_tokens > 0
    fabric = cluster.fabric_stats()
    assert fabric["block_hits"] > 0
    assert 0.0 < fabric["prefix_hit_rate"] < 1.0
    assert fabric["transport_latency_s"]["p50"] > 0.0
    # the finished batch's tokens were released back to the router
    assert all(h.load_tokens == 0 for h in cluster.handles)


def test_cluster_parallel_replicas_complete(dense_setup):
    _, model, params = dense_setup
    cluster = _cluster(model, params, policy="random")
    reqs = _reqs(n=6, dup_groups=3)
    out = cluster.serve(reqs, parallel=True)
    assert all(r is not None and len(r.token_ids) > 0 for r in out)
    assert cluster.merged_stats().requests == len(reqs)
    # the seeded random baseline used more than one replica
    assert sum(1 for e in cluster.engines if e.stats.requests) > 1


def test_cluster_experiences_l2_latency(dense_setup):
    """The acceptance-bar behavior: with a clocked fabric, restored
    prefixes have flight time, and whatever the scheduler cannot hide
    behind decode steps shows up as nonzero waited time."""
    _, model, params = dense_setup
    # rate 5: flights compress 5x (wall waits stay ~ms) but remain far
    # longer than the host-side gap between Get issue and consumption,
    # so un-hidden flight time is guaranteed to exist
    clock = SimClock(rate=5.0)
    cluster = _cluster(model, params, clock=clock, num_replicas=1)
    reqs = _reqs(n=4, dup_groups=1)
    cluster.serve(reqs, parallel=False)       # populate the cache
    cluster.reset_stats()
    cluster.serve(reqs, parallel=False)       # warm pass fetches blocks
    merged = cluster.merged_stats()
    assert merged.cached_tokens > 0
    assert merged.l2_wait_s > 0.0
    assert merged.l2_fetch_waits > 0
    assert cluster.fabric_stats()["l2_wait_s"] == merged.l2_wait_s


def test_scheduler_overlaps_l2_flight_with_decode(dense_setup):
    """A prefix fetched mid-decode stays in flight for many decode steps
    (the ISL flight is long at rate 1): the scheduler must keep decoding
    and defer the consuming chunk instead of stalling -- visible as
    ``l2_deferred_chunks`` -- and the admitted request still completes."""
    from repro.serving import Engine

    _, model, params = dense_setup
    clock = SimClock(rate=1.0)
    kvc = make_kvc(clock=clock)
    eng = Engine(model, params, kvc=kvc, block_size=16,
                 max_seq_len=256, max_batch=2)
    cached_prompt = "overlap this fetched prefix with live decode " * 3
    eng.generate([Request(prompt=cached_prompt,
                          sampling=SamplingParams(max_new_tokens=2))])
    eng.stats = EngineStats()
    # slot 0 frees after 2 tokens while slot 1 keeps decoding; the queued
    # duplicate then admits mid-decode and its SkyMemory hit's flight
    # overlaps the running decode steps
    out = eng.generate([
        Request(prompt="short warm request",
                sampling=SamplingParams(max_new_tokens=2)),
        Request(prompt="long running decode " * 4,
                sampling=SamplingParams(max_new_tokens=48)),
        Request(prompt=cached_prompt,
                sampling=SamplingParams(max_new_tokens=4)),
    ])
    assert all(len(r.token_ids) > 0 for r in out)
    assert out[2].cached_tokens > 0           # the hit really restored
    assert eng.stats.mid_decode_admissions >= 1
    assert eng.stats.l2_deferred_chunks > 0   # flight overlapped decode


def test_cluster_rotation_during_serving(dense_setup):
    """The rotation-during-serving scenario: the constellation rotates on
    the serving clock while requests are in flight; chunks migrate and
    the stream still completes with prefix hits."""
    _, model, params = dense_setup
    cluster = _cluster(model, params, rotate_every_s=0.05)
    reqs = _reqs(n=8, dup_groups=2)
    out = cluster.serve(reqs, parallel=False)
    assert all(len(r.token_ids) > 0 for r in out)
    # the ticker really rotated under the live run (8 requests on a CPU
    # engine take far longer than 50ms)
    assert cluster.rotations > 0
    assert cluster.kvc.stats.migrations > 0
    # post-rotation lookups still hit the migrated blocks
    cluster.reset_stats()
    out2 = cluster.serve(_reqs(n=2, dup_groups=2), parallel=False)
    assert cluster.merged_stats().cached_tokens > 0
    assert all(len(r.token_ids) > 0 for r in out2)


def test_cluster_affinity_vs_random_hit_rate(dense_setup):
    """Prefix affinity must not lose to random routing on a duplicated-
    prefix stream (sequential mode keeps this deterministic)."""
    _, model, params = dense_setup
    rates = {}
    for policy in ("prefix_affinity", "random"):
        cluster = _cluster(model, params, policy=policy)
        cluster.serve(_reqs(n=8, dup_groups=2), parallel=False)
        rates[policy] = cluster.fabric_stats()["prefix_hit_rate"]
    assert rates["prefix_affinity"] >= rates["random"]
