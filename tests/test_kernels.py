"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret=True mode on CPU (the kernel body executes in
Python), which checks indexing, masking, and accumulation logic exactly as
it would run on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked_prefill import (
    chunked_prefill_attention,
    chunked_prefill_paged,
)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_chunk_scan

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,h,hkv,d,off,win",
    [
        (2, 64, 64, 4, 2, 32, 0, None),        # GQA, square
        (1, 128, 256, 8, 8, 64, 128, None),    # prefix offset (chunked)
        (2, 32, 96, 4, 1, 16, 64, 48),         # MQA + sliding window
        (1, 200, 200, 2, 2, 24, 0, None),      # ragged (padding path)
        (1, 16, 144, 4, 4, 128, 128, 64),      # window + offset
    ],
)
def test_chunked_prefill_matches_oracle(b, sq, skv, h, hkv, d, off, win, dtype):
    q = _rand((b, sq, h, d), dtype)
    k = _rand((b, skv, hkv, d), dtype)
    v = _rand((b, skv, hkv, d), dtype)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off,
                             sliding_window=win)
    got = chunked_prefill_attention(q, k, v, causal=True, q_offset=off,
                                    sliding_window=win, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_chunked_prefill_noncausal():
    q = _rand((1, 32, 2, 16), jnp.float32)
    k = _rand((1, 48, 2, 16), jnp.float32)
    v = _rand((1, 48, 2, 16), jnp.float32)
    want = ref.attention_ref(q, k, v, causal=False)
    got = chunked_prefill_attention(q, k, v, causal=False, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# paged chunked prefill (prefill chunks reading a shared page pool)
# ---------------------------------------------------------------------------

def _paged_setup(b, sq, h, hkv, d, page, p_max, n_pages, dtype=jnp.float32):
    q = _rand((b, sq, h, d), dtype)
    kp = _rand((n_pages, page, hkv, d), dtype)
    vp = _rand((n_pages, page, hkv, d), dtype)
    bt = jnp.asarray(
        RNG.permutation(n_pages)[: b * p_max].reshape(b, p_max), jnp.int32)
    return q, kp, vp, bt


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,h,hkv,d,page,p_max,offs,lens",
    [
        # ragged, non-page-multiple offsets (chunk boundaries mid-page)
        (2, 24, 4, 2, 16, 8, 4, [5, 17], [22, 31]),
        # page-aligned chunk boundaries (the scheduler's normal case)
        (2, 16, 4, 4, 32, 16, 4, [16, 32], [32, 48]),
        # zero-length suffix: all keys masked -> zeros; plus a full row
        (2, 8, 2, 1, 16, 8, 3, [0, 3], [0, 11]),
        # single-token replay chunk one position before a page boundary
        (1, 1, 4, 2, 64, 16, 4, [31], [32]),
    ],
)
def test_chunked_prefill_paged_matches_oracle(b, sq, h, hkv, d, page, p_max,
                                              offs, lens, dtype):
    q, kp, vp, bt = _paged_setup(b, sq, h, hkv, d, page, p_max,
                                 b * p_max + 2, dtype)
    offs = jnp.asarray(offs, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    want = ref.chunked_prefill_paged_ref(q, kp, vp, lens, bt, offs)
    got = chunked_prefill_paged(q, kp, vp, lens, bt, offs, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_chunked_prefill_paged_matches_dense_gather():
    """Reading the prefix in place through the block table == gathering
    the pages into a contiguous sequence and running the dense oracle."""
    b, sq, h, hkv, d, page, p_max = 1, 24, 4, 2, 16, 8, 4
    q, kp, vp, bt = _paged_setup(b, sq, h, hkv, d, page, p_max, 8)
    off, kv_len = 5, 5 + sq
    got = chunked_prefill_paged(
        q, kp, vp, jnp.asarray([kv_len], jnp.int32), bt,
        jnp.asarray([off], jnp.int32), interpret=True)
    k_seq = jnp.take(kp, bt[0], axis=0).reshape(1, p_max * page, hkv, d)
    v_seq = jnp.take(vp, bt[0], axis=0).reshape(1, p_max * page, hkv, d)
    want = ref.attention_ref(q, k_seq[:, :kv_len], v_seq[:, :kv_len],
                             causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_chunked_prefill_paged_zero_length_rows_are_zero():
    """A row with no visible key (lengths == 0, or the padded tail of a
    ragged final chunk) must return exactly zero in kernel and oracle."""
    b, sq, h, hkv, d, page, p_max = 2, 8, 2, 2, 8, 4, 2
    q, kp, vp, bt = _paged_setup(b, sq, h, hkv, d, page, p_max, 6)
    lens = jnp.asarray([0, 5], jnp.int32)
    offs = jnp.asarray([0, 0], jnp.int32)
    got = chunked_prefill_paged(q, kp, vp, lens, bt, offs, interpret=True)
    want = ref.chunked_prefill_paged_ref(q, kp, vp, lens, bt, offs)
    assert np.abs(np.asarray(got[0])).max() == 0.0
    assert np.abs(np.asarray(want[0])).max() == 0.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_chunked_prefill_paged_gqa_head_mapping():
    """Query head h must read KV head h // (H/Hkv) through the table."""
    b, sq, h, hkv, d, page, p_max = 1, 8, 8, 4, 4, 8, 2
    q, kp, _, bt = _paged_setup(b, sq, h, hkv, d, page, p_max, 4)
    vp = jnp.broadcast_to(
        jnp.arange(hkv, dtype=jnp.float32)[None, None, :, None],
        kp.shape)
    lens = jnp.asarray([11], jnp.int32)
    offs = jnp.asarray([4], jnp.int32)
    out = np.asarray(chunked_prefill_paged(q, kp, vp, lens, bt, offs,
                                           interpret=True))
    rep = h // hkv
    for ih in range(h):
        np.testing.assert_allclose(out[0, :, ih], ih // rep, atol=1e-5)
    np.testing.assert_allclose(
        out, np.asarray(ref.chunked_prefill_paged_ref(q, kp, vp, lens, bt,
                                                      offs)),
        atol=2e-5, rtol=2e-4)


def test_ops_chunked_prefill_paged_dispatch():
    b, sq, h, hkv, d, page, p_max = 2, 16, 4, 2, 8, 8, 3
    q, kp, vp, bt = _paged_setup(b, sq, h, hkv, d, page, p_max, 8)
    lens = jnp.asarray([10, 20], jnp.int32)
    offs = jnp.asarray([0, 7], jnp.int32)
    a = ops.chunked_prefill_paged(q, kp, vp, lens, bt, offs, impl="jnp")
    b_ = ops.chunked_prefill_paged(q, kp, vp, lens, bt, offs, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# paged attention (decode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,p,page,h,hkv,d",
    [
        (2, 4, 32, 4, 2, 16),
        (1, 8, 16, 8, 1, 64),
        (3, 2, 128, 4, 4, 32),
        (2, 16, 8, 2, 2, 128),
    ],
)
def test_paged_attention_matches_oracle(b, p, page, h, hkv, d, dtype):
    q = _rand((b, h, d), dtype)
    k = _rand((b, p, page, hkv, d), dtype)
    v = _rand((b, p, page, hkv, d), dtype)
    lengths = jnp.asarray(RNG.integers(1, p * page + 1, size=(b,)), jnp.int32)
    want = ref.paged_attention_ref(q, k, v, lengths)
    got = paged_attention(q, k, v, lengths, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_paged_attention_length_edge_cases():
    """Ragged lengths: empty (0), single token, partial final page, page
    boundary, boundary+1, completely full."""
    b, p, page, h, d = 2, 3, 16, 2, 8
    q = _rand((b, h, d), jnp.float32)
    k = _rand((b, p, page, h, d), jnp.float32)
    v = _rand((b, p, page, h, d), jnp.float32)
    for lengths in ([0, 48], [1, 41], [16, 17], [0, 0], [15, 33], [48, 48]):
        lg = jnp.asarray(lengths, jnp.int32)
        want = ref.paged_attention_ref(q, k, v, lg)
        got = paged_attention(q, k, v, lg, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-4, err_msg=str(lengths))


def test_paged_attention_zero_length_returns_zeros():
    """A slot with no cached tokens (freshly admitted / idle) must produce
    exactly zero, not a uniform average over garbage pages."""
    b, p, page, h, d = 1, 2, 8, 2, 4
    q = _rand((b, h, d), jnp.float32)
    k = _rand((b, p, page, h, d), jnp.float32)
    v = _rand((b, p, page, h, d), jnp.float32)
    lg = jnp.asarray([0], jnp.int32)
    assert np.abs(np.asarray(
        paged_attention(q, k, v, lg, interpret=True))).max() == 0.0
    assert np.abs(np.asarray(ref.paged_attention_ref(q, k, v, lg))).max() == 0.0


def test_paged_attention_gqa_head_mapping():
    """Query head h must read KV head h // (H/Hkv).  Values are constant
    per KV head, so any mapping mistake shifts the output by >= 1."""
    b, p, page, h, hkv, d = 1, 2, 8, 8, 4, 4
    q = _rand((b, h, d), jnp.float32)
    k = _rand((b, p, page, hkv, d), jnp.float32)
    v = jnp.broadcast_to(
        jnp.arange(hkv, dtype=jnp.float32)[None, None, None, :, None],
        (b, p, page, hkv, d),
    )
    lengths = jnp.asarray([11], jnp.int32)
    out = np.asarray(paged_attention(q, k, v, lengths, interpret=True))
    rep = h // hkv
    for ih in range(h):
        np.testing.assert_allclose(out[0, ih], ih // rep, atol=1e-5)
    np.testing.assert_allclose(
        out, np.asarray(ref.paged_attention_ref(q, k, v, lengths)),
        atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_block_tables(dtype):
    """Shared-pool layout: kernel with scalar-prefetched block tables ==
    oracle == gathering pages into contiguous order first."""
    b, h, hkv, d, page, p_max, n_pages = 3, 4, 2, 16, 8, 4, 16
    q = _rand((b, h, d), dtype)
    kp = _rand((n_pages, page, hkv, d), dtype)
    vp = _rand((n_pages, page, hkv, d), dtype)
    bt = jnp.asarray(
        RNG.permutation(n_pages)[: b * p_max].reshape(b, p_max), jnp.int32)
    lengths = jnp.asarray([0, 13, 32], jnp.int32)
    want = ref.paged_attention_ref(q, kp, vp, lengths, block_tables=bt)
    got = paged_attention(q, kp, vp, lengths, block_tables=bt,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # contiguous gather of the same tables gives the same attention
    contig = paged_attention(q, kp[bt], vp[bt], lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(contig, np.float32), **_tol(dtype))


def test_paged_attention_block_tables_share_prefix_pages():
    """Two sequences may alias the same physical pages (a shared SkyMemory
    prefix): results must equal private copies of those pages."""
    b, h, hkv, d, page = 2, 2, 2, 8, 4
    q = _rand((b, h, d), jnp.float32)
    pool = _rand((6, page, hkv, d), jnp.float32)
    bt_shared = jnp.asarray([[1, 2], [1, 3]], jnp.int32)   # page 1 shared
    bt_private = jnp.asarray([[4, 2], [5, 3]], jnp.int32)
    pool_priv = pool.at[4].set(pool[1]).at[5].set(pool[1])
    lengths = jnp.asarray([7, 5], jnp.int32)
    a = paged_attention(q, pool, pool, lengths, block_tables=bt_shared,
                        interpret=True)
    c = paged_attention(q, pool_priv, pool_priv, lengths,
                        block_tables=bt_private, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,p,g,n,q",
    [
        (2, 128, 4, 8, 2, 16, 32),
        (1, 64, 8, 16, 1, 32, 64),
        (2, 256, 2, 32, 2, 8, 128),
        (1, 96, 4, 64, 4, 128, 32),   # full mamba2-like head/state dims
    ],
)
def test_ssd_scan_matches_oracle(b, l, h, p, g, n, q, dtype):
    x = _rand((b, l, h, p), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = _rand((b, l, g, n), dtype)
    cm = _rand((b, l, g, n), dtype)
    init = jnp.asarray(RNG.standard_normal((b, h, p, n)), jnp.float32)
    yw, fw = ref.ssd_scan_ref(x, dt, a, bm, cm, chunk_size=q,
                              initial_state=init)
    yg, fg = ssd_chunk_scan(x, dt, a, bm, cm, chunk_size=q,
                            initial_state=init, interpret=True)
    np.testing.assert_allclose(
        np.asarray(yg, np.float32), np.asarray(yw, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(fg), np.asarray(fw), atol=1e-4,
                               rtol=1e-3)


def test_ssd_scan_equals_sequential_recurrence():
    """Chunked kernel == token-by-token decode recurrence (ground truth)."""
    b, l, h, p, g, n = 1, 64, 2, 4, 1, 8
    x = _rand((b, l, h, p), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = _rand((b, l, g, n), jnp.float32)
    cm = _rand((b, l, g, n), jnp.float32)
    y, fs = ssd_chunk_scan(x, dt, a, bm, cm, chunk_size=16, interpret=True)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        yt, state = ref.ssd_decode_step_ref(
            x[:, t], dt[:, t], a, bm[:, t], cm[:, t], state
        )
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# ops dispatcher
# ---------------------------------------------------------------------------

def test_ops_dispatch_jnp_vs_pallas(monkeypatch):
    q = _rand((1, 32, 2, 16), jnp.float32)
    k = _rand((1, 32, 2, 16), jnp.float32)
    v = _rand((1, 32, 2, 16), jnp.float32)
    a = ops.flash_attention(q, k, v, impl="jnp")
    b = ops.flash_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-4)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "jnp")
    c = ops.flash_attention(q, k, v, impl="pallas")  # env overrides
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=0)


def test_ops_paged_dispatch_block_tables():
    """ops.paged_attention routes block tables to both implementations."""
    b, h, hkv, d, page, p_max, n_pages = 2, 4, 2, 8, 4, 3, 8
    q = _rand((b, h, d), jnp.float32)
    kp = _rand((n_pages, page, hkv, d), jnp.float32)
    vp = _rand((n_pages, page, hkv, d), jnp.float32)
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    lengths = jnp.asarray([5, 12], jnp.int32)
    a = ops.paged_attention(q, kp, vp, lengths, block_tables=bt, impl="jnp")
    b_ = ops.paged_attention(q, kp, vp, lengths, block_tables=bt,
                             impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               atol=2e-5, rtol=2e-4)


def test_paged_attention_grouped_matches_repeat():
    """Grouped-GQA decode (no head-repeat materialization) == baseline."""
    b, p, page, h, hkv, d = 2, 4, 32, 8, 2, 16
    q = _rand((b, h, d), jnp.float32)
    k = _rand((b, p, page, hkv, d), jnp.float32)
    v = _rand((b, p, page, hkv, d), jnp.float32)
    lengths = jnp.asarray([50, 120], jnp.int32)
    base = ref.paged_attention_ref(q, k, v, lengths, grouped=False)
    grp = ref.paged_attention_ref(q, k, v, lengths, grouped=True)
    np.testing.assert_allclose(np.asarray(grp), np.asarray(base),
                               atol=2e-5, rtol=2e-4)
