"""Placement strategies vs the paper's published grids (Figs 13-15)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.constellation import ConstellationSpec, LosWindow, Sat
from repro.core.mapping import (
    Strategy,
    bounding_box_side,
    hop_rings,
    layout_grid,
    place_servers,
)

SPEC = ConstellationSpec(num_planes=20, sats_per_plane=20, altitude_km=550.0)


def test_rotation_aware_fig13():
    assert layout_grid(Strategy.ROTATION, 3) == [
        [1, 2, 3],
        [4, 5, 6],
        [7, 8, 9],
    ]
    g5 = layout_grid(Strategy.ROTATION, 5)
    assert g5[0] == [1, 2, 3, 4, 5]
    assert g5[4] == [21, 22, 23, 24, 25]


def test_rotation_hop_aware_fig15_3x3():
    # Published 3x3 grid of the rotation+hop mapping.
    assert layout_grid(Strategy.ROTATION_HOP, 3) == [
        [7, 2, 6],
        [5, 1, 3],
        [9, 4, 8],
    ]


def test_rotation_hop_aware_fig15_5x5():
    # Published 5x5 grid of the rotation+hop mapping (paper Fig 15).
    assert layout_grid(Strategy.ROTATION_HOP, 5) == [
        [23, 15, 6, 14, 22],
        [17, 8, 2, 7, 16],
        [13, 5, 1, 3, 9],
        [21, 12, 4, 10, 18],
        [25, 20, 11, 19, 24],
    ]


def test_hop_aware_fig14_structure():
    # Unbounded BFS: ring radii are non-decreasing and form a diamond.
    rings = hop_rings(25)
    assert rings[0] == 0
    assert rings == sorted(rings)
    # ring r has exactly 4r members (diamond) until truncation
    assert rings[1:5] == [1, 1, 1, 1]
    assert rings[5:13] == [2] * 8
    # first ring order: up, right, down, left around the center
    g = layout_grid(Strategy.HOP, 5)
    assert g[2][2] == 1
    assert g[1][2] == 2 and g[2][3] == 3 and g[3][2] == 4 and g[2][1] == 5


def test_bounding_box_side():
    assert bounding_box_side(81) == 9
    assert bounding_box_side(80) == 9
    assert bounding_box_side(9) == 3
    assert bounding_box_side(10) == 4


@given(n=st.integers(1, 81))
@settings(max_examples=40, deadline=None)
def test_placements_are_distinct_sats(n):
    window = LosWindow(Sat(10, 10), 9, 9)
    for strat in Strategy:
        sats = place_servers(strat, SPEC, window, n)
        assert len(sats) == n
        assert len(set(sats)) == n  # no two servers share a satellite


@given(n=st.integers(1, 49))
@settings(max_examples=30, deadline=None)
def test_hop_rings_closer_than_rotation(n):
    """The ring placements never put a server farther (in hops from the
    center) than the worst row-major placement does."""
    window = LosWindow(Sat(10, 10), 7, 7)
    center = window.center

    def worst(strat):
        return max(
            SPEC.hops(center, s) for s in place_servers(strat, SPEC, window, n)
        )

    assert worst(Strategy.HOP) <= worst(Strategy.ROTATION)
    assert worst(Strategy.ROTATION_HOP) <= worst(Strategy.ROTATION)


def test_rotation_requires_window_capacity():
    window = LosWindow(Sat(10, 10), 3, 3)
    with pytest.raises(ValueError):
        place_servers(Strategy.ROTATION, SPEC, window, 10)


def test_hop_center_is_server_one():
    window = LosWindow(Sat(10, 10), 9, 9)
    for strat in (Strategy.HOP, Strategy.ROTATION_HOP):
        sats = place_servers(strat, SPEC, window, 25)
        assert sats[0] == window.center  # chunk 1 on the closest satellite
