"""Test-session bootstrap.

Property-based tests use ``hypothesis`` (declared in pyproject's ``test``
extra).  When it is missing -- e.g. a minimal container with only jax +
pytest -- install a stub into ``sys.modules`` so the four property-test
modules still *collect*: ``@given`` tests skip with a clear reason and every
plain test in those modules runs normally.
"""
from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    class _Strategy:
        """Placeholder for strategy objects (never drawn from)."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

        def __call__(self, *a, **k):
            return _Strategy()

    def _strategy_factory(*a, **k):
        return _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            def wrapper(*a, **k):
                pytest.skip("hypothesis not installed (pip install '.[test]')")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    def _assume(_cond=True):
        return True

    hyp = types.ModuleType("hypothesis")
    hyp.given = _given
    hyp.settings = _settings
    hyp.assume = _assume
    hyp.example = _settings
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, filter_too_much=None, data_too_large=None
    )

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _strategy_factory

    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
