"""Pallas TPU kernel: single-token decode attention over a paged KV cache.

The cache is the block-paged tensor SkyMemory stripes: pages of
``page_size`` tokens (the paper's 128-token blocks) per sequence.  One
query per sequence attends over all valid pages with online softmax.

Grid: (batch, q_heads, pages); pages innermost so the running (m, l, acc)
scratch carries across page iterations.  The per-sequence valid length
arrives as a [B, 1] int32 operand read from its own block.  GQA maps query
head -> kv head in the index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, page: int, num_pages: int):
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)               # [d]
    k = k_ref[0, 0, :, 0, :].astype(jnp.float32)         # [page, d]
    v = v_ref[0, 0, :, 0, :].astype(jnp.float32)         # [page, d]
    length = len_ref[0, 0]

    s = jax.lax.dot_general(
        k, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * scale                                      # [page]
    pos = ip * page + jax.lax.iota(jnp.int32, page)
    s = jnp.where(pos < length, s, NEG_INF)
    s = s[None, :]                                       # [1, page]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # masked scores contribute exactly 0 even when the whole page is masked
    # (m_new == NEG_INF would otherwise make exp(s - m_new) == 1)
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ip == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def _kernel_bt(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
               acc_ref, *, scale: float, page: int, num_pages: int):
    """Block-table variant: k/v arrive from a shared page pool; the page id
    for (sequence, page-slot) was resolved in the index map from the
    scalar-prefetched block table.  Only the length read differs here."""
    ib = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)               # [d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [page, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # [page, d]
    length = len_ref[ib]

    s = jax.lax.dot_general(
        k, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0] * scale                                      # [page]
    pos = ip * page + jax.lax.iota(jnp.int32, page)
    s = jnp.where(pos < length, s, NEG_INF)
    s = s[None, :]                                       # [1, page]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # masked scores contribute exactly 0 even when the whole page is masked
    # (m_new == NEG_INF would otherwise make exp(s - m_new) == 1)
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ip == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def _paged_attention_bt(q, k_pool, v_pool, lengths, block_tables, *,
                        softmax_scale, interpret):
    """Pool layout: k/v [N, page, Hkv, D]; block_tables [B, P] page ids.

    The block table and lengths ride scalar prefetch (SMEM), so the k/v
    index maps can dereference ``bt[ib, ip]`` -- pages stream straight from
    the pool with no per-sequence gather/copy on the host or in HBM.
    """
    b, h, d = q.shape
    _, page, hkv, _ = k_pool.shape
    np_ = block_tables.shape[1]
    dv = v_pool.shape[-1]
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    rep = h // hkv

    kernel = functools.partial(_kernel_bt, scale=scale, page=page,
                               num_pages=np_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, np_),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda ib, ih, ip, lens, bt: (ib, ih, 0)),
            pl.BlockSpec(
                (1, page, 1, d),
                lambda ib, ih, ip, lens, bt, rep=rep:
                    (bt[ib, ip], 0, ih // rep, 0),
            ),
            pl.BlockSpec(
                (1, page, 1, dv),
                lambda ib, ih, ip, lens, bt, rep=rep:
                    (bt[ib, ip], 0, ih // rep, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, dv),
                               lambda ib, ih, ip, lens, bt: (ib, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dv), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      q, k_pool, v_pool)


def paged_attention(
    q, k_pages, v_pages, lengths, *,
    softmax_scale: float | None = None,
    block_tables=None,
    interpret: bool = False,
):
    """q: [B,H,D]; k/v pages: [B,P,page,Hkv,D]; lengths: [B] -> out [B,H,D].

    With ``block_tables`` [B,P], k/v are instead a shared page pool
    [N,page,Hkv,D] and each sequence's pages are resolved through its
    block-table row (scalar prefetch)."""
    if block_tables is not None:
        return _paged_attention_bt(
            q, k_pages, v_pages, lengths, block_tables,
            softmax_scale=softmax_scale, interpret=interpret,
        )
    b, h, d = q.shape
    _, np_, page, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    rep = h // hkv
    lengths2 = lengths.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, page=page,
                               num_pages=np_)
    return pl.pallas_call(
        kernel,
        grid=(b, h, np_),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, ip: (ib, 0)),
            pl.BlockSpec((1, 1, d), lambda ib, ih, ip: (ib, ih, 0)),
            pl.BlockSpec((1, 1, page, 1, d),
                         lambda ib, ih, ip, rep=rep: (ib, ip, 0, ih // rep, 0)),
            pl.BlockSpec((1, 1, page, 1, dv),
                         lambda ib, ih, ip, rep=rep: (ib, ip, 0, ih // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda ib, ih, ip: (ib, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths2, q, k_pages, v_pages)
