"""jit'd kernel entry points with implementation dispatch.

``impl``:
  * ``"auto"``    -- Pallas on TPU backends, pure-jnp reference elsewhere
                     (this CPU container always takes the jnp path unless
                     interpret mode is forced);
  * ``"jnp"``     -- the ref.py oracle;
  * ``"pallas"``  -- the Pallas TPU kernel (compiled on TPU, interpret=True
                     on CPU so correctness is testable in this container).

Override globally with the ``REPRO_KERNEL_IMPL`` environment variable.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import ref


def _resolve(impl: str) -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", impl)
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    q_offset=0,
    sliding_window: int | None = None,
    lengths=None,
    softmax_scale: float | None = None,
    impl: str = "auto",
):
    """Prefill/chunked-prefill attention ([B,Sq,H,D] x [B,Skv,Hkv,D])."""
    impl = _resolve(impl)
    if impl == "jnp":
        if (lengths is None and k.shape[1] >= ref.STREAMING_KV_THRESHOLD):
            # memory-realistic path for long sequences: never materialize
            # the full score matrix (mirrors the TPU flash kernel)
            return ref.attention_streaming_ref(
                q, k, v, causal=causal, q_offset=q_offset,
                sliding_window=sliding_window, softmax_scale=softmax_scale,
                block_k=ref.STREAMING_BLOCK_K,
            )
        return ref.attention_ref(
            q, k, v, causal=causal, q_offset=q_offset,
            sliding_window=sliding_window, lengths=lengths,
            softmax_scale=softmax_scale,
        )
    from repro.kernels import chunked_prefill

    return chunked_prefill.chunked_prefill_attention(
        q, k, v, causal=causal, q_offset=q_offset,
        sliding_window=sliding_window, lengths=lengths,
        softmax_scale=softmax_scale, interpret=_interpret(),
    )


def paged_attention(
    q, k_pages, v_pages, lengths, *,
    softmax_scale: float | None = None,
    block_tables=None,
    grouped: bool | None = None,
    impl: str = "auto",
):
    """Decode attention over a paged KV cache ([B,H,D] x [B,P,page,Hkv,D]).

    ``block_tables`` [B,P] switches to the shared-pool layout: k/v are
    [N,page,Hkv,D] and pages are resolved per sequence through the table
    (the serving engine's device-resident layout).  ``grouped`` forces the
    jnp oracle's grouped-GQA contraction (no head-repeat materialization);
    the Pallas kernel is always grouped by construction.
    """
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.paged_attention_ref(
            q, k_pages, v_pages, lengths, softmax_scale=softmax_scale,
            block_tables=block_tables, grouped=grouped,
        )
    from repro.kernels import paged_attention as pa

    return pa.paged_attention(
        q, k_pages, v_pages, lengths,
        softmax_scale=softmax_scale, block_tables=block_tables,
        interpret=_interpret(),
    )


def chunked_prefill_paged(
    q, k_pool, v_pool, lengths, block_tables, q_offsets, *,
    softmax_scale: float | None = None,
    impl: str = "auto",
):
    """Prefill-chunk attention over a shared page pool ([B,Sq,H,D] x
    [N,page,Hkv,D] through [B,P] block tables).

    The serving engine's chunked-prefill read path: a chunk's queries at
    absolute offset ``q_offsets`` attend causally over the first
    ``lengths`` pool tokens of their sequence -- SkyMemory-restored pages
    and earlier chunks are read in place (scalar-prefetched tables on
    TPU; grouped-GQA gather oracle elsewhere).  Offsets/lengths are
    runtime values, so one compilation serves every chunk of a prefill.
    """
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.chunked_prefill_paged_ref(
            q, k_pool, v_pool, lengths, block_tables, q_offsets,
            softmax_scale=softmax_scale,
        )
    from repro.kernels import chunked_prefill

    return chunked_prefill.chunked_prefill_paged(
        q, k_pool, v_pool, lengths, block_tables, q_offsets,
        softmax_scale=softmax_scale, interpret=_interpret(),
    )


def ssd_scan(
    x, dt, a, b_mat, c_mat, *,
    chunk_size: int = 64,
    initial_state=None,
    impl: str = "auto",
):
    """Mamba-2 SSD chunked scan ([B,L,H,P] -> y, final_state)."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.ssd_scan_ref(
            x, dt, a, b_mat, c_mat,
            chunk_size=chunk_size, initial_state=initial_state,
        )
    from repro.kernels import ssd_scan as sk

    return sk.ssd_chunk_scan(
        x, dt, a, b_mat, c_mat,
        chunk_size=chunk_size, initial_state=initial_state,
        interpret=_interpret(),
    )


ssd_decode_step = ref.ssd_decode_step_ref  # tiny op: jnp everywhere
attention = partial(flash_attention)
