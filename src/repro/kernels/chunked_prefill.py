"""Pallas TPU kernel: chunked-prefill flash attention.

Computes causal (optionally sliding-window) attention where the query block
starts ``q_offset`` tokens into the key sequence -- exactly the shape of a
prefill on top of a SkyMemory-restored prefix (fresh queries over
prefix + fresh keys).  GQA is handled by mapping each query head to its KV
head in the BlockSpec index maps (no materialized head repeat).

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv dimension is innermost,
so the online-softmax running state (m, l, acc) lives in VMEM scratch and
persists across kv iterations.  Block sizes default to 128 (MXU-aligned);
the wrapper pads ragged shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, q_offset: int,
            sliding_window: int | None, block_q: int, block_k: int,
            kv_len: int, num_kv_blocks: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # [bk, d]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                            # [bq, bk]

    iq = pl.program_id(2)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # [bq, bk]
    correction = jnp.exp(m_prev - m_new)                 # [bq, 1]
    l_new = correction * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def chunked_prefill_attention(
    q, k, v, *,
    causal: bool = True,
    q_offset: int = 0,
    sliding_window: int | None = None,
    lengths=None,
    softmax_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """q: [B,Sq,H,Dq]; k/v: [B,Skv,Hkv,D].  Returns [B,Sq,H,Dv].

    ``lengths`` is not supported by this kernel (decode masking belongs to
    paged_attention); the jnp reference handles that case.
    """
    if lengths is not None:
        raise NotImplementedError("use paged_attention for length masking")
    b, sq, h, dq = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    scale = softmax_scale if softmax_scale is not None else dq ** -0.5
    rep = h // hkv

    block_q = min(block_q, _round_up(sq))
    block_k = min(block_k, _round_up(skv))
    pq = (-sq) % block_q
    pk = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = qp.shape[1] // block_q
    nk = kp.shape[1] // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, q_offset=q_offset,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        kv_len=skv, num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dq),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, dq),
                         lambda ib, ih, iq, ik, rep=rep: (ib, ik, ih // rep, 0)),
            pl.BlockSpec((1, block_k, 1, dv),
                         lambda ib, ih, iq, ik, rep=rep: (ib, ik, ih // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qp.shape[1], h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]


def _kernel_paged(len_ref, off_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, page: int,
                  block_q: int, num_pages: int):
    """Paged variant: q is a prefill *chunk* whose keys live in a shared
    page pool; the page id for (sequence, page-slot) was resolved in the
    index map from the scalar-prefetched block table, and the causal
    offset / valid length arrive per sequence through SMEM (they are
    traced values in the serving engine's fused step, not compile-time
    constants like the dense kernel's ``q_offset``)."""
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ip = pl.program_id(3)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [page, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # [page, d]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                            # [bq, page]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + off_ref[ib]
    k_pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (k_pos <= q_pos) & (k_pos < len_ref[ib])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # masked scores contribute exactly 0 even when the whole page is masked
    # (m_new == NEG_INF would otherwise make exp(s - m_new) == 1)
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ip == num_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def chunked_prefill_paged(
    q, k_pool, v_pool, lengths, block_tables, q_offsets, *,
    softmax_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
):
    """Chunked prefill reading keys straight from a shared page pool.

    q: [B,Sq,H,Dq] (one chunk per sequence); k/v pool: [N,page,Hkv,D];
    lengths [B] total valid kv tokens; block_tables [B,P] page ids;
    q_offsets [B] absolute position of each chunk's first query.  Returns
    [B,Sq,H,Dv].  Unlike ``chunked_prefill_attention`` the offset and
    length are *runtime* values (scalar prefetch), so one compiled kernel
    serves every chunk of a prefill as it advances -- and the prefix pages
    (SkyMemory-restored blocks, earlier chunks) are read in place, never
    gathered into a contiguous per-sequence tensor.  Fully masked query
    rows (padded chunk tail, ``lengths == 0``) return zeros.
    """
    b, sq, h, dq = q.shape
    _, page, hkv, dv = v_pool.shape
    np_ = block_tables.shape[1]
    scale = softmax_scale if softmax_scale is not None else dq ** -0.5
    rep = h // hkv

    block_q = min(block_q, _round_up(sq))
    pq = (-sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    nq = qp.shape[1] // block_q

    kernel = functools.partial(
        _kernel_paged, scale=scale, page=page, block_q=block_q,
        num_pages=np_,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, nq, np_),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dq),
                         lambda ib, ih, iq, ip, lens, offs, bt:
                             (ib, iq, ih, 0)),
            pl.BlockSpec((1, page, 1, dq),
                         lambda ib, ih, iq, ip, lens, offs, bt, rep=rep:
                             (bt[ib, ip], 0, ih // rep, 0)),
            pl.BlockSpec((1, page, 1, dv),
                         lambda ib, ih, iq, ip, lens, offs, bt, rep=rep:
                             (bt[ib, ip], 0, ih // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv),
                               lambda ib, ih, iq, ip, lens, offs, bt:
                                   (ib, iq, ih, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, dv), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, qp.shape[1], h, dv), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q_offsets.astype(jnp.int32),
      block_tables.astype(jnp.int32), qp, k_pool, v_pool)
    return out[:, :sq]


def _round_up(n: int, mult: int = 128) -> int:
    return max(mult, -(-n // mult) * mult) if n >= mult else _pow2(n)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
