"""Pallas TPU kernel: Mamba-2 SSD chunked scan (arXiv:2405.21060).

TPU-native layout of the state-space-duality algorithm: the sequence is cut
into chunks of Q tokens; within a chunk the token-token interaction is a
pair of MXU matmuls (quadratic only in Q); across chunks a [P, N] state
carries in VMEM scratch.

Grid: (batch, heads, chunks) with chunks innermost -- the recurrence is
sequential per (batch, head), which maps exactly onto the persistent-scratch
pattern (state re-initialized at chunk 0 from the optional initial state).
B/C projections are shared across head groups; the index maps route head ->
group without materializing the repeat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref,
            y_ref, final_ref, state_ref, *,
            chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)   # [P, N]

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    a = a_ref[0]                                      # scalar decay rate
    bm = b_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    cm = c_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]

    log_decay = a * dt                                # [Q]
    seg = jnp.cumsum(log_decay)                       # [Q]
    total = seg[-1]
    xdt = x * dt[:, None]                             # [Q, P]

    # Intra-chunk: scores[q,t] = (C_q . B_t) * exp(seg_q - seg_t), t <= q.
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [Q, Q]
    rel = seg[:, None] - seg[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ti <= qi, jnp.exp(rel), 0.0)
    y = jax.lax.dot_general(
        scores * decay, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [Q, P]

    # Off-diagonal: y[q] += exp(seg_q) * C_q . S_in
    s_in = state_ref[...]                             # [P, N]
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        cm, s_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # State update: S_out = exp(total) S_in + sum_t exp(total-seg_t) B_t x_t
    w = jnp.exp(total - seg)                          # [Q]
    upd = jax.lax.dot_general(
        xdt * w[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [P, N]
    state_ref[...] = jnp.exp(total) * s_in + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        final_ref[0, 0] = state_ref[...]


def ssd_chunk_scan(
    x, dt, a, b_mat, c_mat, *,
    chunk_size: int = 64,
    initial_state=None,
    interpret: bool = False,
):
    """x: [B,L,H,P]; dt: [B,L,H]; a: [H]; b/c: [B,L,G,N].

    Returns (y [B,L,H,P], final_state [B,H,P,N]) -- same contract as
    ``ref.ssd_scan_ref``.
    """
    bsz, seqlen, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert seqlen % chunk_size == 0, "pad sequence to a chunk multiple"
    nc = seqlen // chunk_size
    rep = h // g
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    kernel = functools.partial(_kernel, chunk=chunk_size, num_chunks=nc)
    y, final = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk_size, 1, p),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk_size, 1),
                         lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk_size, 1, n),
                         lambda ib, ih, ic, rep=rep: (ib, ic, ih // rep, 0)),
            pl.BlockSpec((1, chunk_size, 1, n),
                         lambda ib, ih, ic, rep=rep: (ib, ic, ih // rep, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk_size, 1, p),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, seqlen, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a.astype(jnp.float32), b_mat, c_mat,
      initial_state.astype(jnp.float32))
    return y, final
