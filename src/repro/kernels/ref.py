"""Pure-jnp oracles for every Pallas kernel (and the CPU fallback path).

These are the semantics of record: the Pallas kernels in this package must
match them (assert_allclose in tests, interpret=True on CPU), and the model
layer uses them whenever the TPU kernel path is unavailable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, S, H, D] by repeating each KV head."""
    b, s, hkv, d = k.shape
    if hkv == num_heads:
        return k
    rep = num_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def attention_ref(
    q: jax.Array,              # [B, Sq, H, D]
    k: jax.Array,              # [B, Skv, Hkv, D]
    v: jax.Array,              # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    sliding_window: int | None = None,
    lengths: jax.Array | None = None,   # [B] valid kv length per batch row
    softmax_scale: float | None = None,
) -> jax.Array:
    """Reference multi-head attention with GQA, causal offset and windowing.

    ``q_offset``: absolute position of q[0] within the kv sequence -- this is
    how prefill-with-cached-prefix attends over (prefix + fresh) keys.
    ``sliding_window``: query at absolute position p sees kv positions in
    (p - window, p].
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale

    q_pos = jnp.arange(sq)[:, None] + q_offset          # [Sq, 1]
    kv_pos = jnp.arange(skv)[None, :]                   # [1, Skv]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kv_pos <= q_pos
    if sliding_window is not None:
        mask &= kv_pos > q_pos - sliding_window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    if lengths is not None:
        valid = kv_pos < lengths[:, None, None, None]   # [B,1,1,Skv]
        logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_streaming_ref(
    q: jax.Array,              # [B, Sq, H, D]
    k: jax.Array,              # [B, Skv, Hkv, D]
    v: jax.Array,              # [B, Skv, Hkv, D]
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    sliding_window: int | None = None,
    softmax_scale: float | None = None,
    block_k: int = 2048,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    Streams over KV blocks with a lax.scan, so the (Sq x Skv) score matrix
    is never materialized -- the memory-realistic lowering for the 32k+
    shapes (the naive ``attention_ref`` would claim O(S^2) temp).  Matches
    ``attention_ref`` numerically.
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]            # may differ from d (MLA: qk 192, v 128)
    skv = k.shape[1]
    if skv % block_k:
        pad = (-skv) % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // block_k
    kh = _repeat_kv(k, h).reshape(b, nb, block_k, h, d)
    vh = _repeat_kv(v, h).reshape(b, nb, block_k, h, dv)
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(sq)[:, None] + q_offset            # [Sq, 1]

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, ib = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q32,
                       kb.astype(jnp.float32)) * scale
        k_pos = (ib * block_k + jnp.arange(block_k))[None, :]
        mask = k_pos < skv
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window is not None:
            mask &= k_pos > q_pos - sliding_window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kh, 1, 0), jnp.moveaxis(vh, 1, 0),
         jnp.arange(nb)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


STREAMING_KV_THRESHOLD = 8192
STREAMING_BLOCK_K = 2048


import os as _os

# Grouped-GQA decode: contract per KV-head group instead of materializing
# the head-repeated cache (a §Perf memory-term optimization; env-switchable
# so the baseline remains reproducible).
GQA_GROUPED = _os.environ.get("REPRO_GQA_GROUPED", "0") == "1"


def paged_attention_ref(
    q: jax.Array,              # [B, H, D] single decode query per sequence
    k_pages: jax.Array,        # [B, P, page, Hkv, D] or pool [N, page, Hkv, D]
    v_pages: jax.Array,        # same layout as k_pages
    lengths: jax.Array,        # [B] number of valid tokens in the cache
    *,
    softmax_scale: float | None = None,
    grouped: bool | None = None,
    block_tables: jax.Array | None = None,   # [B, P] page ids into the pool
) -> jax.Array:
    """Decode attention over a block-paged KV cache (one new token).

    Two layouts:
    * ``block_tables=None`` -- pages are the *contiguous per-sequence* page
      list ``[B, P, page, Hkv, D]`` (the serving layer already gathered
      pages into sequence order, mirroring how SkyMemory reassembles a
      block from its chunks);
    * ``block_tables=[B, P]`` -- k/v are a shared page *pool*
      ``[N, page, Hkv, D]`` and each sequence's pages are looked up through
      its block-table row (the serving engine's layout: pages are
      allocated/freed dynamically and never copied into sequence order).

    A row with ``lengths == 0`` has no valid key and returns zeros (matching
    the Pallas kernel, whose online-softmax accumulator stays empty).
    """
    if block_tables is not None:
        k_pages = jnp.take(k_pages, block_tables, axis=0)
        v_pages = jnp.take(v_pages, block_tables, axis=0)
        if grouped is None:
            # serving hot path: never materialize the head-repeated cache
            grouped = True
    b, p, page, hkv, d = k_pages.shape
    grouped = GQA_GROUPED if grouped is None else grouped
    k = k_pages.reshape(b, p * page, hkv, d)
    v = v_pages.reshape(b, p * page, hkv, d)
    any_valid = (lengths > 0)[:, None, None]
    if grouped:
        h = q.shape[1]
        rep = h // hkv
        scale = softmax_scale if softmax_scale is not None else d ** -0.5
        qg = q.reshape(b, hkv, rep, d)
        s = jnp.einsum("bgrd,bsgd->bgrs", qg, k).astype(jnp.float32) * scale
        valid = (jnp.arange(k.shape[1])[None, None, None, :]
                 < lengths[:, None, None, None])
        s = jnp.where(valid, s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bgrs,bsgd->bgrd", probs, v)
        out = out.reshape(b, h, v.shape[-1])
        return jnp.where(any_valid, out, jnp.zeros_like(out))
    out = attention_ref(
        q[:, None],
        k,
        v,
        causal=False,
        lengths=lengths,
        softmax_scale=softmax_scale,
    )[:, 0]
    return jnp.where(any_valid, out, jnp.zeros_like(out))


def chunked_prefill_paged_ref(
    q: jax.Array,              # [B, Sq, H, D] one prefill chunk per sequence
    k_pool: jax.Array,         # [N, page, Hkv, D] shared page pool
    v_pool: jax.Array,         # [N, page, Hkv, Dv]
    lengths: jax.Array,        # [B] total valid kv tokens (prefix + chunk)
    block_tables: jax.Array,   # [B, P] page ids into the pool
    q_offsets: jax.Array,      # [B] absolute position of q[:, 0]
    *,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Chunked prefill over a paged KV cache: the semantics of record for
    the Pallas ``chunked_prefill_paged`` kernel.

    Each row's queries sit at absolute positions ``q_offsets[b] + i`` and
    attend causally over the first ``lengths[b]`` tokens of the sequence,
    read *in place* from pool pages through the row's block table -- this
    is a prefill chunk running on top of a SkyMemory-restored prefix (plus
    any earlier chunks) without densifying it.  The chunk's own K/V must
    already be written into the pool (the model layer writes before it
    reads, like the decode path).  Query rows with no visible key (padded
    chunk tail, or ``lengths == 0``) return zeros, matching the kernel's
    empty online-softmax accumulator.  GQA is contracted per KV-head group
    (no materialized head repeat).
    """
    b, sq, h, d = q.shape
    _, page, hkv, dv = v_pool.shape
    p = block_tables.shape[1]
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    rep = h // hkv
    k = jnp.take(k_pool, block_tables, axis=0).reshape(b, p * page, hkv, d)
    v = jnp.take(v_pool, block_tables, axis=0).reshape(b, p * page, hkv, dv)
    qg = q.reshape(b, sq, hkv, rep, d)
    s = jnp.einsum("bqgrd,bsgd->bgrqs", qg, k).astype(jnp.float32) * scale
    q_pos = q_offsets[:, None] + jnp.arange(sq)[None, :]        # [B, Sq]
    k_pos = jnp.arange(p * page)[None, :]                       # [1, S]
    mask = (k_pos[:, None, :] <= q_pos[..., None]) \
        & (k_pos[:, None, :] < lengths[:, None, None])          # [B, Sq, S]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqs,bsgd->bqgrd", probs, v).reshape(b, sq, h, dv)
    row_valid = mask.any(axis=-1)[..., None, None]              # [B, Sq, 1, 1]
    return jnp.where(row_valid, out, jnp.zeros_like(out))


def ssd_scan_ref(
    x: jax.Array,    # [B, L, H, P]  inputs per head
    dt: jax.Array,   # [B, L, H]     softplus'd discretization step
    a: jax.Array,    # [H]           negative decay rate (A = -exp(A_log))
    b_mat: jax.Array,  # [B, L, G, N]  input projection (B in SSM terms)
    c_mat: jax.Array,  # [B, L, G, N]  output projection (C in SSM terms)
    *,
    chunk_size: int = 64,
    initial_state: jax.Array | None = None,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD (state-space duality) chunked scan, pure jnp.

    Returns (y [B,L,H,P], final_state [B,H,P,N]).  Sequential over chunks
    (lax.scan); quadratic only within a chunk.  G groups share B/C across
    H//G heads.
    """
    bsz, seqlen, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert seqlen % chunk_size == 0, "pad sequence to a chunk multiple"
    nc = seqlen // chunk_size
    rep = h // g

    # Broadcast groups to heads.
    b_h = jnp.repeat(b_mat, rep, axis=2)   # [B, L, H, N]
    c_h = jnp.repeat(c_mat, rep, axis=2)   # [B, L, H, N]

    # Per-step log decay: dA = a * dt  (a < 0).
    log_decay = (a[None, None, :] * dt).astype(jnp.float32)  # [B, L, H]
    xdt = (x * dt[..., None]).astype(jnp.float32)            # [B, L, H, P]

    def to_chunks(t):
        return t.reshape((bsz, nc, chunk_size) + t.shape[2:])

    xc = to_chunks(xdt)               # [B, C, Q, H, P]
    bc = to_chunks(b_h.astype(jnp.float32))
    cc = to_chunks(c_h.astype(jnp.float32))
    ld = to_chunks(log_decay)         # [B, C, Q, H]

    seg = jnp.cumsum(ld, axis=2)      # within-chunk cumulative log decay
    total = seg[:, :, -1, :]          # [B, C, H] chunk total decay

    # Intra-chunk (quadratic within the chunk):
    #   y[q] += sum_{t<=q} C[q]·B[t] * exp(seg[q]-seg[t]) * x[t]
    scores = jnp.einsum("bcqhn,bcthn->bchqt", cc, bc)        # [B,C,H,Q,Q]
    # rel[q, t] = seg[q] - seg[t], axes [B,C,Q,T,H]:
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]       # [B,C,Q,T,H]
    rel = jnp.moveaxis(rel, -1, 2)                            # [B,C,H,Q,T]
    causal = jnp.tril(jnp.ones((chunk_size, chunk_size), dtype=bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(rel), 0.0)
    y_diag = jnp.einsum("bchqt,bcthp->bcqhp", scores * decay, xc)

    # Chunk states: S_c = sum_t B[t] * exp(total - seg[t]) * x[t]
    state_decay = jnp.exp(total[:, :, None, :] - seg)         # [B,C,Q,H]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", bc, state_decay, xc)

    # Inter-chunk recurrence: S_out[c] = exp(total_c) * S_in[c] + states[c].
    # Done with an associative scan (log-depth combine, no while loop -- so
    # XLA cost analysis sees the true work and SPMD can parallelize it):
    # elements (a_c, b_c) with a=exp(total), b=chunk state; combine
    # (a1,b1)o(a2,b2) = (a1*a2, b1*a2 + b2) gives inclusive prefix states.
    if initial_state is None:
        init = jnp.zeros((bsz, h, p, n), dtype=jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    decay_tot = jnp.exp(total)                                 # [B,C,H]

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay[..., None, None] + by

    inc_decay, inc_states = jax.lax.associative_scan(
        combine, (decay_tot, states), axis=1
    )
    # state entering chunk c = init * prod_{<c} a + inclusive_states[c-1]
    excl_decay = jnp.concatenate(
        [jnp.ones_like(inc_decay[:, :1]), inc_decay[:, :-1]], axis=1
    )
    excl_states = jnp.concatenate(
        [jnp.zeros_like(inc_states[:, :1]), inc_states[:, :-1]], axis=1
    )
    prev_states = (
        init[:, None] * excl_decay[..., None, None] + excl_states
    )                                                          # [B,C,H,P,N]
    final = init * inc_decay[:, -1][..., None, None] + inc_states[:, -1]

    # Off-diagonal contribution: y[q] += C[q] · (exp(seg[q]) * S_prev)
    y_off = jnp.einsum(
        "bcqhn,bcqh,bchpn->bcqhp", cc, jnp.exp(seg), prev_states
    )

    y = (y_diag + y_off).reshape(bsz, seqlen, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step_ref(
    x: jax.Array,      # [B, H, P] one token
    dt: jax.Array,     # [B, H]
    a: jax.Array,      # [H]
    b_vec: jax.Array,  # [B, G, N]
    c_vec: jax.Array,  # [B, G, N]
    state: jax.Array,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence: state' = exp(a dt) state + dt x B^T."""
    h, g = x.shape[1], b_vec.shape[1]
    rep = h // g
    b_h = jnp.repeat(b_vec, rep, axis=1)   # [B, H, N]
    c_h = jnp.repeat(c_vec, rep, axis=1)
    decay = jnp.exp(a[None] * dt)          # [B, H]
    state32 = state.astype(jnp.float32)
    upd = jnp.einsum("bhp,bhn->bhpn", (x * dt[..., None]), b_h)
    new_state = state32 * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_h)
    return y.astype(x.dtype), new_state
