"""pjit training loop."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    AxisRules,
    param_specs,
    use_rules,
)
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass
class TrainConfig:
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    remat: str | None = None
    log_every: int = 10
    zero1: bool = False      # shard optimizer moments over data (beyond-paper)


def make_train_step(model: Model, tcfg: TrainConfig,
                    rules: AxisRules | None = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics),
    optionally pjit'd over the rules' mesh."""

    def step(params, opt_state, batch):
        with use_rules(rules):
            def loss_fn(p):
                loss, metrics = model.train_loss(p, batch, remat=tcfg.remat)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params)
            params, opt_state, opt_metrics = adamw_update(
                tcfg.opt, params, grads, opt_state
            )
            metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    if rules is None:
        return jax.jit(step)

    mesh = rules.mesh
    pspecs = param_specs(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)), rules
    )
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda s: isinstance(s, P))
    # optimizer moments follow the params (or data-sharded under zero1)
    def moment_spec(s):
        if tcfg.zero1 and s == P():
            return NamedSharding(mesh, P(rules.data))
        return NamedSharding(mesh, s)
    osh = {
        "m": jax.tree.map(moment_spec, pspecs,
                          is_leaf=lambda s: isinstance(s, P)),
        "v": jax.tree.map(moment_spec, pspecs,
                          is_leaf=lambda s: isinstance(s, P)),
        "step": NamedSharding(mesh, P()),
    }
    bsh = NamedSharding(mesh, P(rules.data))
    batch_shardings = {
        "tokens": bsh, "targets": bsh,
        "image_embeds": bsh, "frames": bsh,
    }
    return jax.jit(
        step,
        in_shardings=(psh, osh, None),
        out_shardings=(psh, osh, None),
    )


def train(
    model: Model,
    dataset,
    tcfg: TrainConfig,
    *,
    num_steps: int,
    seed: int = 0,
    rules: AxisRules | None = None,
    log_fn: Callable[[int, dict], None] | None = None,
):
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    step_fn = make_train_step(model, tcfg, rules)
    it = dataset.batches()
    history = []
    t0 = time.perf_counter()
    for step in range(num_steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if log_fn:
                log_fn(step, m)
    return params, opt_state, history
