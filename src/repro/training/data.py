"""Data pipeline: deterministic synthetic LM streams + text-file corpus.

Synthetic mode generates structured pseudo-text token streams (Zipfian
unigrams + Markov bigram structure) so the loss actually decreases during
the example training runs; file mode tokenizes a UTF-8 corpus with the
byte tokenizer and yields packed blocks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.serving.tokenizer import ByteTokenizer


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    path: str | None = None     # optional text-file corpus
    d_model: int = 0            # for frontend stubs
    num_image_tokens: int = 0
    is_encoder_decoder: bool = False
    arch_type: str = "dense"


class SyntheticLM:
    """Zipf unigram + bigram-chain synthetic language."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse deterministic bigram successor table
        self._succ = rng.integers(0, v, size=(v, 4))
        self._zipf_p = 1.0 / np.arange(1, v + 1)
        self._zipf_p /= self._zipf_p.sum()

    def _stream(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        tok = int(rng.integers(0, self.cfg.vocab_size))
        for i in range(n):
            out[i] = tok
            if rng.random() < 0.8:  # follow bigram structure
                tok = int(self._succ[tok, rng.integers(0, 4)])
            else:
                tok = int(rng.choice(self.cfg.vocab_size, p=self._zipf_p))
        return out

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1)
        while True:
            toks = np.stack([
                self._stream(rng, cfg.seq_len) for _ in range(cfg.batch_size)
            ])
            yield _attach_frontends(cfg, toks, rng)


class TextFileLM:
    """Packed blocks from a UTF-8 text file via the byte tokenizer."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        tk = ByteTokenizer(cfg.vocab_size, add_bos=False)
        with open(cfg.path, encoding="utf-8") as f:
            self.ids = np.asarray(tk.encode(f.read()), dtype=np.int32)
        if len(self.ids) < cfg.seq_len + 1:
            reps = (cfg.seq_len + 1) // max(len(self.ids), 1) + 1
            self.ids = np.tile(self.ids, reps)

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        hi = len(self.ids) - cfg.seq_len - 1
        while True:
            starts = rng.integers(0, hi, size=cfg.batch_size)
            toks = np.stack([self.ids[s : s + cfg.seq_len] for s in starts])
            yield _attach_frontends(cfg, toks, rng)


def _attach_frontends(cfg: DataConfig, toks: np.ndarray,
                      rng: np.random.Generator) -> dict:
    batch = {"tokens": toks, "targets": toks}
    if cfg.num_image_tokens and cfg.arch_type == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (cfg.batch_size, cfg.num_image_tokens, cfg.d_model)
        ).astype(np.float32) * 0.1
    if cfg.is_encoder_decoder:
        batch["frames"] = rng.standard_normal(
            (cfg.batch_size, cfg.seq_len, cfg.d_model)
        ).astype(np.float32) * 0.5
    return batch


def make_dataset(cfg: DataConfig):
    return TextFileLM(cfg) if cfg.path else SyntheticLM(cfg)
