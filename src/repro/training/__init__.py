from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, SyntheticLM, TextFileLM, make_dataset
from repro.training.loop import TrainConfig, make_train_step, train
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
)

__all__ = [
    "load_checkpoint",
    "save_checkpoint",
    "DataConfig",
    "SyntheticLM",
    "TextFileLM",
    "make_dataset",
    "TrainConfig",
    "make_train_step",
    "train",
    "AdamWConfig",
    "adamw_update",
    "global_norm",
    "init_opt_state",
    "lr_at",
]
