"""Checkpointing: pytree <-> .npz with path-encoded keys (no deps)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params, opt_state=None, *, step: int = 0,
                    metadata: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, **(metadata or {})}, f)


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    def fill(path, leaf):
        key = "/".join(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = flat[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        return jnp.asarray(arr, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(fill, template)


def load_checkpoint(path: str, params_template, opt_template=None):
    flat = dict(np.load(os.path.join(path, "params.npz")))
    params = _unflatten_into(params_template, flat)
    opt_state = None
    opt_file = os.path.join(path, "opt_state.npz")
    if opt_template is not None and os.path.exists(opt_file):
        opt_state = _unflatten_into(opt_template, dict(np.load(opt_file)))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta
