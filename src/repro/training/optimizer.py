"""AdamW + schedules, self-contained (no optax dependency).

Optimizer state is a pytree shaped like the params (m, v moments), so it
shards with the same rules as the parameters; the launcher can additionally
shard moments over ``data`` (ZeRO-1) as a beyond-paper memory lever.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, moment_dtype: str = "float32") -> dict[str, Any]:
    dt = jnp.dtype(moment_dtype)

    def zeros(p):
        return jax.tree.map(lambda a: jnp.zeros(a.shape, dt), p)

    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (skip norms, biases, scalars)."""
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("scale", "bias", "a_log", "dt_bias", "d_skip",
                        "norm_scale")


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mdt = m.dtype
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if _decay_mask(path) and p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"],
    )
    new_params = jax.tree.map(lambda t3: t3[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
