"""Composable model assembly for all assigned architecture families.

One ``Model`` wraps a ``ModelConfig`` and exposes pure functions:

* ``init(key)``                          -- parameter pytree (stacked layers)
* ``forward(params, tokens, ...)``       -- full-sequence logits (train/prefill)
* ``train_loss(params, batch)``          -- mean CE (+ MoE aux, + MTP)
* ``decode_step(params, cache, tok, pos)`` -- one-token serve step over the
  decode cache (the tensor SkyMemory blocks/chunks/stripes)

Layers are stacked (leading dim = n_layers) and driven by ``lax.scan`` so
96-layer dry-runs lower quickly; heterogeneous stacks (deepseek dense
prefix, zamba2 shared-attention periods) are segmented scans.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import maybe_shard
from repro.models import cache as cache_lib
from repro.models.attention import (
    attention_decode,
    attention_decode_paged,
    attention_prefill,
    attention_prefill_paged,
    init_attention,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    cross_entropy_loss,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
    unembed,
)
from repro.models.mla import init_mla, mla_decode, mla_prefill
from repro.models.moe import init_moe, moe_forward
from repro.models.ssd import init_ssd, ssd_decode, ssd_prefill


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _slice_layers(tree, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _remat(fn, policy: str | None):
    if policy is None or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots
        )
    if policy == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(f"unknown remat policy {policy!r}")


class Model:
    def __init__(self, cfg: ModelConfig, *, unroll: bool = False):
        self.cfg = cfg
        # Fully unroll layer scans: used by the dry-run so XLA cost
        # analysis counts every layer (scan bodies are costed once).
        self.unroll = unroll

    def _scan(self, body, init, xs):
        if not self.unroll:
            return jax.lax.scan(body, init, xs)
        length = jax.tree.leaves(xs)[0].shape[0]
        if length == 1:
            # a length-1 scan still lowers to a while loop (which blocks
            # SPMD sharding propagation); inline the body instead
            x1 = jax.tree.map(lambda a: a[0], xs)
            carry, y = body(init, x1)
            return carry, jax.tree.map(lambda a: a[None], y)
        return jax.lax.scan(body, init, xs, unroll=True)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: dict[str, Any] = {"embed": init_embed(ks[0], cfg)}

        if cfg.arch_type in ("ssm", "hybrid"):
            params["blocks"] = _stacked(
                lambda k: self._init_ssm_block(k), ks[1], cfg.num_layers
            )
            if cfg.arch_type == "hybrid":
                params["shared_attn"] = {
                    "norm": init_norm(cfg),
                    "attn": init_attention(ks[2], cfg),
                }
        elif cfg.use_mla and cfg.first_k_dense:
            params["blocks_dense"] = _stacked(
                lambda k: self._init_block(k, moe=False), ks[1], cfg.first_k_dense
            )
            params["blocks"] = _stacked(
                lambda k: self._init_block(k, moe=True),
                ks[2],
                cfg.num_layers - cfg.first_k_dense,
            )
        else:
            moe = cfg.num_experts > 0
            params["blocks"] = _stacked(
                lambda k: self._init_block(k, moe=moe), ks[1], cfg.num_layers
            )

        if cfg.is_encoder_decoder:
            params["encoder"] = {
                "blocks": _stacked(
                    lambda k: self._init_block(k, moe=False),
                    ks[3],
                    cfg.num_encoder_layers,
                ),
                "norm": init_norm(cfg),
            }
            params["cross"] = _stacked(
                lambda k: {"norm": init_norm(cfg), "attn": init_attention(k, cfg)},
                ks[4],
                cfg.num_layers,
            )
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": jax.vmap(
                    lambda k: jax.random.normal(k, (2 * cfg.d_model, cfg.d_model))
                    * (2 * cfg.d_model) ** -0.5
                )(jax.random.split(ks[5], cfg.mtp_depth)).astype(cfg.dtype),
                "blocks": _stacked(
                    lambda k: self._init_block(k, moe=False), ks[6], cfg.mtp_depth
                ),
                "norm": init_norm(cfg),
            }
        params["final_norm"] = init_norm(cfg)
        return params

    def _init_block(self, key, *, moe: bool) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
        p["attn"] = init_mla(k1, cfg) if cfg.use_mla else init_attention(k1, cfg)
        if moe:
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg)
        return p

    def _init_ssm_block(self, key) -> dict:
        return {"norm1": init_norm(self.cfg), "ssd": init_ssd(key, self.cfg)}

    # ------------------------------------------------------------------
    # embedding / frontends
    # ------------------------------------------------------------------
    def embed(self, params, tokens, *, image_embeds=None, frames=None):
        """Token embeddings; VLM prepends (stubbed) patch embeddings."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        if cfg.arch_type == "vlm" and image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
        return maybe_shard(x, "act_btd")

    # ------------------------------------------------------------------
    # full-sequence forward (training / prefill)
    # ------------------------------------------------------------------
    def forward(
        self,
        params,
        tokens,
        *,
        image_embeds=None,
        frames=None,
        q_offset: int = 0,
        sliding_window: int | None = None,
        collect_state: bool = False,
        remat: str | None = None,
        prefix_state=None,
    ):
        """Returns (logits, aux_loss, state) -- ``state`` is the stacked
        per-layer decode state when ``collect_state`` (prefill), else None.
        ``prefix_state`` feeds a SkyMemory-restored prefix (chunked prefill:
        dense K/V prefix or SSM state snapshot)."""
        cfg = self.cfg
        x = self.embed(params, tokens, image_embeds=image_embeds)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, frames, remat=remat)

        if cfg.arch_type in ("ssm", "hybrid"):
            x, aux, state = self._ssm_stack(
                params, x, q_offset=q_offset,
                sliding_window=sliding_window,
                collect_state=collect_state, remat=remat,
                prefix_state=prefix_state,
            )
        else:
            x, aux, state = self._attn_stack(
                params, x, enc_out=enc_out, q_offset=q_offset,
                sliding_window=sliding_window,
                collect_state=collect_state, remat=remat,
                prefix_state=prefix_state,
            )
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        logits = maybe_shard(logits, "logits")
        return logits, aux, state

    def _encode(self, params, frames, *, remat=None):
        cfg = self.cfg

        def block(p, x):
            h = apply_norm(p["norm1"], x, cfg)
            a, _ = attention_prefill(p["attn"], h, cfg, causal=False)
            x = x + a
            h = apply_norm(p["norm2"], x, cfg)
            x = x + apply_mlp(p["mlp"], h, cfg)
            return maybe_shard(x, "act_btd")

        blk = _remat(lambda p, x: (block(p, x), None), remat)

        def body(x, p):
            y, _ = blk(p, x)
            return y, None

        x, _ = self._scan(body, frames.astype(jnp.dtype(cfg.dtype)),
                            params["encoder"]["blocks"])
        return apply_norm(params["encoder"]["norm"], x, cfg)

    def _attn_block(self, p, x, *, enc_out, cross_p, q_offset, sliding_window,
                    moe: bool, prefix_kv=None):
        cfg = self.cfg
        h = apply_norm(p["norm1"], x, cfg)
        if cfg.use_mla:
            latent_prefix = (
                (prefix_kv["ckv"], prefix_kv["kr"]) if prefix_kv else None
            )
            a, kv = mla_prefill(p["attn"], h, cfg, q_offset=q_offset,
                                sliding_window=sliding_window,
                                latent_prefix=latent_prefix)
        else:
            kv_prefix = (
                (prefix_kv["k"], prefix_kv["v"]) if prefix_kv else None
            )
            a, kv = attention_prefill(
                p["attn"], h, cfg, q_offset=q_offset,
                sliding_window=sliding_window, kv_cache=kv_prefix,
            )
        x = x + a
        if enc_out is not None and cross_p is not None:
            hc = apply_norm(cross_p["norm"], x, cfg)
            c, cross_kv = attention_prefill(
                cross_p["attn"], hc, cfg, kv_x=enc_out, causal=False
            )
            x = x + c
            kv = kv + cross_kv  # (k, v, ck, cv)
        h2 = apply_norm(p["norm2"], x, cfg)
        aux = jnp.float32(0.0)
        if moe:
            y, aux = moe_forward(p["moe"], h2, cfg)
        else:
            y = apply_mlp(p["mlp"], h2, cfg)
        x = maybe_shard(x + y, "act_btd")
        return x, aux, kv

    def _attn_stack(self, params, x, *, enc_out, q_offset, sliding_window,
                    collect_state, remat, prefix_state=None):
        cfg = self.cfg

        def run_scan(blocks, x, *, moe, cross=None, prefix=None):
            def blk_fn(p, x, cross_p, pref):
                return self._attn_block(
                    p, x, enc_out=enc_out, cross_p=cross_p,
                    q_offset=q_offset, sliding_window=sliding_window,
                    moe=moe, prefix_kv=pref,
                )

            blk = _remat(blk_fn, remat)

            def body(carry, xs):
                x, aux = carry
                y, a, kv = blk(xs["p"], x, xs.get("c"), xs.get("pref"))
                return (y, aux + a), (kv if collect_state else None)

            xs = {"p": blocks}
            if cross is not None:
                xs["c"] = cross
            if prefix is not None:
                xs["pref"] = prefix
            (x, aux), kvs = self._scan(body, (x, jnp.float32(0.0)), xs)
            return x, aux, kvs

        state = {}
        if cfg.use_mla and cfg.first_k_dense:
            k = cfg.first_k_dense
            mla_prefix = prefix_state.get("mla") if prefix_state else None
            pre_d = _slice_layers(mla_prefix, 0, k) if mla_prefix else None
            pre_m = (_slice_layers(mla_prefix, k, cfg.num_layers)
                     if mla_prefix else None)
            x, aux1, kv1 = run_scan(params["blocks_dense"], x, moe=False,
                                    prefix=pre_d)
            x, aux2, kv2 = run_scan(params["blocks"], x, moe=True,
                                    prefix=pre_m)
            total_aux = aux1 + aux2
            if collect_state:
                state["mla"] = {
                    "ckv": jnp.concatenate([kv1[0], kv2[0]], axis=0),
                    "kr": jnp.concatenate([kv1[1], kv2[1]], axis=0),
                }
        else:
            moe = cfg.num_experts > 0
            cross = params.get("cross")
            prefix = None
            if prefix_state:
                prefix = prefix_state.get("mla") or prefix_state.get("kv")
            x, total_aux, kvs = run_scan(
                params["blocks"], x, moe=moe, cross=cross, prefix=prefix
            )
            if collect_state and kvs is not None:
                if cfg.use_mla:
                    state["mla"] = {"ckv": kvs[0], "kr": kvs[1]}
                elif cfg.is_encoder_decoder:
                    state["kv"] = {"k": kvs[0], "v": kvs[1]}
                    state["cross"] = {"k": kvs[2], "v": kvs[3]}
                else:
                    state["kv"] = {"k": kvs[0], "v": kvs[1]}
        return x, total_aux, (state if collect_state else None)

    def _ssm_stack(self, params, x, *, q_offset, sliding_window,
                   collect_state, remat, prefix_state=None):
        cfg = self.cfg

        def ssm_block(p, x, pref):
            h = apply_norm(p["norm1"], x, cfg)
            y, st = ssd_prefill(p["ssd"], h, cfg, state=pref)
            return maybe_shard(x + y, "act_btd"), st

        blk = _remat(ssm_block, remat)

        def segment(blocks, x, prefix):
            def body(carry, xs):
                y, st = blk(xs["p"], carry, xs.get("pref"))
                return y, st if collect_state else None

            xs = {"p": blocks}
            if prefix is not None:
                xs["pref"] = prefix
            return self._scan(body, x, xs)

        state: dict = {}
        if cfg.arch_type == "hybrid" and cfg.attn_layer_period:
            period = cfg.attn_layer_period
            n_attn = cfg.num_layers // period
            sts, kvs_k, kvs_v = [], [], []
            lo = 0
            for j in range(n_attn):
                hi = lo + period
                seg_prefix = (
                    _slice_layers(prefix_state["ssm"], lo, hi)
                    if prefix_state else None
                )
                x, st = segment(
                    _slice_layers(params["blocks"], lo, hi), x, seg_prefix
                )
                if collect_state:
                    sts.append(st)
                # shared attention block (weights reused every period)
                sp = params["shared_attn"]
                h = apply_norm(sp["norm"], x, cfg)
                pref_kv = None
                if prefix_state and "kv" in prefix_state:
                    pref_kv = (
                        prefix_state["kv"]["k"][j],
                        prefix_state["kv"]["v"][j],
                    )
                a, kv = attention_prefill(
                    sp["attn"], h, cfg, q_offset=q_offset,
                    sliding_window=sliding_window, kv_cache=pref_kv,
                )
                x = maybe_shard(x + a, "act_btd")
                if collect_state:
                    kvs_k.append(kv[0])
                    kvs_v.append(kv[1])
                lo = hi
            if lo < cfg.num_layers:
                seg_prefix = (
                    _slice_layers(prefix_state["ssm"], lo, cfg.num_layers)
                    if prefix_state else None
                )
                x, st = segment(
                    _slice_layers(params["blocks"], lo, cfg.num_layers),
                    x, seg_prefix,
                )
                if collect_state:
                    sts.append(st)
            if collect_state:
                state["ssm"] = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *sts
                )
                state["kv"] = {
                    "k": jnp.stack(kvs_k, axis=0),
                    "v": jnp.stack(kvs_v, axis=0),
                }
        else:
            prefix = prefix_state["ssm"] if prefix_state else None
            x, st = segment(params["blocks"], x, prefix)
            if collect_state:
                state["ssm"] = st
        return x, jnp.float32(0.0), (state if collect_state else None)

    # ------------------------------------------------------------------
    # training loss
    # ------------------------------------------------------------------
    def train_loss(self, params, batch, *, remat: str | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        targets = batch["targets"]
        logits, aux, _ = self.forward(
            params,
            tokens,
            image_embeds=batch.get("image_embeds"),
            frames=batch.get("frames"),
            sliding_window=cfg.sliding_window or None,
            remat=remat,
        )
        n_img = 0
        if cfg.arch_type == "vlm" and batch.get("image_embeds") is not None:
            n_img = batch["image_embeds"].shape[1]
            logits = logits[:, n_img:]
        loss = cross_entropy_loss(logits[:, :-1], targets[:, 1:])
        metrics = {"ce": loss, "aux": aux}
        if cfg.mtp_depth and "mtp" in params:
            loss = loss + 0.3 * self._mtp_loss(params, logits, tokens, targets)
        total = loss + aux
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, logits, tokens, targets):
        """DeepSeek-V3 multi-token prediction: one extra depth predicting
        token t+2 from [h_t ; emb(token_{t+1})] (simplified single block)."""
        cfg = self.cfg
        del logits
        x = self.embed(params, tokens)
        emb_next = jnp.roll(x, -1, axis=1)
        h = jnp.concatenate([x, emb_next], axis=-1)
        proj = params["mtp"]["proj"][0]
        h = (h @ proj).astype(x.dtype)
        blk = _slice_layers(params["mtp"]["blocks"], 0, 1)
        p0 = jax.tree.map(lambda a: a[0], blk)
        h2, _, _ = (
            self._attn_block(
                p0, h, enc_out=None, cross_p=None, q_offset=0,
                sliding_window=None, moe=False,
            )
        )
        h2 = apply_norm(params["mtp"]["norm"], h2, cfg)
        lg = unembed(params["embed"], h2, cfg)
        return cross_entropy_loss(lg[:, :-2], targets[:, 2:])

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, *, specs_only=False,
                   src_len=None):
        return cache_lib.init_cache(
            self.cfg, batch, seq_len, specs_only=specs_only, src_len=src_len
        )

    @property
    def supports_paged_decode(self) -> bool:
        return cache_lib.supports_paged_decode(self.cfg)

    def init_paged_cache(self, *, num_slots: int, page_size: int,
                         max_seq_len: int, num_pages: int | None = None):
        return cache_lib.PagedKVCache(
            self.cfg, num_slots=num_slots, page_size=page_size,
            max_seq_len=max_seq_len, num_pages=num_pages,
        )

    def decode_step_paged(self, params, k_pool, v_pool, tokens,
                          block_tables, lengths, *, contiguous=False):
        """One continuous-batching serve step over the shared page pool.

        ``tokens`` [B,1] at per-sequence absolute positions ``lengths`` [B]
        (heterogeneous: slots admit mid-decode); ``k_pool``/``v_pool`` are
        ``[L, N_pages, page, Hkv, hd]``; ``block_tables`` [B, P] maps each
        slot's logical pages to pool pages (``None`` with
        ``contiguous=True``, where slot regions make page ids arithmetic).
        Returns (logits [B,1,V], k_pool', v_pool').  Dense-attention
        families only (``supports_paged_decode``).
        """
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)

        # The stacked pools ride the scan CARRY and each layer writes back
        # through dynamic_update_index_in_dim, so XLA aliases the update in
        # place -- a ys-stacked scan would materialize a full copy of the
        # cache every token (the dominant memory traffic of a decode step).
        def body(carry, l):
            x, kp, vp = carry
            p = jax.tree.map(lambda a: a[l], params["blocks"])
            h = apply_norm(p["norm1"], x, cfg)
            a, kl, vl = attention_decode_paged(
                p["attn"], h, cfg, k_pool=kp[l], v_pool=vp[l],
                block_tables=block_tables, lengths=lengths,
                contiguous=contiguous,
            )
            x = x + a
            h2 = apply_norm(p["norm2"], x, cfg)
            if cfg.num_experts > 0:
                y, _ = moe_forward(p["moe"], h2, cfg)
            else:
                y = apply_mlp(p["mlp"], h2, cfg)
            kp = jax.lax.dynamic_update_index_in_dim(kp, kl, l, 0)
            vp = jax.lax.dynamic_update_index_in_dim(vp, vl, l, 0)
            return (x + y, kp, vp), None

        carry = (x, k_pool, v_pool)
        if self.unroll:
            for l in range(cfg.num_layers):
                carry, _ = body(carry, l)
        else:
            carry, _ = jax.lax.scan(
                body, carry, jnp.arange(cfg.num_layers))
        x, ks, vs = carry
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        return logits, ks, vs

    def prefill_chunk_paged(self, params, k_pool, v_pool, tokens,
                            block_tables, q_offsets, n_valid):
        """A batch of prefill *chunks* over the shared page pool.

        ``tokens`` [R, C] holds one prompt slice per row, row ``i``
        starting at absolute position ``q_offsets[i]`` (``n_valid[i] <= C``
        real tokens; the tail is padding, and an all-padding row with
        ``n_valid == 0`` is a no-op).  Each layer writes the chunks' K/V
        into their slots' pool pages (through ``block_tables`` [R, P]) and
        attends over everything cached so far -- SkyMemory-restored pages,
        earlier chunks, and this chunk -- read in place from the pool.
        Returns ``(last_logits [R, V], k_pool', v_pool')`` -- only each
        row's last *valid* position is unembedded (the one logit a
        finishing chunk samples its first token from; a C x V projection
        per step would be pure waste on a serving vocabulary).
        ``q_offsets``/``n_valid`` are traced, so one compilation per
        buffer shape serves every chunk of every admission; this is the
        half of the engine's fused mixed step that retires prompt tokens
        (decode_step_paged retires generation tokens), and the whole of
        its cold-start admission wave.  Dense attention families only
        (``supports_paged_decode``).
        """
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)

        # pools ride the scan carry with in-place dynamic updates, exactly
        # like decode_step_paged -- a ys-stacked scan would copy the pool
        def body(carry, l):
            x, kp, vp = carry
            p = jax.tree.map(lambda a: a[l], params["blocks"])
            h = apply_norm(p["norm1"], x, cfg)
            a, kl, vl = attention_prefill_paged(
                p["attn"], h, cfg, k_pool=kp[l], v_pool=vp[l],
                block_tables=block_tables, q_offsets=q_offsets,
                n_valid=n_valid,
            )
            x = x + a
            h2 = apply_norm(p["norm2"], x, cfg)
            if cfg.num_experts > 0:
                y, _ = moe_forward(p["moe"], h2, cfg)
            else:
                y = apply_mlp(p["mlp"], h2, cfg)
            kp = jax.lax.dynamic_update_index_in_dim(kp, kl, l, 0)
            vp = jax.lax.dynamic_update_index_in_dim(vp, vl, l, 0)
            return (x + y, kp, vp), None

        carry = (x, k_pool, v_pool)
        if self.unroll:
            for l in range(cfg.num_layers):
                carry, _ = body(carry, l)
        else:
            carry, _ = jax.lax.scan(
                body, carry, jnp.arange(cfg.num_layers))
        x, ks, vs = carry
        x = apply_norm(params["final_norm"], x, cfg)
        idx = jnp.maximum(jnp.asarray(n_valid, jnp.int32) - 1, 0)   # [R]
        last = jnp.take_along_axis(
            x, idx[:, None, None], axis=1)                  # [R, 1, D]
        logits = unembed(params["embed"], last, cfg)[:, 0]  # [R, V]
        return logits, ks, vs

    def decode_step(self, params, cache, tokens, pos):
        """One serve step: ``tokens`` [B,1] at absolute position ``pos``
        (scalar); returns (logits [B,1,V], new_cache)."""
        cfg = self.cfg
        swin = cfg.sliding_window or None
        x = embed_tokens(params["embed"], tokens, cfg)
        new_cache = dict(cache)

        if cfg.arch_type in ("ssm", "hybrid"):
            x, new_cache = self._ssm_decode(params, x, cache, pos)
        elif cfg.use_mla:
            x, new_cache = self._mla_decode(params, x, cache, pos, swin)
        else:
            x, new_cache = self._attn_decode(params, x, cache, pos, swin)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = unembed(params["embed"], x, cfg)
        return logits, new_cache

    def _attn_decode(self, params, x, cache, pos, swin):
        cfg = self.cfg
        cross = params.get("cross")

        def body(x, xs):
            p = xs["p"]
            h = apply_norm(p["norm1"], x, cfg)
            a, k, v = attention_decode(
                p["attn"], h, cfg, k_cache=xs["k"], v_cache=xs["v"],
                pos=pos, sliding_window=swin,
            )
            x = x + a
            if cross is not None:
                hc = apply_norm(xs["c"]["norm"], x, cfg)
                cx, _, _ = attention_decode(
                    xs["c"]["attn"], hc, cfg, k_cache=xs["k"], v_cache=xs["v"],
                    pos=pos, cross_kv=(xs["ck"], xs["cv"]),
                )
                x = x + cx
            h2 = apply_norm(p["norm2"], x, cfg)
            if cfg.num_experts > 0:
                y, _ = moe_forward(p["moe"], h2, cfg)
            else:
                y = apply_mlp(p["mlp"], h2, cfg)
            return x + y, (k, v)

        xs = {"p": params["blocks"], "k": cache["kv"]["k"], "v": cache["kv"]["v"]}
        if cross is not None:
            xs["c"] = cross
            xs["ck"] = cache["cross"]["k"]
            xs["cv"] = cache["cross"]["v"]
        x, (ks, vs) = self._scan(body, x, xs)
        new_cache = dict(cache)
        new_cache["kv"] = {"k": ks, "v": vs}
        return x, new_cache

    def _mla_decode(self, params, x, cache, pos, swin):
        cfg = self.cfg

        def make_body(moe):
            def body(x, xs):
                p = xs["p"]
                h = apply_norm(p["norm1"], x, cfg)
                a, ckv, kr = mla_decode(
                    p["attn"], h, cfg, ckv_cache=xs["ckv"],
                    krope_cache=xs["kr"], pos=pos, sliding_window=swin,
                )
                x = x + a
                h2 = apply_norm(p["norm2"], x, cfg)
                if moe:
                    y, _ = moe_forward(p["moe"], h2, cfg)
                else:
                    y = apply_mlp(p["mlp"], h2, cfg)
                return x + y, (ckv, kr)
            return body

        mla = cache["mla"]
        new_cache = dict(cache)
        if cfg.first_k_dense:
            k = cfg.first_k_dense
            x, (c1, r1) = self._scan(
                make_body(False), x,
                {"p": params["blocks_dense"], "ckv": mla["ckv"][:k],
                 "kr": mla["kr"][:k]},
            )
            x, (c2, r2) = self._scan(
                make_body(True), x,
                {"p": params["blocks"], "ckv": mla["ckv"][k:],
                 "kr": mla["kr"][k:]},
            )
            new_cache["mla"] = {
                "ckv": jnp.concatenate([c1, c2], axis=0),
                "kr": jnp.concatenate([r1, r2], axis=0),
            }
        else:
            x, (c, r) = self._scan(
                make_body(cfg.num_experts > 0), x,
                {"p": params["blocks"], "ckv": mla["ckv"], "kr": mla["kr"]},
            )
            new_cache["mla"] = {"ckv": c, "kr": r}
        return x, new_cache

    def _ssm_decode(self, params, x, cache, pos):
        cfg = self.cfg
        swin = cfg.sliding_window or None

        def body(x, xs):
            p = xs["p"]
            h = apply_norm(p["norm1"], x, cfg)
            y, conv, st = ssd_decode(
                p["ssd"], h, cfg, conv_state=xs["conv"], ssm_state=xs["state"]
            )
            return x + y, (conv, st)

        ssm = cache["ssm"]
        new_cache = dict(cache)
        if cfg.arch_type == "hybrid" and cfg.attn_layer_period:
            period = cfg.attn_layer_period
            n_attn = cfg.num_layers // period
            convs, states, ks, vs = [], [], [], []
            lo = 0
            kvc = cache["kv"]
            for j in range(n_attn):
                hi = lo + period
                xs = {
                    "p": _slice_layers(params["blocks"], lo, hi),
                    "conv": ssm["conv"][lo:hi],
                    "state": ssm["state"][lo:hi],
                }
                x, (cv, st) = self._scan(body, x, xs)
                convs.append(cv)
                states.append(st)
                sp = params["shared_attn"]
                h = apply_norm(sp["norm"], x, cfg)
                a, k, v = attention_decode(
                    sp["attn"], h, cfg, k_cache=kvc["k"][j], v_cache=kvc["v"][j],
                    pos=pos, sliding_window=swin,
                )
                x = x + a
                ks.append(k)
                vs.append(v)
                lo = hi
            if lo < cfg.num_layers:
                xs = {
                    "p": _slice_layers(params["blocks"], lo, cfg.num_layers),
                    "conv": ssm["conv"][lo:],
                    "state": ssm["state"][lo:],
                }
                x, (cv, st) = self._scan(body, x, xs)
                convs.append(cv)
                states.append(st)
            new_cache["ssm"] = {
                "conv": jnp.concatenate(convs, axis=0),
                "state": jnp.concatenate(states, axis=0),
            }
            new_cache["kv"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        else:
            xs = {"p": params["blocks"], "conv": ssm["conv"], "state": ssm["state"]}
            x, (cv, st) = self._scan(body, x, xs)
            new_cache["ssm"] = {"conv": cv, "state": st}
        return x, new_cache
