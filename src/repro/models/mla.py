"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

MLA compresses K/V into a per-token latent ``c_kv`` (kv_lora_rank) plus a
shared RoPE key (qk_rope_head_dim).  The decode cache stores only
``c_kv || k_rope`` -- ~14x smaller than GQA K/V -- which is exactly the
payload SkyMemory blocks and chunks for this architecture (DESIGN.md §4).

Prefill expands the latent to full K/V (flash attention); decode uses the
*absorbed* form: W_UK folds into the query and W_UV into the output, so
attention runs directly against the latent cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_norm, apply_norm
from repro.models.rope import apply_rope


def init_mla(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dtype=dt),
        "q_norm": init_norm(cfg, qr),
        "wq_b": dense_init(ks[1], (qr, h * (dn + dr)), dtype=dt),
        "wkv_a": dense_init(ks[2], (d, kr + dr), dtype=dt),
        "kv_norm": init_norm(cfg, kr),
        # stored per-head for the absorbed decode path:
        "w_uk": dense_init(ks[3], (h, kr, dn), in_axis_size=kr, dtype=dt),
        "w_uv": dense_init(ks[4], (h, kr, dv), in_axis_size=kr, dtype=dt),
        "wo": dense_init(ks[5], (h * dv, d), dtype=dt),
    }


def _queries(params, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = apply_norm(params["q_norm"], x @ params["wq_a"], cfg) @ params["wq_b"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(params, x, cfg: ModelConfig, positions):
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ params["wkv_a"]
    c_kv = apply_norm(params["kv_norm"], kv[..., :kr], cfg)
    k_rope = kv[..., kr:][:, :, None, :]               # [B,S,1,dr]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_prefill(params, x, cfg: ModelConfig, *, q_offset=0,
                sliding_window: int | None = None, latent_prefix=None):
    """Returns (out, (c_kv, k_rope)) -- the latent pair is the KVC payload.

    ``latent_prefix=(ckv, kr)``: a SkyMemory-restored latent prefix; fresh
    latents are appended and queries attend across both (chunked prefill).
    The returned latents cover prefix + fresh.
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.arange(s) + q_offset
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_kv, k_rope = _latent(params, x, cfg, positions)
    if latent_prefix is not None:
        c_kv = jnp.concatenate(
            [latent_prefix[0].astype(c_kv.dtype), c_kv], axis=1)
        k_rope = jnp.concatenate(
            [latent_prefix[1].astype(k_rope.dtype), k_rope], axis=1)
    skv = c_kv.shape[1]

    # Expand latent to full K/V for the flash path.
    k_nope = jnp.einsum("bsr,hrd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,hrd->bshd", c_kv, params["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, skv, h, dr))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = ops.flash_attention(
        q, k, v, causal=True, q_offset=skv - s,
        sliding_window=sliding_window,
        softmax_scale=(dn + dr) ** -0.5,
    )
    out = out.reshape(b, s, h * dv)
    return out @ params["wo"], (c_kv, k_rope)


def mla_decode(
    params,
    x,                 # [B, 1, d_model]
    cfg: ModelConfig,
    *,
    ckv_cache,         # [B, S_cache, kv_lora_rank]
    krope_cache,       # [B, S_cache, qk_rope_head_dim]
    pos,
    sliding_window: int | None = None,
):
    """Absorbed-MLA decode against the latent cache (no K/V expansion)."""
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_new, kr_new = _latent(params, x, cfg, positions)

    s_cache = ckv_cache.shape[1]
    slot = pos % s_cache if sliding_window else pos
    # masked one-hot write (shard-local on a sequence-sharded cache)
    onehot = (jnp.arange(s_cache, dtype=jnp.int32)[None, :]
              == slot[:, None])[..., None]                 # [B,S,1]
    ckv_cache = jnp.where(onehot, c_new.astype(ckv_cache.dtype), ckv_cache)
    krope_cache = jnp.where(onehot, kr_new.astype(krope_cache.dtype),
                            krope_cache)
    n_valid = jnp.minimum(pos + 1, s_cache) if sliding_window else pos + 1

    # Absorb W_UK into the query: q_abs[h] = q_nope[h] @ W_UK[h]^T.
    q_abs = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0], params["w_uk"])
    scores = jnp.einsum("bhr,bsr->bhs", q_abs, ckv_cache.astype(q_abs.dtype))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0],
                         krope_cache.astype(q_rope.dtype))
    scores = scores.astype(jnp.float32) * (dn + dr) ** -0.5
    valid = jnp.arange(s_cache)[None, None, :] < n_valid[:, None, None]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(x.dtype))
    out = jnp.einsum("bhr,hrd->bhd", ctx, params["w_uv"])
    out = out.reshape(b, 1, h * cfg.v_head_dim)
    return out @ params["wo"], ckv_cache, krope_cache
