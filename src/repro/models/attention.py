"""GQA attention: prefill (flash/chunked) + decode (paged KV cache).

The decode path consumes the block-paged KV cache -- the tensor SkyMemory
blocks, chunks and stripes.  Sliding-window decode uses the same cache as a
ring buffer (the ``long_500k`` variant for full-attention architectures).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import maybe_shard
from repro.kernels import ops
from repro.models.cache import KVC_INT8_SCALE, dequant_kvc, quant_kvc
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.rope import apply_rope

PAGE_SIZE = 128  # KV-cache page (= the paper's 128-token block)

_quant = quant_kvc
_dequant = dequant_kvc


def init_attention(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dt),
        "wo": dense_init(ks[3], (h * hd, d), dtype=dt),
    }


def _project_qkv(params, x, kv_x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    skv = kv_x.shape[1]
    k = (kv_x @ params["wk"]).reshape(b, skv, hkv, hd)
    v = (kv_x @ params["wv"]).reshape(b, skv, hkv, hd)
    return q, k, v


def attention_prefill(
    params,
    x,
    cfg: ModelConfig,
    *,
    q_offset=0,
    sliding_window: int | None = None,
    kv_x=None,
    causal: bool = True,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
):
    """Full-sequence attention.  ``kv_cache=(k_prefix, v_prefix)`` implements
    chunked prefill on top of a SkyMemory-restored prefix: fresh K/V are
    appended after the cached prefix and queries attend across both."""
    b, s, _ = x.shape
    cross = kv_x is not None
    kv_src = kv_x if cross else x
    q, k, v = _project_qkv(params, x, kv_src, cfg)
    if not cross:
        q_pos = jnp.arange(s) + q_offset
        k_pos = jnp.arange(k.shape[1]) + q_offset
        q = apply_rope(q, q_pos, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, k_pos, cfg.rope_theta, cfg.rotary_pct)
    if kv_cache is not None:
        k = jnp.concatenate([kv_cache[0].astype(k.dtype), k], axis=1)
        v = jnp.concatenate([kv_cache[1].astype(v.dtype), v], axis=1)
    out = ops.flash_attention(
        q, k, v,
        causal=causal and not cross,
        q_offset=(kv_cache[0].shape[1] if kv_cache is not None else 0)
        if not cross else 0,
        sliding_window=sliding_window,
    )
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], (k, v)


def attention_prefill_paged(
    params,
    x,                     # [R, C, d_model] one prefill chunk per row
    cfg: ModelConfig,
    *,
    k_pool,                # [N_pages, page, Hkv, hd] shared page pool
    v_pool,
    block_tables,          # [R, P] page ids of each row's slot
    q_offsets,             # [R] int32: chunk starts (absolute positions)
    n_valid,               # [R] int32: valid tokens per chunk (<= C)
):
    """A batch of prefill chunks against the shared page pool; returns
    ``(out, k_pool', v_pool')``.

    Each row's chunk K/V are scattered into its slot's pages *first*
    (per-token, so a chunk start need not be page-aligned -- the
    whole-prompt-cached replay starts one token before a block
    boundary), then the chunk's queries attend over everything valid so
    far: SkyMemory-restored pages, earlier chunks, and this chunk, all
    read in place through the block tables.  Positions past ``n_valid``
    (the padded tail of a ragged final chunk, or an all-padding batch
    row) are dropped from the write (their page id is pushed out of
    range with scatter mode ``drop``) and their outputs are garbage the
    scheduler never reads.  ``q_offsets`` / ``n_valid`` are traced
    values: one compilation per chunk-buffer shape serves every chunk of
    every admission.
    """
    r, c = x.shape[0], x.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    page = k_pool.shape[1]
    n_pages = k_pool.shape[0]
    num_tables = block_tables.shape[1]
    q_offsets = jnp.asarray(q_offsets, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)

    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    positions = q_offsets[:, None] + jnp.arange(c, dtype=jnp.int32)  # [R, C]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.rotary_pct)
    q = maybe_shard(q, "decode_qkv")
    k_new = maybe_shard(k_new, "decode_qkv")
    v_new = maybe_shard(v_new, "decode_qkv")

    row_ok = jnp.arange(c)[None, :] < n_valid[:, None]             # [R, C]
    table_idx = jnp.clip(positions // page, 0, num_tables - 1)
    page_ids = jnp.take_along_axis(block_tables, table_idx, axis=1)
    page_ids = jnp.where(row_ok, page_ids, n_pages)        # OOB -> dropped
    slots = positions % page
    int8_kvc = k_pool.dtype == jnp.int8
    if int8_kvc:
        k_new, v_new = _quant(k_new), _quant(v_new)
    k_pool = k_pool.at[page_ids, slots].set(
        k_new.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[page_ids, slots].set(
        v_new.astype(v_pool.dtype), mode="drop")
    if int8_kvc:
        k_read = _dequant(k_pool, x.dtype)
        v_read = _dequant(v_pool, x.dtype)
    else:
        k_read, v_read = k_pool, v_pool
    out = ops.chunked_prefill_paged(
        q, k_read, v_read, q_offsets + n_valid, block_tables, q_offsets,
    )
    return out.reshape(r, c, h * hd) @ params["wo"], k_pool, v_pool


def attention_decode(
    params,
    x,                     # [B, 1, d_model]
    cfg: ModelConfig,
    *,
    k_cache,               # [B, S_cache, Hkv, hd]
    v_cache,
    pos,                   # scalar int32: number of tokens already cached
    sliding_window: int | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
):
    """One-token decode over the paged cache; returns (out, k', v').

    With ``sliding_window`` the cache is a ring buffer of ``window`` slots
    (sub-quadratic memory for long_500k); RoPE is applied at the *absolute*
    position before writing, so relative phases stay correct after wrap.
    """
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    if cross_kv is not None:
        q = (x @ params["wq"]).reshape(b, 1, h, hd)[:, 0]
        k, v = cross_kv
        lengths = jnp.full((b,), k.shape[1], jnp.int32)
        out = _paged(q, k, v, lengths)
        return out.reshape(b, 1, h * hd) @ params["wo"], k_cache, v_cache

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))  # per-sequence
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    positions = pos[:, None]                              # [B,1] abs position
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.rotary_pct)
    # with TP attention projections + a model-striped cache, gather the tiny
    # q/k/v here rather than letting SPMD gather the cache
    q = maybe_shard(q, "decode_qkv")
    k_new = maybe_shard(k_new, "decode_qkv")
    v_new = maybe_shard(v_new, "decode_qkv")

    s_cache = k_cache.shape[1]
    slot = pos % s_cache if sliding_window else pos
    # Masked one-hot write: elementwise on the (possibly sequence-sharded)
    # cache, so SPMD keeps every shard local -- a scatter/DUS on a sharded
    # seq dim would force a full cache all-gather.
    onehot = (jnp.arange(s_cache, dtype=jnp.int32)[None, :]
              == slot[:, None])[..., None, None]          # [B,S,1,1]
    int8_kvc = k_cache.dtype == jnp.int8
    if int8_kvc:  # quantized KVC (paper's 8-bit memory trade-off)
        k_new, v_new = _quant(k_new), _quant(v_new)
    k_cache = jnp.where(onehot, k_new.astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(onehot, v_new.astype(v_cache.dtype), v_cache)
    n_valid = jnp.minimum(pos + 1, s_cache) if sliding_window else pos + 1
    if int8_kvc:
        k_read = _dequant(k_cache, x.dtype)
        v_read = _dequant(v_cache, x.dtype)
    else:
        k_read, v_read = k_cache, v_cache
    out = _paged(q[:, 0], k_read, v_read, n_valid.astype(jnp.int32))
    return out.reshape(b, 1, h * hd) @ params["wo"], k_cache, v_cache


def attention_decode_paged(
    params,
    x,                     # [B, 1, d_model]
    cfg: ModelConfig,
    *,
    k_pool,                # [N_pages, page, Hkv, hd] shared page pool
    v_pool,
    block_tables,          # [B, P] page ids per slot; None in contiguous mode
    lengths,               # [B] int32: tokens already cached per sequence
    contiguous: bool = False,
):
    """One-token decode against the shared page pool (continuous batching).

    Per-sequence positions are heterogeneous (slots admit mid-decode), so
    RoPE, the page write, and the attention mask are all driven by
    ``lengths``.  The new K/V is scattered into the page holding position
    ``lengths[b]`` -- pages are exclusive to a slot, so the scatter rows
    never collide (idle slots write into their own region / the scratch
    page, which the next admission overwrites).

    ``contiguous`` (slot-region pools): slot ``b`` owns pages
    ``[b*P, (b+1)*P)``, so the page id is arithmetic and attention reads
    the pool as ``[B, P, page, Hkv, hd]`` by reshape -- zero gather and no
    table on device.  Otherwise pages resolve through ``block_tables``
    (the scalar-prefetch kernel path).
    """
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim
    page = k_pool.shape[1]
    pos = jnp.asarray(lengths, jnp.int32)                  # [B]

    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    positions = pos[:, None]                               # [B,1] abs position
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.rotary_pct)
    q = maybe_shard(q, "decode_qkv")
    k_new = maybe_shard(k_new, "decode_qkv")
    v_new = maybe_shard(v_new, "decode_qkv")

    if contiguous:
        p_max = k_pool.shape[0] // b
        page_ids = jnp.arange(b, dtype=jnp.int32) * p_max + pos // page
    else:
        page_ids = jnp.take_along_axis(
            block_tables, (pos // page)[:, None], axis=1)[:, 0]  # [B]
    slots = pos % page
    int8_kvc = k_pool.dtype == jnp.int8
    if int8_kvc:
        k_new, v_new = _quant(k_new), _quant(v_new)
    k_pool = k_pool.at[page_ids, slots].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[page_ids, slots].set(v_new[:, 0].astype(v_pool.dtype))
    if int8_kvc:
        k_read = _dequant(k_pool, x.dtype)
        v_read = _dequant(v_pool, x.dtype)
    else:
        k_read, v_read = k_pool, v_pool
    if contiguous:
        hkv = k_read.shape[2]
        shape = (b, k_read.shape[0] // b, page, hkv, k_read.shape[3])
        out = ops.paged_attention(
            q[:, 0], k_read.reshape(shape), v_read.reshape(shape), pos + 1,
            grouped=True,
        )
    else:
        out = ops.paged_attention(
            q[:, 0], k_read, v_read, pos + 1, block_tables=block_tables
        )
    return out.reshape(b, 1, h * hd) @ params["wo"], k_pool, v_pool


def _paged(q, k_cache, v_cache, lengths):
    """View the contiguous cache as pages and run the paged-decode kernel."""
    b, s, hkv, hd = k_cache.shape
    page = PAGE_SIZE if s % PAGE_SIZE == 0 else s
    kp = k_cache.reshape(b, s // page, page, hkv, hd)
    vp = v_cache.reshape(b, s // page, page, hkv, hd)
    return ops.paged_attention(q, kp, vp, lengths)
