"""Decode-state containers (the tensors SkyMemory blocks and stripes).

Caches are plain dicts of arrays so they pjit/shard cleanly.  Constructors
have a ``specs_only`` mode returning ShapeDtypeStructs for the dry-run
(no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _make(shape, dtype, specs_only: bool):
    if specs_only:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: the sliding window if configured, else seq_len."""
    if cfg.sliding_window and cfg.sliding_window < seq_len:
        return cfg.sliding_window
    return seq_len


def init_cache(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    *,
    specs_only: bool = False,
    src_len: int | None = None,
):
    """Decode cache for one model family.

    dense/moe/vlm -> paged K/V; MLA -> latent; ssm -> fixed state;
    hybrid -> ssm state + K/V for the shared-attention invocations;
    audio (enc-dec) -> decoder self K/V + frozen cross K/V.
    """
    dt = jnp.dtype(cfg.kvc_dtype or cfg.dtype)
    s = cache_len(cfg, seq_len)
    cache: dict = {}

    if cfg.use_mla:
        la = cfg.num_layers
        cache["mla"] = {
            "ckv": _make((la, batch, s, cfg.kv_lora_rank), dt, specs_only),
            "kr": _make((la, batch, s, cfg.qk_rope_head_dim), dt, specs_only),
        }
    elif cfg.arch_type in ("ssm", "hybrid"):
        lm = cfg.num_layers
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm"] = {
            "conv": _make((lm, batch, cfg.ssm_conv - 1, conv_dim),
                          jnp.dtype(cfg.dtype), specs_only),
            "state": _make(
                (lm, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32, specs_only,
            ),
        }
        if cfg.arch_type == "hybrid":
            na = n_attn_layers(cfg)
            cache["kv"] = {
                "k": _make((na, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                           specs_only),
                "v": _make((na, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                           specs_only),
            }
    else:
        la = cfg.num_layers
        cache["kv"] = {
            "k": _make((la, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
            "v": _make((la, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
        }

    if cfg.is_encoder_decoder:
        ss = src_len if src_len is not None else s
        la = cfg.num_layers
        cache["cross"] = {
            "k": _make((la, batch, ss, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
            "v": _make((la, batch, ss, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
        }
    return cache


def cache_bytes(cfg: ModelConfig, batch: int, seq_len: int) -> int:
    specs = init_cache(cfg, batch, seq_len, specs_only=True)
    return sum(
        int(jnp.prod(jnp.array(leaf.shape))) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(specs)
    )
