"""Decode-state containers (the tensors SkyMemory blocks and stripes).

Two layouts:

* ``init_cache``      -- dense per-sequence caches (dict of arrays), used by
  training-side tooling and the non-paged decode families (MLA latents, SSM
  state, encoder-decoder cross K/V).  Plain pytrees so they pjit/shard
  cleanly; ``specs_only`` returns ShapeDtypeStructs for the dry-run.
* ``PagedKVCache``    -- the serving engine's device-resident page pool for
  dense-attention families.  Pages are ``page_size`` tokens (= the
  SkyMemory block size), allocated from a shared free list and addressed
  through per-slot block tables, so constellation-fetched blocks drop
  straight into pages and freed pages are recycled mid-decode
  (continuous batching).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

KVC_INT8_SCALE = 1.0 / 32.0  # symmetric int8 KVC quantization step


def quant_kvc(x):
    return jnp.clip(jnp.round(x / KVC_INT8_SCALE), -127, 127).astype(jnp.int8)


def dequant_kvc(x, dtype):
    return (x.astype(jnp.float32) * KVC_INT8_SCALE).astype(dtype)


def _make(shape, dtype, specs_only: bool):
    if specs_only:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: the sliding window if configured, else seq_len."""
    if cfg.sliding_window and cfg.sliding_window < seq_len:
        return cfg.sliding_window
    return seq_len


def init_cache(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    *,
    specs_only: bool = False,
    src_len: int | None = None,
):
    """Decode cache for one model family.

    dense/moe/vlm -> paged K/V; MLA -> latent; ssm -> fixed state;
    hybrid -> ssm state + K/V for the shared-attention invocations;
    audio (enc-dec) -> decoder self K/V + frozen cross K/V.
    """
    dt = jnp.dtype(cfg.kvc_dtype or cfg.dtype)
    s = cache_len(cfg, seq_len)
    cache: dict = {}

    if cfg.use_mla:
        la = cfg.num_layers
        cache["mla"] = {
            "ckv": _make((la, batch, s, cfg.kv_lora_rank), dt, specs_only),
            "kr": _make((la, batch, s, cfg.qk_rope_head_dim), dt, specs_only),
        }
    elif cfg.arch_type in ("ssm", "hybrid"):
        lm = cfg.num_layers
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm"] = {
            "conv": _make((lm, batch, cfg.ssm_conv - 1, conv_dim),
                          jnp.dtype(cfg.dtype), specs_only),
            "state": _make(
                (lm, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32, specs_only,
            ),
        }
        if cfg.arch_type == "hybrid":
            na = n_attn_layers(cfg)
            cache["kv"] = {
                "k": _make((na, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                           specs_only),
                "v": _make((na, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                           specs_only),
            }
    else:
        la = cfg.num_layers
        cache["kv"] = {
            "k": _make((la, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
            "v": _make((la, batch, s, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
        }

    if cfg.is_encoder_decoder:
        ss = src_len if src_len is not None else s
        la = cfg.num_layers
        cache["cross"] = {
            "k": _make((la, batch, ss, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
            "v": _make((la, batch, ss, cfg.num_kv_heads, cfg.head_dim), dt,
                       specs_only),
        }
    return cache


def supports_paged_decode(cfg: ModelConfig) -> bool:
    """True for the families whose decode state is plain per-token K/V --
    the ones the paged pool + paged-attention kernel can serve.  MLA
    latents, SSM state, encoder-decoder cross K/V, and sliding-window ring
    buffers keep the dense layout (a later PR can page the MLA latent)."""
    return (
        cfg.arch_type not in ("ssm", "hybrid")
        and not cfg.use_mla
        and not cfg.is_encoder_decoder
        and not cfg.sliding_window
    )


class PagedKVCache:
    """Shared K/V page pool + per-slot block tables (dense-attn families).

    Device state: ``k_pool`` / ``v_pool`` of shape
    ``[layers, num_pages, page_size, kv_heads, head_dim]``.  Host state:
    an int32 ``block_tables`` [slots, pages_per_seq] mapping each slot's
    logical page index to a pool page.  Two allocation modes:

    * **contiguous** (default, full-size pool): slot ``s`` permanently
      owns pages ``[s*P, (s+1)*P)``, so per layer the pool *is*
      ``[slots, P, page, Hkv, hd]`` by reshape -- decode attention reads
      it with zero gather (the contiguous paged kernel / oracle), and the
      decode write's page id is ``s*P + pos//page``, needing no table on
      device.  An idle slot's unconditional decode write lands at its own
      region's page 0, which the next admission overwrites.
    * **free-list** (explicit ``num_pages``, e.g. oversubscribed pools):
      pages come from a shared free list; page 0 is a reserved scratch
      page that idle slots' rows point at; attention goes through the
      block-table (scalar-prefetch) kernel path.

    The pool arrays are replaced functionally (the jitted decode step
    returns updated pools; the engine donates them so backends update in
    place); the allocator is host-side bookkeeping only.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        num_slots: int,
        page_size: int,
        max_seq_len: int,
        num_pages: int | None = None,
    ) -> None:
        if not supports_paged_decode(cfg):
            raise ValueError(f"{cfg.name}: family has no paged decode layout")
        self.cfg = cfg
        self.page_size = page_size
        self.num_slots = num_slots
        self.pages_per_seq = -(-max_seq_len // page_size)
        self.contiguous = num_pages is None
        if self.contiguous:
            self.num_pages = num_slots * self.pages_per_seq
        else:
            self.num_pages = num_pages
            if self.num_pages < 1 + self.pages_per_seq:
                raise ValueError("pool smaller than one sequence")
        self.dtype = jnp.dtype(cfg.kvc_dtype or cfg.dtype)
        shape = (cfg.num_layers, self.num_pages, page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        self.k_pool = jnp.zeros(shape, self.dtype)
        self.v_pool = jnp.zeros(shape, self.dtype)
        p = self.pages_per_seq
        if self.contiguous:
            self._free = []
            self.block_tables = np.asarray(
                [[s * p + j for j in range(p)] for s in range(num_slots)],
                np.int32)
            self._slot_pages = [list(row) for row in self.block_tables]
            self._slot_free = [True] * num_slots
        else:
            # page 0 reserved as scratch -- never on the free list
            self._free = list(range(self.num_pages - 1, 0, -1))
            self.block_tables = np.zeros((num_slots, p), np.int32)
            self._slot_pages = [[] for _ in range(num_slots)]
        # partial-prefill write cursor: tokens of the slot's sequence
        # covered by pages so far (restored blocks + retired chunks) --
        # chunked prefill advances it span by span, and span bookkeeping
        # rejects gaps/overlap bugs before they corrupt the pool
        self.cursors = [0] * num_slots

    # -- allocator ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        if self.contiguous:
            return sum(self._slot_free) * self.pages_per_seq
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        """Enough free pages to reserve ``n_tokens`` tokens up front.

        The engine reserves a sequence's *worst-case* footprint (prompt +
        max_new_tokens, capped at max_seq_len) at admission, so a running
        sequence can never hit pool exhaustion mid-decode -- an admitted
        request always completes.  Unused reserved pages return to the
        pool at release (early EOS)."""
        if self.contiguous:
            return (any(self._slot_free)
                    and self.pages_for(n_tokens) <= self.pages_per_seq)
        return len(self._free) >= self.pages_for(n_tokens)

    def ensure_capacity(self, slot: int, n_tokens: int) -> bool:
        """Allocate pages until ``slot`` can hold ``n_tokens`` tokens.
        Returns True when the block table changed (caller re-uploads it)."""
        need = self.pages_for(n_tokens)
        if need > self.pages_per_seq:
            raise RuntimeError(
                f"slot {slot}: {n_tokens} tokens exceeds "
                f"{self.pages_per_seq} pages per sequence")
        if self.contiguous:
            self._slot_free[slot] = False
            return False                 # fixed region: table never changes
        pages = self._slot_pages[slot]
        changed = False
        while len(pages) < need:
            if not self._free:
                raise RuntimeError("KV page pool exhausted")
            pid = self._free.pop()
            self.block_tables[slot, len(pages)] = pid
            pages.append(pid)
            changed = True
        return changed

    def pages_allocated(self, slot: int) -> int:
        """Pages currently backing ``slot`` (contiguous regions always own
        their full span; free-list slots grow lazily)."""
        if self.contiguous:
            return self.pages_per_seq
        return len(self._slot_pages[slot])

    def export_pages(self, slot: int, n_pages: int):
        """Offload view: the slot's first ``n_pages`` pages as host arrays
        ``[layers, n_pages, page_size, kv_heads, head_dim]``.

        ONE gathered device read per pool (then a single device->host
        transfer each), not a round trip per page -- the export half of
        preemption-by-offload, where a victim sequence's K/V moves to the
        host tier so its pool pages can be reassigned.  ``write_pages`` is
        the exact inverse; an export/import round trip is bit-identical
        (int8 pools move as raw int8)."""
        ids = self._slot_pages[slot][:n_pages]
        if len(ids) != n_pages:
            raise RuntimeError(
                f"slot {slot}: export of {n_pages} pages exceeds "
                f"{len(ids)} allocated")
        idx = jnp.asarray(ids, jnp.int32)
        return (np.asarray(self.k_pool[:, idx]),
                np.asarray(self.v_pool[:, idx]))

    def free_slot(self, slot: int) -> None:
        """Return the slot's pages to the pool (free-list mode repoints
        the slot at the scratch page)."""
        self.cursors[slot] = 0
        if self.contiguous:
            self._slot_free[slot] = True
            return
        self._free.extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.block_tables[slot, :] = 0

    # -- partial-prefill write cursors ----------------------------------
    def table_row(self, slot: int) -> np.ndarray:
        """The slot's block-table row [pages_per_seq] -- what a chunked
        prefill uploads so the chunk can resolve its own page ids on
        device (contiguous mode rows are the arithmetic region ids)."""
        return self.block_tables[slot]

    def note_span(self, slot: int, start: int, n_tokens: int) -> None:
        """Record that tokens ``[start, start + n_tokens)`` of the slot's
        sequence are now (being) written to its pages -- the device-side
        chunk scatter does the actual write.  Rewriting already-covered
        positions is allowed (the whole-prompt-cached replay recomputes
        the final token in place); a *gap* past the cursor is a scheduler
        bug and raises before the pool is corrupted."""
        if start > self.cursors[slot]:
            raise RuntimeError(
                f"slot {slot}: span start {start} leaves a gap past write "
                f"cursor {self.cursors[slot]}")
        end = start + n_tokens
        if self.pages_for(end) > len(self._slot_pages[slot]):
            raise RuntimeError(
                f"slot {slot}: span end {end} beyond allocated pages")
        self.cursors[slot] = max(self.cursors[slot], end)

    # -- page writes (host side, outside the jitted step) ---------------
    def write_pages(self, slot: int, first_page: int, k_blocks, v_blocks):
        """Drop whole pages into the pool: ``k_blocks``/``v_blocks`` are
        ``[layers, n_pages, page_size, kv_heads, head_dim]`` -- e.g. blocks
        fetched from the constellation, already page-shaped.  No dense
        restacking: one scatter per pool array."""
        n = k_blocks.shape[1]
        ids = jnp.asarray(
            self._slot_pages[slot][first_page:first_page + n], jnp.int32)
        if ids.shape[0] != n:
            raise RuntimeError("write_pages beyond allocated pages")
        k_blocks, v_blocks = self._cast(k_blocks), self._cast(v_blocks)
        self.k_pool = self.k_pool.at[:, ids].set(k_blocks)
        self.v_pool = self.v_pool.at[:, ids].set(v_blocks)
        self.cursors[slot] = max(self.cursors[slot],
                                 (first_page + n) * self.page_size)

    def write_token_span(self, slot: int, start: int, k, v):
        """Write ``k``/``v`` ``[layers, n_tokens, kv_heads, head_dim]`` at
        token offset ``start`` (must be page-aligned: spans start where a
        fetched-block prefix ended).  The tail is zero-padded to a page
        boundary; the per-sequence length masks it."""
        if start % self.page_size:
            raise ValueError("span start must be page-aligned")
        la, n, hkv, hd = k.shape
        pad = (-n) % self.page_size
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nb = k.shape[1] // self.page_size
        shape = (la, nb, self.page_size, hkv, hd)
        self.write_pages(slot, start // self.page_size,
                         k.reshape(shape), v.reshape(shape))
        self.cursors[slot] = start + n   # the padded tail is not real data

    def _cast(self, x):
        x = jnp.asarray(x)
        if self.dtype == jnp.int8 and x.dtype != jnp.int8:
            return quant_kvc(x)
        return x.astype(self.dtype)


def cache_bytes(cfg: ModelConfig, batch: int, seq_len: int) -> int:
    specs = init_cache(cfg, batch, seq_len, specs_only=True)
    return sum(
        int(jnp.prod(jnp.array(leaf.shape))) * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(specs)
    )
