"""Rotary position embeddings with partial-rotary support (stablelm)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0):
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, theta: float, rotary_pct: float = 1.0):
    """x: [B, S, H, D]; positions: [S] or [B, S] absolute positions."""
    d = x.shape[-1]
    inv, rot_dim = rope_freqs(d, theta, rotary_pct)
    if rot_dim == 0:
        return x
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[..., None] * inv[None, None, :]        # [B, S, rot/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rotated, xp], axis=-1).astype(x.dtype)
