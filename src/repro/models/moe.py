"""Mixture-of-Experts with capacity-based dispatch (shardable dense einsums).

Top-k routing with per-group capacity: tokens are processed in fixed groups;
each expert accepts at most C = ceil(k * group / E * capacity_factor) tokens
per group and overflow tokens fall back to the residual path (standard
"dropping" MoE, MaxText-style).  Dispatch/combine are one-hot einsums, so
XLA shards them cleanly: experts ride the ``model`` mesh axis (expert
parallelism), groups ride ``data``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.distributed.sharding import maybe_shard

# "all": constrain dispatch + expert tensors; "io": expert tensors only
# (skips resharding the big one-hot dispatch tensor); "none": no constraints.
_MOE_SHARD_MODE = os.environ.get("REPRO_MOE_SHARD", "all")
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def moe_capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # >=4, rounded up to a multiple of 4


def init_moe(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "wi_gate": dense_init(ks[1], (e, d, f), in_axis_size=d, dtype=dt),
        "wi_up": dense_init(ks[2], (e, d, f), in_axis_size=d, dtype=dt),
        "wo": dense_init(ks[3], (e, f, d), in_axis_size=f, dtype=dt),
    }
    if cfg.num_shared_experts:
        fs = cfg.expert_d_ff * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": dense_init(kk[0], (d, fs), dtype=dt),
            "wi_up": dense_init(kk[1], (d, fs), dtype=dt),
            "wo": dense_init(kk[2], (fs, d), dtype=dt),
        }
    return p


def moe_forward(params, x, cfg: ModelConfig):
    """x: [B, S, D] -> (y, aux_loss).  Works for S=1 decode too."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    g = min(cfg.moe_group_size, t)
    pad = (-t) % g
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    ng = tokens.shape[0] // g
    xt = tokens.reshape(ng, g, d)

    logits = (xt.astype(jnp.float32) @ params["router"])          # [G,g,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # [G,g,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # token->expert weight matrix and membership mask
    onehot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)          # [G,g,k,E]
    combine = jnp.einsum("gtke,gtk->gte", onehot, top_p)          # [G,g,E]
    member = onehot.sum(2)                                        # [G,g,E] 0/1

    # capacity assignment: position of each token within its expert's buffer
    cap = moe_capacity(cfg, g)
    position = jnp.cumsum(member, axis=1) - 1.0                   # [G,g,E]
    keep = (position < cap) & (member > 0)
    disp = jax.nn.one_hot(position.astype(jnp.int32), cap,
                          dtype=x.dtype) * keep[..., None]        # [G,g,E,C]
    if _MOE_SHARD_MODE == "all":
        disp = maybe_shard(disp, "moe_dispatch")

    expert_in = jnp.einsum("gtec,gtd->gecd", disp, xt)            # [G,E,C,D]
    if _MOE_SHARD_MODE != "none":
        expert_in = maybe_shard(expert_in, "moe_expert")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, params["wi_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"])    # [G,E,C,D]
    if _MOE_SHARD_MODE != "none":
        expert_out = maybe_shard(expert_out, "moe_expert")

    y = jnp.einsum("gtec,gte,gecd->gtd", disp,
                   combine.astype(x.dtype), expert_out)           # [G,g,D]
    y = y.reshape(-1, d)
    if pad:
        y = y[:t]
    y = y.reshape(b, s, d)

    if cfg.num_shared_experts:
        sp = params["shared"]
        hs = jax.nn.silu(x @ sp["wi_gate"]) * (x @ sp["wi_up"])
        y = y + hs @ sp["wo"]

    # Switch-style load-balance aux loss + router z-loss.
    frac_tokens = jnp.mean(member, axis=1)                        # [G,E]
    frac_probs = jnp.mean(probs, axis=1)                          # [G,E]
    balance = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = cfg.router_aux_coef * balance + 1e-3 * z
    return y, aux
