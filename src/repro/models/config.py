"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0     # 0 -> MHA (== num_heads)
    head_dim: int = 0         # 0 -> d_model // num_heads

    # block flavor
    mlp_type: str = "swiglu"          # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0           # stablelm: partial rotary
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (0 -> d_ff)
    first_k_dense: int = 0            # deepseek: leading dense layers
    router_aux_coef: float = 0.01
    moe_group_size: int = 1024        # dispatch group (capacity einsum)
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    attn_layer_period: int = 0        # hybrid: shared attn every k layers

    # MLA (DeepSeek-V3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0                # multi-token-prediction heads

    # encoder-decoder (audio)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # modality frontend stubs
    num_image_tokens: int = 0         # vlm: anyres patch-embedding count
    frontend: str = "none"            # none | vision | audio

    # decode variants
    sliding_window: int = 0           # 0 = full attention
    kvc_dtype: str = ""               # "" = model dtype; "int8" = quantized
                                      # KVC (paper §3.3/§5 8-bit trade-off)
    notes: str = ""
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.num_kv_heads == 0 and self.num_heads:
            object.__setattr__(self, "num_kv_heads", self.num_heads)
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def is_attn_layer(self, layer_idx: int) -> bool:
        """Hybrid (zamba2-style): a shared attention block fires every
        ``attn_layer_period`` layers; pure SSM never; others always."""
        if self.arch_type == "ssm":
            return False
        if self.arch_type == "hybrid":
            return self.attn_layer_period > 0 and (
                layer_idx % self.attn_layer_period == self.attn_layer_period - 1
            )
        return True

    def is_moe_layer(self, layer_idx: int) -> bool:
        return self.num_experts > 0 and layer_idx >= self.first_k_dense

    # -- parameter / cache accounting (used by roofline + docs) ----------
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for layer in range(self.num_layers):
            total += self._layer_params(layer)
        if self.arch_type == "hybrid" and self.attn_layer_period:
            total += self._attn_params()  # one shared block
        if self.is_encoder_decoder:
            for _ in range(self.num_encoder_layers):
                total += self._attn_params() + self._mlp_params(self.d_ff)
            total += self.num_layers * self._attn_params()  # cross-attn
        if self.mtp_depth:
            total += self.mtp_depth * (
                self._layer_params(self.num_layers - 1) + 2 * d * d
            )
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed top-k + shared)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        dense = self.param_count()
        moe_layers = sum(
            1 for i in range(self.num_layers) if self.is_moe_layer(i)
        )
        all_experts = moe_layers * self.num_experts * self._expert_params()
        active_experts = moe_layers * (
            (self.num_experts_per_tok + self.num_shared_experts)
            * self._expert_params()
        )
        return dense - all_experts + active_experts

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            q = d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                self.qk_nope_head_dim + self.qk_rope_head_dim
            )
            kv = d * (self.kv_lora_rank + self.qk_rope_head_dim)
            kv += self.kv_lora_rank * self.num_heads * (
                self.qk_nope_head_dim + self.v_head_dim
            )
            o = self.num_heads * self.v_head_dim * d
            return q + kv + o
        h, hkv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        return d * h * hd + 2 * d * hkv * hd + h * hd * d

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.mlp_type == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _expert_params(self) -> int:
        return self._mlp_params(self.expert_d_ff) // 1

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, n = self.ssm_groups, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * g * n + h)
        conv = (di + 2 * g * n) * self.ssm_conv
        return in_proj + conv + 2 * h + di + di * d  # A_log, D, norm, out

    def _layer_params(self, layer_idx: int) -> int:
        d = self.d_model
        total = 2 * d  # two norms
        if self.arch_type in ("ssm", "hybrid"):
            total += self._ssm_params()
        else:
            total += self._attn_params()
        if self.arch_type not in ("ssm", "hybrid"):
            if self.is_moe_layer(layer_idx):
                total += self.num_experts * self._expert_params()
                total += self.num_shared_experts * self._expert_params()
                total += d * self.num_experts  # router
            else:
                total += self._mlp_params(self.d_ff)
        return total

    def kv_cache_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """Per-token decode-state footprint (the object SkyMemory chunks)."""
        if self.arch_type == "ssm":
            return 0  # fixed-size state, not per-token
        if self.use_mla:
            per = self.kv_lora_rank + self.qk_rope_head_dim
            return self.num_layers * per * bytes_per_el
        n_attn = sum(
            1 for i in range(self.num_layers) if self.is_attn_layer(i)
        )
        return n_attn * 2 * self.num_kv_heads * self.head_dim * bytes_per_el

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
