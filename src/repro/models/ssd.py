"""Mamba-2 block (SSD / state-space duality, arXiv:2405.21060).

Prefill runs the chunked SSD scan (Pallas kernel on TPU, jnp oracle on CPU);
decode is the O(1)-per-token state recurrence.  The decode state
(conv_state, ssm_state) is a *fixed-size* snapshot -- for SSM architectures
this snapshot is the "KV cache block" SkyMemory stores (DESIGN.md §4).

Projections are kept as separate weights (wz/wx/wb/wc/wdt instead of one
fused in_proj) so the tensor-parallel axis cuts clean head boundaries:
wz/wx shard the inner dim over ``model``; the small B/C/dt projections stay
replicated (they are shared across heads within a group anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm_gated


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    return di, g, n, h, p


def init_ssd(key, cfg: ModelConfig):
    d = cfg.d_model
    di, g, n, h, p = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    dt_min, dt_max = 1e-3, 0.1
    dt_init = jnp.exp(
        jax.random.uniform(ks[8], (h,)) * (jnp.log(dt_max) - jnp.log(dt_min))
        + jnp.log(dt_min)
    )
    return {
        "wz": dense_init(ks[0], (d, di), dtype=dt),
        "wx": dense_init(ks[1], (d, di), dtype=dt),
        "wb": dense_init(ks[2], (d, g * n), dtype=dt),
        "wc": dense_init(ks[3], (d, g * n), dtype=dt),
        "wdt": dense_init(ks[4], (d, h), dtype=dt),
        "conv_x_w": dense_init(ks[5], (cfg.ssm_conv, di),
                               in_axis_size=cfg.ssm_conv, dtype=dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_w": dense_init(ks[6], (cfg.ssm_conv, 2 * g * n),
                                in_axis_size=cfg.ssm_conv, dtype=dt),
        "conv_bc_b": jnp.zeros((2 * g * n,), dt),
        "a_log": jnp.log(jax.random.uniform(ks[9], (h,), minval=1.0, maxval=16.0)),
        "dt_bias": dt_init + jnp.log(-jnp.expm1(-dt_init)),  # inv softplus
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[7], (di, d), dtype=dt),
    }


def _causal_conv(u, w, b, seqlen):
    """Depthwise causal conv, unrolled over the (small) kernel width."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(up[:, j : j + seqlen] * w[j] for j in range(k))
    return out + b


def ssd_prefill(params, x, cfg: ModelConfig, *, state=None):
    """x: [B, L, D] -> (out, (conv_state, ssm_state)).

    ``state``: optional {"conv": [B,K-1,di+2gn], "state": [B,H,P,N]} restored
    from a SkyMemory snapshot -- resumes mid-sequence without rescanning the
    cached prefix.
    """
    bsz, seqlen, _ = x.shape
    di, g, n, h, p = _dims(cfg)
    z = x @ params["wz"]
    xin = x @ params["wx"]
    bc = jnp.concatenate([x @ params["wb"], x @ params["wc"]], axis=-1)
    dt = x @ params["wdt"]

    conv_in_x, conv_in_bc = xin, bc
    ssm_state0 = None
    if state is not None:
        tail = state["conv"]  # [B, K-1, di+2gn]
        ssm_state0 = state["state"]
        conv_in_x = jnp.concatenate([tail[..., :di].astype(xin.dtype), xin], 1)
        conv_in_bc = jnp.concatenate([tail[..., di:].astype(bc.dtype), bc], 1)
        cx = _causal_conv(conv_in_x, params["conv_x_w"], params["conv_x_b"],
                          conv_in_x.shape[1])[:, tail.shape[1]:]
        cbc = _causal_conv(conv_in_bc, params["conv_bc_w"], params["conv_bc_b"],
                           conv_in_bc.shape[1])[:, tail.shape[1]:]
    else:
        cx = _causal_conv(xin, params["conv_x_w"], params["conv_x_b"], seqlen)
        cbc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], seqlen)
    cx = jax.nn.silu(cx)
    cbc = jax.nn.silu(cbc)

    xh = cx.reshape(bsz, seqlen, h, p)
    b_mat = cbc[..., : g * n].reshape(bsz, seqlen, g, n)
    c_mat = cbc[..., g * n :].reshape(bsz, seqlen, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    chunk = min(cfg.ssm_chunk, seqlen)
    pad = (-seqlen) % chunk
    if pad:
        # zero-pad to a chunk multiple; dt=0 on padded steps keeps the
        # state recurrence exact (decay exp(0)=1, update 0).
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, ssm_state = ops.ssd_scan(
        xh, dt, -jnp.exp(params["a_log"]), b_mat, c_mat,
        chunk_size=chunk, initial_state=ssm_state0,
    )
    if pad:
        y = y[:, :seqlen]
        xh = xh[:, :seqlen]
    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, seqlen, di)
    y = rms_norm_gated(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]

    # pre-conv tails for decode resumption (= the cacheable snapshot)
    k1 = cfg.ssm_conv - 1
    conv_state = jnp.concatenate([xin[:, -k1:], bc[:, -k1:]], axis=-1)
    return out, {"conv": conv_state, "state": ssm_state}


def ssd_decode(params, x, cfg: ModelConfig, *, conv_state, ssm_state):
    """x: [B, 1, D]; O(1) recurrence. Returns (out, conv_state, ssm_state)."""
    bsz = x.shape[0]
    di, g, n, h, p = _dims(cfg)
    xt = x[:, 0]
    z = xt @ params["wz"]
    xin = xt @ params["wx"]
    bc = jnp.concatenate([xt @ params["wb"], xt @ params["wc"]], axis=-1)
    dt = xt @ params["wdt"]

    new_in = jnp.concatenate([xin, bc], axis=-1)                 # [B, C]
    window = jnp.concatenate(
        [conv_state.astype(new_in.dtype), new_in[:, None]], axis=1
    )                                                            # [B, K, C]
    wx = window[..., :di]
    wbc = window[..., di:]
    cx = jnp.einsum("bkc,kc->bc", wx, params["conv_x_w"]) + params["conv_x_b"]
    cbc = jnp.einsum("bkc,kc->bc", wbc, params["conv_bc_w"]) + params["conv_bc_b"]
    cx = jax.nn.silu(cx)
    cbc = jax.nn.silu(cbc)
    new_conv_state = window[:, 1:]

    xh = cx.reshape(bsz, h, p)
    bv = cbc[:, : g * n].reshape(bsz, g, n)
    cv = cbc[:, g * n :].reshape(bsz, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]

    y, new_ssm = ops.ssd_decode_step(
        xh, dt, -jnp.exp(params["a_log"]), bv, cv, ssm_state
    )
    y = y + params["d_skip"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, di)
    y = rms_norm_gated(y, z, params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, new_conv_state, new_ssm
