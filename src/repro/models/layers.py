"""Shared building blocks: initializers, norms, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis_size: int | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (kept in fp32; cast at use)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, shape=None):
    d = shape if shape is not None else cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    return y.astype(x.dtype)


def rms_norm_gated(x, z, scale, eps: float = 1e-5):
    """Mamba-2 gated RMSNorm: norm(x * silu(z)) * scale."""
    x32 = (x * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], (d, f), dtype=dt),
            "wi_up": dense_init(ks[1], (d, f), dtype=dt),
            "wo": dense_init(ks[2], (f, d), dtype=dt),
        }
    return {
        "wi": dense_init(ks[0], (d, f), dtype=dt),
        "wo": dense_init(ks[1], (f, d), dtype=dt),
    }


def apply_mlp(params, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])
    elif cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ params["wi"]))
    else:  # gelu
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding.
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p = {"tok": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                           in_axis_size=cfg.d_model, dtype=dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dt)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return x @ params["tok"].T
    return x @ params["unembed"]


def cross_entropy_loss(logits, targets, mask=None, z_loss_coef: float = 0.0):
    """Mean token cross-entropy in fp32 (+ optional logit z-loss).

    The gold logit is picked with a one-hot contraction rather than
    take_along_axis: with a vocab-sharded logits tensor the contraction
    reduces over the sharded axis (a scalar-per-token all-reduce) instead
    of forcing an all-gather of the full logits.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if z_loss_coef:
        nll = nll + z_loss_coef * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
