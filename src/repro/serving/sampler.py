"""Token sampling: greedy / temperature / top-k / top-p.

Two entry points:

* ``sample_batch`` -- fully vectorized over heterogeneous per-sequence
  parameters (temperature/top-k/top-p stacked into [B] arrays).  This is
  the serving hot path: the engine jits it *fused with the decode step*,
  so one device program per token produces the next token ids for every
  slot -- no per-sequence Python loop, no per-sequence host sync.
* ``sample``       -- the original per-request API (uniform params),
  now a thin wrapper over ``sample_batch``.

Disabled filters are encoded as identities rather than branches so one
compiled program covers any parameter mix: ``top_k == 0`` selects the
V-th largest as the threshold (keeps everything) and ``top_p >= 1`` sets
the cumulative-probability cutoff past 1 (never reached).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 -> greedy
    top_k: int = 0             # 0 -> disabled
    top_p: float = 1.0
    max_new_tokens: int = 32


def stack_sampling(params: list[SamplingParams], pad_to: int | None = None):
    """Stack per-sequence params into the [B] arrays ``sample_batch`` takes.

    Padding rows (inactive slots) are greedy: argmax is the cheapest path
    and their output is masked by the scheduler anyway.
    """
    n = pad_to if pad_to is not None else len(params)
    temps = [0.0] * n
    top_ks = [0] * n
    top_ps = [1.0] * n
    for i, p in enumerate(params):
        temps[i], top_ks[i], top_ps[i] = p.temperature, p.top_k, p.top_p
    return (
        jnp.asarray(temps, jnp.float32),
        jnp.asarray(top_ks, jnp.int32),
        jnp.asarray(top_ps, jnp.float32),
    )


def sample_batch(
    logits: jax.Array,          # [B, V]
    key,
    temperature: jax.Array,     # [B] float32; <= 0 -> greedy
    top_k: jax.Array,           # [B] int32;   0 -> disabled
    top_p: jax.Array,           # [B] float32; >= 1 -> disabled
) -> jax.Array:
    """Vectorized sampling with per-row parameters -> token ids [B]."""
    v = logits.shape[-1]
    lg32 = logits.astype(jnp.float32)
    greedy_ids = jnp.argmax(lg32, axis=-1).astype(jnp.int32)

    is_greedy = temperature <= 0.0
    temp = jnp.where(is_greedy, 1.0, temperature)[:, None]
    lg = lg32 / temp

    # top-k: threshold at the k-th largest (k=0 -> V-th largest = min).
    k_eff = jnp.where(top_k <= 0, v, jnp.clip(top_k, 1, v))     # [B]
    sorted_desc = jnp.sort(lg, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_desc, (k_eff - 1)[:, None], axis=-1)
    lg = jnp.where(lg < kth, -jnp.inf, lg)

    # top-p on the (already top-k-masked) logits, matching the sequential
    # semantics: keep the smallest prefix of the sorted distribution whose
    # cumulative probability reaches p.  Top-k masking only removes a
    # descending-sorted *suffix*, so the masked sort is the original sort
    # with positions >= k set to -inf -- no second O(V log V) sort.
    sorted_masked = jnp.where(
        jnp.arange(v)[None, :] < k_eff[:, None], sorted_desc, -jnp.inf)
    probs = jax.nn.softmax(sorted_masked, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    p_eff = jnp.where(top_p >= 1.0, 2.0, top_p)[:, None]        # 2 -> never
    cutoff_idx = jnp.sum(csum < p_eff, axis=-1, keepdims=True)
    cutoff_idx = jnp.minimum(cutoff_idx, v - 1)
    cutoff = jnp.take_along_axis(sorted_masked, cutoff_idx, axis=-1)
    lg = jnp.where(lg < cutoff, -jnp.inf, lg)

    sampled = jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    return jnp.where(is_greedy, greedy_ids, sampled)


def sample(logits: jax.Array, key, params: SamplingParams) -> jax.Array:
    """logits: [B, V] -> token ids [B] (uniform params across the batch)."""
    b = logits.shape[0]
    return sample_batch(
        logits, key,
        jnp.full((b,), params.temperature, jnp.float32),
        jnp.full((b,), params.top_k, jnp.int32),
        jnp.full((b,), params.top_p, jnp.float32),
    )
