"""StreamWorker: the long-lived worker loop behind ``Engine.submit``.

Owns every piece of streaming state -- the daemon thread, the stop/wake
events, the no-drain flag, and the dense micro-batching inbox -- so the
``Engine`` facade stays pure orchestration.  The central invariant is
*single-writer queue ownership*: the scheduler's deques (and the dense
inbox) are mutated only by whichever thread is servicing them.  That is
the worker thread while it runs, and the caller's thread in threadless
``pump()`` mode.  Consequently ``stop(drain=False)`` never cancels from
the caller: it raises a one-shot flag and the worker sheds its own queue
at the top of the next loop iteration (or, when no worker was ever
started, the cancellation runs inline because the caller *is* the
servicing thread).
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future, InvalidStateError

from repro.serving.request import Request


class StreamWorker:
    """Streaming front door for one ``Engine`` (paged or dense)."""

    def __init__(self, engine) -> None:
        self.engine = engine
        self._thread: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        self._error: BaseException | None = None
        self._drain_on_stop = True
        # non-paged families stream by micro-batching through the dense
        # runtime: queued (request, future) pairs the worker drains
        self._dense_inbox: deque[tuple[Request, Future]] = deque()

    # ------------------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def backlog(self) -> bool:
        """Anything submitted but not yet finished."""
        if self.engine.paged:
            return self.engine.scheduler.backlog
        return bool(self._dense_inbox)

    def submit(self, request: Request) -> Future:
        """Enqueue one request on the live stream; resolves to its
        ``GenerationResult``.  Thread-safe.  The worker loop (if started)
        or explicit ``pump()`` calls do the stepping."""
        if self._stop_evt.is_set() and self.running:
            raise RuntimeError("engine is stopping; submit refused")
        if self._error is not None:
            raise RuntimeError("engine worker died") from self._error
        if self.engine.paged:
            fut = self.engine.scheduler.submit(request)
        else:
            fut = Future()
            self._dense_inbox.append((request, fut))
        self._wake.set()
        return fut

    def pump(self) -> bool:
        """One servicing round, inline on the caller's thread: the
        deterministic-interleave alternative to ``start()`` (clusters
        round-robin ``pump`` across replicas for reproducible runs).
        Returns whether backlog remains."""
        if self.engine.paged:
            return self.engine.scheduler.service()
        if self._dense_inbox:
            batch: list[tuple[Request, Future]] = []
            while self._dense_inbox:
                batch.append(self._dense_inbox.popleft())
            try:
                results = self.engine._dense.generate([r for r, _ in batch])
            except BaseException as e:
                for _, fut in batch:
                    try:
                        fut.set_exception(e)
                    except InvalidStateError:
                        pass
                raise
            for (_, fut), res in zip(batch, results):
                try:
                    fut.set_result(res)
                except InvalidStateError:
                    pass
        return bool(self._dense_inbox)

    def start(self) -> None:
        """Start the long-lived worker loop: it steps while the queue
        drains, idles when empty, and exits via ``stop()``.  Idempotent."""
        if self.running:
            return
        self._stop_evt.clear()
        self._wake.clear()
        self._error = None
        self._drain_on_stop = True
        self._thread = threading.Thread(
            target=self._loop, name="engine-worker", daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True) -> None:
        """Stop the worker loop.  ``drain=True`` (default) finishes every
        submitted request first; ``drain=False`` cancels queued-but-
        unstarted requests and finishes only what is already on the
        machine.  The cancellation itself runs on whichever thread owns
        the scheduler's queues: inline when no worker is running, inside
        the worker loop otherwise."""
        if not self.running:
            if not drain:
                self._cancel_queued()
            return
        self._drain_on_stop = drain
        self._stop_evt.set()
        self._wake.set()
        self._thread.join()
        self._thread = None
        if self._error is not None:
            raise RuntimeError("engine worker died") from self._error

    # ------------------------------------------------------------------
    def _cancel_queued(self) -> None:
        if self.engine.paged:
            self.engine.scheduler.cancel_queued()
            return
        kept: list[tuple[Request, Future]] = []
        while self._dense_inbox:
            r, fut = self._dense_inbox.popleft()
            if not fut.cancel():
                kept.append((r, fut))
        self._dense_inbox.extend(kept)

    def _loop(self) -> None:
        try:
            while True:
                if self._stop_evt.is_set() and not self._drain_on_stop:
                    # no-drain stop: shed the queue once (on this
                    # thread -- it owns the scheduler's queues), then
                    # fall through to finish what is on the machine
                    self._cancel_queued()
                    self._drain_on_stop = True
                busy = self.pump()
                if busy:
                    continue
                if self._stop_evt.is_set():
                    if not self.backlog:   # late submits still drain
                        break
                    continue
                # idle: settle pending Set KVC, then sleep until work
                if self.engine.paged:
                    self.engine.kv.drain_write_back()
                self._wake.wait(0.005)
                self._wake.clear()
            if self.engine.paged:
                self.engine.kv.drain_write_back()
        except BaseException as e:       # pragma: no cover - crash path
            self._error = e
            if self.engine.paged:
                self.engine.scheduler.fail_all(e)
            else:
                while self._dense_inbox:
                    _, fut = self._dense_inbox.popleft()
                    try:
                        fut.set_exception(e)
                    except InvalidStateError:
                        pass
