"""Seeded open-ended traffic: the arrival processes that drive streaming.

A production cluster never sees a closed batch; it sees *processes* --
steady Poisson request streams, diurnal load swings, and bursty tenants
whose requests arrive in correlated clumps with shared document
prefixes (the paper's repeated-context workload, CELESTIAL's continuous
operation).  This module generates those streams deterministically from
a seed:

* ``TenantSpec`` describes one tenant: its arrival process (``poisson``
  / ``diurnal`` / ``bursty``), rate, prompt-length distribution,
  prefix-reuse probability over a per-tenant document pool, decode
  length, and scheduling priority (the SLO tier).
* ``TrafficGenerator`` merges every tenant's stream into one
  time-ordered iterator of ``Arrival(t_s, tenant, Request)`` -- open
  ended (generate as much as you consume), with ``take(n)`` /
  ``until(t_end)`` for bounded slices.

Times are *virtual* seconds on the fabric clock; the cluster's
streaming front door paces wall time by the clock rate.  Every draw --
inter-arrival gaps, burst sizes, prompt lengths, document choices --
comes from per-tenant ``random.Random`` instances seeded from strings
(CPython hashes string seeds with sha512, independent of
``PYTHONHASHSEED``), so the same seed yields the same
``(arrival_time, tenant, prompt)`` stream in any process.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass
from typing import Iterator, Sequence

from repro.serving.request import Request
from repro.serving.sampler import SamplingParams


@dataclass(frozen=True)
class Arrival:
    """One request's arrival on the stream (virtual seconds)."""

    t_s: float
    tenant: str
    request: Request


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic model (all times in virtual seconds)."""

    name: str
    rate_rps: float                   # mean arrivals per second
    process: str = "poisson"          # "poisson" | "diurnal" | "bursty"
    # bursty: bursts arrive as Poisson at rate/burst_size, each carrying
    # a geometric number of requests (mean burst_size) spaced ~spread
    burst_size: int = 4
    burst_spread_s: float = 0.02
    # diurnal: lam(t) = rate * (1 + amplitude * sin(2*pi*t/period)),
    # realized by thinning a homogeneous process at the peak rate
    diurnal_period_s: float = 60.0
    diurnal_amplitude: float = 0.8
    # prompts: uniform char-length range (the byte tokenizer maps chars
    # ~1:1 to tokens); with probability prefix_reuse_p the prompt opens
    # with one of the tenant's shared documents (cache-friendly prefix)
    prompt_chars: tuple[int, int] = (48, 160)
    prefix_reuse_p: float = 0.0
    num_documents: int = 4
    doc_chars: int = 96
    max_new_tokens: int = 16
    priority: int = 0                 # Request.priority (SLO tier)

    def __post_init__(self) -> None:
        # fail loudly at construction, not corruptly at draw time:
        if not self.rate_rps > 0.0:
            raise ValueError(
                f"TenantSpec {self.name!r}: rate_rps must be > 0 (got "
                f"{self.rate_rps}); a zero/negative rate would raise "
                "from inside expovariate on the first draw")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError(
                f"TenantSpec {self.name!r}: diurnal_amplitude must be "
                f"in [0, 1] (got {self.diurnal_amplitude}); beyond 1 "
                "the instantaneous rate goes negative and the thinning "
                "loop silently drops that phase of the day -- a hidden "
                "traffic hole, not more swing")


_WORDS = (
    "sky", "memory", "orbit", "cache", "relay", "prefix", "block",
    "token", "fabric", "anchor", "plane", "hop", "window", "chunk",
    "decode", "rotate", "ground", "stripe", "swarm", "laser",
)


def _filler(rng: random.Random, n_chars: int) -> str:
    """Deterministic pseudo-text of roughly ``n_chars`` characters."""
    parts: list[str] = []
    total = 0
    while total < n_chars:
        w = _WORDS[rng.randrange(len(_WORDS))]
        parts.append(w)
        total += len(w) + 1
    return " ".join(parts)[:n_chars]


def poisson_times(rate_rps: float, rng: random.Random) -> Iterator[float]:
    """Homogeneous Poisson arrival times (exponential gaps), open-ended."""
    t = 0.0
    while True:
        t += rng.expovariate(rate_rps)
        yield t


def diurnal_times(rate_rps: float, amplitude: float, period_s: float,
                  rng: random.Random) -> Iterator[float]:
    """Nonhomogeneous Poisson with a sinusoidal day/night swing, via
    thinning at the peak rate."""
    lam_max = rate_rps * (1.0 + amplitude)
    t = 0.0
    while True:
        t += rng.expovariate(lam_max)
        lam = rate_rps * (1.0 + amplitude
                          * math.sin(2.0 * math.pi * t / period_s))
        if rng.random() * lam_max <= lam:
            yield t


def bursty_times(rate_rps: float, burst_size: int, spread_s: float,
                 rng: random.Random) -> Iterator[float]:
    """Correlated clumps: burst starts are Poisson at rate/burst_size,
    each burst carries a geometric number of requests (mean burst_size)
    spaced by small exponential gaps.  Mean rate stays ``rate_rps``."""
    burst_size = max(1, burst_size)
    t = 0.0
    last = 0.0
    while True:
        t = max(t + rng.expovariate(rate_rps / burst_size), last)
        n = 1
        while n < 4 * burst_size and rng.random() > 1.0 / burst_size:
            n += 1
        tb = t
        for _ in range(n):
            yield tb
            last = tb
            tb += rng.expovariate(1.0 / spread_s)


@dataclass
class TrafficGenerator:
    """Merge every tenant's seeded stream into one time-ordered arrival
    iterator.  Deterministic: the same ``(tenants, seed)`` produces the
    same ``(t_s, tenant, prompt, priority, max_new_tokens)`` stream."""

    tenants: Sequence[TenantSpec]
    seed: int = 0

    def arrivals(self) -> Iterator[Arrival]:
        streams = [self._tenant_stream(spec) for spec in self.tenants]
        return heapq.merge(*streams, key=lambda a: (a.t_s, a.tenant))

    def take(self, n: int) -> list[Arrival]:
        out = []
        for arr in self.arrivals():
            out.append(arr)
            if len(out) >= n:
                break
        return out

    def until(self, t_end_s: float) -> list[Arrival]:
        out = []
        for arr in self.arrivals():
            if arr.t_s > t_end_s:
                break
            out.append(arr)
        return out

    # ------------------------------------------------------------------
    def _tenant_stream(self, spec: TenantSpec) -> Iterator[Arrival]:
        # independent rngs for times and prompt content, so changing one
        # distribution never perturbs the other's draws
        t_rng = random.Random(f"{self.seed}/{spec.name}/times")
        p_rng = random.Random(f"{self.seed}/{spec.name}/prompts")
        doc_rng = random.Random(f"{self.seed}/{spec.name}/docs")
        docs = [f"<{spec.name}/doc{j}> " + _filler(doc_rng, spec.doc_chars)
                for j in range(max(1, spec.num_documents))]
        if spec.process == "poisson":
            times = poisson_times(spec.rate_rps, t_rng)
        elif spec.process == "diurnal":
            times = diurnal_times(spec.rate_rps, spec.diurnal_amplitude,
                                  spec.diurnal_period_s, t_rng)
        elif spec.process == "bursty":
            times = bursty_times(spec.rate_rps, spec.burst_size,
                                 spec.burst_spread_s, t_rng)
        else:
            raise ValueError(f"unknown arrival process: {spec.process!r}")
        lo, hi = spec.prompt_chars
        for serial, t in enumerate(times):
            if spec.prefix_reuse_p and p_rng.random() < spec.prefix_reuse_p:
                doc = docs[p_rng.randrange(len(docs))]
                prompt = f"{doc} q{serial}: " + _filler(
                    p_rng, max(8, lo // 4))
            else:
                prompt = f"[{spec.name}#{serial}] " + _filler(
                    p_rng, p_rng.randint(lo, hi))
            req = Request(
                prompt=prompt,
                sampling=SamplingParams(max_new_tokens=spec.max_new_tokens),
                priority=spec.priority,
                tenant=spec.name,
            )
            yield Arrival(t_s=t, tenant=spec.name, request=req)


def standard_tenants(n: int, total_rate_rps: float, *,
                     max_new_tokens: int = 16,
                     prompt_chars: tuple[int, int] = (48, 160),
                     prefix_reuse_p: float = 0.5) -> list[TenantSpec]:
    """A ready-made multi-tenant mix for examples and benchmarks:
    tenant 0 is the high-priority ``pro`` tier (steady Poisson), the
    rest alternate bursty document-reuse tenants and diurnal
    free-tier traffic, splitting ``total_rate_rps`` evenly."""
    n = max(1, n)
    rate = total_rate_rps / n
    specs = [TenantSpec(
        name="pro", rate_rps=rate, process="poisson", priority=1,
        prompt_chars=prompt_chars, max_new_tokens=max_new_tokens)]
    for i in range(1, n):
        if i % 2:
            specs.append(TenantSpec(
                name=f"burst{i}", rate_rps=rate, process="bursty",
                burst_size=3, prefix_reuse_p=prefix_reuse_p,
                prompt_chars=prompt_chars,
                max_new_tokens=max_new_tokens))
        else:
            specs.append(TenantSpec(
                name=f"diurnal{i}", rate_rps=rate, process="diurnal",
                diurnal_period_s=30.0, prompt_chars=prompt_chars,
                max_new_tokens=max_new_tokens))
    return specs
