"""Serving layer: a scale-out, tiered-KV SkyMemory runtime.

Scale-out layering
==================

The stack now serves from a **cluster of Engine replicas over one shared
constellation fabric** (the paper's "Scale Out" axis):

* **Router** (``repro.serving.router``) -- the cluster's front door.
  Every request is scored per replica before any engine sees it:
  *prefix affinity* (route duplicated contexts to the replica already
  holding / writing their blocks, via a router-local block-hash memory
  plus the shared radix index), *hop latency* (the estimated Get KVC
  cost from the replica's anchor satellite to the blocks' home
  satellites, priced by the same transport model the fetch later
  experiences), and *load* (outstanding tokens; always the tie-break).
  A seeded ``RandomRouter`` is the baseline every benchmark compares
  against.
* **Cluster** (``repro.serving.cluster``) -- ``EngineCluster`` wires N
  replicas to ONE ``ConstellationKVC``: each replica is *anchored* at a
  different satellite through ``ConstellationKVC.view`` (private
  transport: per-anchor hop costs, per-replica cache/transport stats)
  and bound to the shared §3.10 radix index through
  ``KVCManager.sibling`` (one prefix index, one recency policy, one
  lock, N entry points).  ``serve`` routes a stream, runs replicas on
  concurrent threads, and merges results in request order;
  ``rotate_every_s`` rotates the constellation on the serving clock
  while requests are in flight (chunks migrate, prefix affinity
  shifts).  ``EngineStats.merge`` folds per-replica stats into true
  cluster-level TTFT/ITL percentiles and constellation hit rates.

Streaming tier
==============

The cluster serves **open-ended streams**, not just closed batches:

* **Engine worker loops** (``Engine.start`` / ``submit`` / ``stop``) --
  each replica runs a long-lived worker thread over its scheduler's
  persistent ``submit()``/``service()`` stream: it keeps stepping while
  the queue drains (mid-decode admission picks new arrivals up between
  steps), idles when empty, and drains cleanly on ``stop()``.
  ``Engine.pump`` services one round inline for threadless
  deterministic interleaves; closed-batch ``generate`` is a thin
  wrapper that submits, services to empty, and restamps batch wall
  time.  Non-paged families stream by micro-batching through the dense
  runtime.
* **Per-request routing and release** (``EngineCluster.submit`` /
  ``serve_stream``) -- every request is routed at its *arrival time* on
  the fabric clock, and its ``committed_tokens`` return to the router
  the moment it finishes (a future callback), so the load tie-break
  compares true in-flight work instead of end-of-batch totals.
* **Traffic** (``repro.serving.traffic``) -- seeded open-ended arrival
  processes: Poisson, diurnal-modulated (thinned nonhomogeneous
  Poisson), and bursty multi-tenant streams with per-tenant prompt
  length, document prefix-reuse, decode length, and priority --
  ``TrafficGenerator`` merges them into one deterministic
  ``Arrival(t_s, tenant, Request)`` iterator.
* **SLOs + admission control** (``repro.serving.slo``) -- per-tenant
  TTFT / per-request-ITL-p95 targets (``SLO``), goodput accounting
  (``SLOTracker``: SLO-attained tokens/s, per-tenant attainment, tail
  ITL), and the overload valve (``AdmissionController``): past a
  committed-token capacity, arrivals below ``protect_priority`` are
  shed at the front door while protected tenants always enter and
  additionally ride the scheduler's priority preemption inside the
  engines.  Shedding decides on load, never latency, so deterministic
  replays (``serve_stream(parallel=False)``, pump-budget interleave
  with the fractional budget carried across arrival gaps, rotation on
  virtual-time crossings) reproduce byte-identical runs.

Chaos under sustained load
==========================

The fault machinery and the streaming tier compose:
``serve_stream(faults=...)`` drives a seeded ``FaultPlan`` (or prebuilt
``FaultInjector``) *while the traffic generator runs* --
``FaultPlan.chaos_arc`` builds the composite schedule (survivable
satellite kills + ISL cuts rerouted into detours + a directory-stripe
wipeout + a replica-home-pair kill forcing ground fall-through), armed
at stream start so event times share the arrival timeline.  In realtime
mode the injector advances on the fabric clock from inside chunk ops;
in deterministic mode it is *held* and driven on virtual arrival-time
crossings under the manager lock, interleaved with rotation in
virtual-time order and with ``reconcile()`` fired on heal crossings, so
a kill->degrade->heal->repair arc replays byte-identically.  The
measurement side: ``SLOTracker(window_s=...)`` buckets attained tokens
into fixed virtual-time windows keyed by arrival ``t_s``, each tagged
with its fault phase (``FaultPhases``: pre_churn / churn / post_heal
from the plan's ``churn_span``), so "goodput holds within X% through
churn and recovers after heal" is a computable bar -- and the
``StreamReport.faults`` block carries the stream's own degradation
deltas (``degraded_reads`` / ``degraded_lookups`` / ``ground_hits`` /
``lost_blocks`` / ``repaired_*``) next to the injector's event tallies.
The ``chaos_sustained_load`` benchmark runs the arc against a 2-replica
clocked int8 fabric at ~1.2x capacity and holds those bars, with a
k=1 control demonstrably degrading further.

Constellation latency is **experienced, not just recorded**: with a
``core.protocol.SimClock`` on the fabric, every Get KVC completes at a
virtual time (``IslTransport.last_ready_at``).  The scheduler treats a
fetched prefix as *in flight* until the clock passes that time --
chunks that would consume it are deferred so the flight overlaps live
decode steps (extending the fetch-ahead hook), and whatever cannot be
hidden is waited out and accounted (``EngineStats.l2_wait_s`` /
``l2_deferred_chunks``).  Unclocked fabrics keep the legacy
instant-L2 behavior.

Fault model
===========

Constellation *failures* are experienced end-to-end too -- satellites
crash and ISL links drop (``core.faults``: seeded ``FaultPlan`` applied
by a ``FaultInjector`` on the fabric clock), and the serving stack
degrades **gracefully** instead of falling off a cliff:

* **k-replica placement** (``ConstellationKVC(replication=k)``): every
  chunk is stored ``k`` times -- replica 0 on its server's satellite,
  replica ``r`` offset by ``core.chunking.replica_delta``, which walks
  plane-first so copies are plane-diverse whenever ``k <= num_planes``
  and never share a satellite.  Rotation migrates every replica's home
  along with its server.
* **Rerouted detours, not binary link failure**: a dead ISL link no
  longer fails the op -- ``FaultState.route_hops`` finds the cheapest
  clean detour around severed links (bounded torus search), and every
  chunk op, presence probe, and router estimate
  (``estimate_get_latency_s``) prices the SAME detoured path: a cut
  link costs ``+extra_hops`` of latency, counted in
  ``CacheStats.detoured_ops`` / ``detour_hops``.  A satellite is
  *unreachable* only when its endpoint is genuinely partitioned, and
  an unreachable probe is charged a flat ``IslTransport.
  probe_timeout_s`` (when set) instead of a fabricated round trip.
* **Degraded reads, swarm-ordered**: Get KVC / presence probes fall
  through dead replicas *cheapest-live-first* per anchor (the same
  cost order ``estimate_get_latency_s`` prices), charging each failed
  attempt on the same clock the successful fetch completes on -- a
  degraded fetch *feels* slower, and the router sees failures before
  engines do.
* **The metadata tier is fabric state too**
  (``ConstellationKVC(dir_replication=k)``): the block directory --
  ``block_hash -> n_chunks`` -- is striped across satellites (stripe
  home hash-derived like chunk servers, replicated plane-diversely via
  the same ``replica_delta`` geometry) instead of living in one
  immortal host dict.  Every directory op is priced on the clock:
  lookups walk the stripe replicas cheapest-live-first and fall
  through dead homes exactly like degraded data reads
  (``CacheStats.dir_lookups`` / ``degraded_lookups``), Sets register,
  purges unregister, and rotation migrates shard entries with their
  server.  A satellite death destroys its shard; ``reconcile()``
  rebuilds lost entries from surviving stripe replicas plus
  per-satellite chunk inventories (``dir_repaired_entries``) and
  deletes orphaned chunks no reconstructed entry explains
  (``orphaned_chunks``).  A block whose *later* chunk died everywhere
  no longer reads as present until the fetch fails: the fabric serves
  the longest still-complete prefix and counts it
  (``shortened_prefixes``).
* **The ground tier (L3)**: an attached ``GroundStationTier`` is the
  durable store below the constellation -- bigger, slower, priced as
  ISL hops to the LOS window center plus an Eq-4 uplink round trip.
  Write policies (``ground_write``): ``"all"`` write-through on every
  Set, ``"spill"`` reassemble-and-spill on LRU eviction, ``"none"``.
  A Get with no live orbital copy falls through to ground
  (``CacheStats.ground_hits``) and is only a clean miss -- prefix
  shortened, tail recomputed, never a failed request -- when ground
  misses too.
* **Repair, now from ground**: ``ConstellationKVC.repair()``
  re-replicates surviving orbital copies onto live replica homes, and
  when NO orbital copy survives it re-replicates from the ground tier
  (``CacheStats.repaired_from_ground``); only blocks absent from both
  orbit and ground are purged and pruned from the radix index.
* **Accounting**: ``CacheStats.degraded_reads`` / ``lost_blocks`` /
  ``repaired_chunks`` / ``detoured_ops`` / ``detour_hops`` /
  ``ground_hits`` / ``repaired_from_ground`` / ``dir_lookups`` /
  ``degraded_lookups`` / ``dir_repaired_entries`` /
  ``orphaned_chunks`` / ``shortened_prefixes`` on the fabric,
  ``EngineStats.degraded_reads`` / ``lost_blocks`` / ``detoured_ops``
  / ``ground_hits`` / ``degraded_lookups`` / ``shortened_prefixes``
  per replica, all folded by ``EngineCluster.fabric_stats`` and
  exercised by the ``faulty_fabric`` benchmark (k=2 holds the prefix
  hit rate through mid-serve satellite kills that collapse k=1), the
  ``degraded_fabric`` benchmark (sustained link outages + satellite
  kills with a ground station attached: zero failed ops, losses
  repaired from ground, hit rate held while the no-ground run decays),
  and the ``striped_directory`` benchmark (a directory-stripe wipeout
  mid-serve at ``dir_replication=2`` stays byte-identical with zero
  failed requests and the stripe rebuilt by ``reconcile()``, while
  ``dir_replication=1`` demonstrably loses the entries).

Payload codec
=============

Everything the constellation stores or moves is a **versioned, self-
describing payload** (``core.chunking.PayloadCodec``): ``f32`` ships
the legacy raw-array container byte-for-byte; ``int8`` / ``int4``
quantize float K/V symmetrically per last-axis channel with one scale
table per engine-block chunk of tokens (integer pools stay raw), and
``int8+delta`` / ``int4+delta`` make each cumulative Set ship only its
own block's tokens plus a back-pointer to the previous block's hash --
``KVCManager`` walks the chain with real priced Gets and reassembles
on restore.  Decoding is always codec-agnostic (headers carry codec id
and source dtype, so bf16 pools dequantize back to bf16 exactly), and
the router prices *encoded* bytes: registered blocks via their real
``payload_bytes``, unregistered ones via the adapter's codec-derived
``payload_bytes_per_token`` -- estimates and experienced fetches agree
on sizes by construction.  ``CacheStats.bytes_encoded`` /
``bytes_raw`` (and ``EngineStats.dequant_overlap_s``, the dequantize
leg hidden on the fetch-ahead worker) surface the compression through
``EngineCluster.fabric_stats``.

Single-replica layering
=======================

Each replica is the three-layer engine behind a thin ``Engine`` facade
(``repro.serving.engine``), each layer separately importable and
separately tested:

* **Scheduler** (``repro.serving.scheduler``) -- the host-side brain:
  request lifecycle (QUEUED -> PREFILLING -> RUNNING -> FINISHED, with
  PREEMPTED as the swap detour), continuous admission, page-aligned
  chunk budgeting, and the preemption policy.  It speaks tokens and
  slots, never device arrays.
* **Executor** (``repro.serving.executor``) -- every jitted device
  program: the fused decode step, the mixed decode+chunk step, the
  cold-start chunk wave, bucketed dense prefill, the vectorized
  sampler, and the PRNG stream; plus the dense runtime for non-paged
  families.
* **KVManager** (``repro.serving.kv_manager``) -- the
  ``TieredKVManager``, a three-level KV fabric:

  - **L0, device page pool** (``repro.models.cache.PagedKVCache``): one
    pool of K/V pages per layer (``[L, N_pages, page, Hkv, hd]``), page
    size = the SkyMemory block size (the paper's 128-token KVC blocks).
    Slots own pages through int32 block tables; pages are allocated
    *lazily* as sequences grow -- no worst-case reservation -- so the
    pool can run more live sequences than it could hold at their maximum
    lengths.  Full-size pools use fixed per-slot regions (zero-gather
    reshape reads); oversubscribed pools (explicit ``num_pages``) go
    through the Pallas paged-attention kernel's scalar-prefetched
    block-table variant.  The jitted step donates the pools, so backends
    with buffer donation update the cache in place.
  - **L1, host-RAM page cache** (``HostPageCache``): preempted
    sequences' pages, exported in ONE gathered device read per pool.  A
    hit restores bit-identical K/V including the non-block-aligned tail
    page, so a resumed sequence replays nothing.
  - **L2, the constellation** (``core.protocol`` Set/Get KVC through
    ``SkyKVCAdapter``): the paper's LEO cache as a real swap tier with
    real (clocked) fetch latency.  Host-cache overflow spills a
    victim's *block-aligned* prefix as payloads built directly from its
    exported pages (no model recompute), indexed in the same radix tree
    as ordinary write-backs; restores that miss L1 fetch the longest
    cached block prefix -- experiencing the flight -- and replay only
    the unaligned tail.

  One ``core.eviction.LRUClock`` stamps accesses across L1, L2, and the
  radix index -- for every replica of a cluster -- so victim selection
  anywhere sees one recency timeline.

Preemption-by-offload
=====================

Admission needs a free slot and pages for the prompt plus one decode
write.  When a running sequence needs a page and the pool has none
(growth pressure), or a strictly higher-priority request is queued
behind a full machine (``Request.priority``), the scheduler offloads the
lowest-priority sequence -- ties broken against the most recently
admitted -- up the tier hierarchy and requeues it at the front.  The
already-sampled next token travels with the swap, so a preempted-and-
resumed sequence emits byte-identical tokens to an uninterrupted run
when restored from L1, and replays only its unaligned tail through the
chunked-prefill path otherwise.  Admission refusal and pool exhaustion
are therefore no longer failure modes: an oversubscribed pool completes
every request.

Chunked prefill and sync points
===============================

Prompts prefill in page-aligned chunks of at most ``chunk_tokens`` that
ride the decode step: each fused step decodes every running slot AND
retires one chunk, which writes its K/V into pool pages and attends over
the SkyMemory-restored prefix + earlier chunks *in place* (paged
chunked-prefill kernel, runtime offsets -- one compilation per buffer
shape).  Chunks are FIFO across PREFILLING sequences; a sequence's
SkyMemory lookup happens at chunk-head (after earlier write-backs, so
duplicate contexts queued together still hit) and its payload->pages
decode runs on the adapter's fetch-ahead thread -- now alongside the
simulated ISL flight -- overlapping live decode steps.  Cold-start
waves prefill together as lockstep batched chunk steps.  MoE families
keep stop-the-world admission (``chunk_tokens=0``): capacity routing is
group-composition dependent, so chunk splits would change real tokens'
routing.

The decode loop launches ONE jitted program per step and performs ONE
host sync: reading the sampled token ids (a finishing chunk's first
token rides the same vector as row ``B``).  Sampling params are stacked
into [B] arrays and re-uploaded only when slot membership changes.
``EngineStats`` records TTFT / inter-token-latency samples (plus the
during-admission ITL subset) for p50/p95/p99 reporting, the swap
counters (``preemptions``, ``restores``, ``offloaded_pages``,
``spilled_blocks``, ``replayed_tokens``), and the experienced-L2
counters (``l2_wait_s``, ``l2_fetch_waits``, ``l2_deferred_chunks``);
``TransportStats`` keeps a bounded latency reservoir with its own
p50/p95/p99 alongside.

Non-paged families (MLA latent, SSM state, hybrid, encoder-decoder)
keep a dense batched cache (``DenseRuntime``) but share the vectorized
sampler and the one-sync-per-step loop; paging their decode state is
future work.
"""
from repro.serving.cluster import (
    EngineCluster,
    StreamRecord,
    StreamReport,
    spread_anchors,
)
from repro.serving.engine import Engine
from repro.serving.executor import DenseRuntime, PagedExecutor
from repro.serving.kv_manager import HostPageCache, TieredKVManager
from repro.serving.request import (
    FinishReason,
    GenerationResult,
    Request,
    SeqState,
)
from repro.serving.slo import (
    SLO,
    AdmissionController,
    FaultPhases,
    SLOTracker,
    itl_tail,
)
from repro.serving.router import (
    PrefixAffinityRouter,
    RandomRouter,
    ReplicaHandle,
    RouteDecision,
    Router,
    make_router,
)
from repro.serving.sampler import (
    SamplingParams,
    sample,
    sample_batch,
    stack_sampling,
)
from repro.serving.scheduler import Scheduler, chunk_spans, head_span
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.stats import EngineStats, SampleReservoir
from repro.serving.tokenizer import ByteTokenizer
from repro.serving.traffic import (
    Arrival,
    TenantSpec,
    TrafficGenerator,
    standard_tenants,
)

__all__ = [
    "AdmissionController",
    "Arrival",
    "Engine",
    "EngineCluster",
    "EngineStats",
    "FaultPhases",
    "FinishReason",
    "GenerationResult",
    "SLO",
    "SLOTracker",
    "SampleReservoir",
    "StreamRecord",
    "StreamReport",
    "TenantSpec",
    "TrafficGenerator",
    "PrefixAffinityRouter",
    "RandomRouter",
    "ReplicaHandle",
    "Request",
    "RouteDecision",
    "Router",
    "SamplingParams",
    "SeqState",
    "Scheduler",
    "PagedExecutor",
    "DenseRuntime",
    "TieredKVManager",
    "HostPageCache",
    "chunk_spans",
    "head_span",
    "make_router",
    "sample",
    "sample_batch",
    "spread_anchors",
    "stack_sampling",
    "standard_tenants",
    "itl_tail",
    "SkyKVCAdapter",
    "ByteTokenizer",
]
