from repro.serving.engine import Engine, EngineStats
from repro.serving.request import GenerationResult, Request
from repro.serving.sampler import SamplingParams, sample
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer

__all__ = [
    "Engine",
    "EngineStats",
    "GenerationResult",
    "Request",
    "SamplingParams",
    "sample",
    "SkyKVCAdapter",
    "ByteTokenizer",
]
