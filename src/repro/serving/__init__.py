"""Serving layer: the paged, continuously-batched SkyMemory runtime.

Engine architecture
===================

**Paged layout.**  Dense-attention families decode against a
``repro.models.cache.PagedKVCache``: one device-resident pool of K/V pages
per layer (``[L, N_pages, page, Hkv, hd]``) whose page size equals the
SkyMemory block size (the paper's 128-token KVC blocks).  Each batch slot
owns a page list through an int32 block table; pages come from a shared
free list and are recycled when a sequence finishes.  Because pages and
constellation blocks coincide, a prefix fetched from the LEO cache is
reshaped ``[L, n_blocks, page, Hkv, hd]`` and scattered straight into pool
pages -- there is no dense per-sequence restacking between prefill and
decode.  Full-size pools (the default) use fixed per-slot page regions,
so decode attention reads the pool as ``[B, P, page, Hkv, hd]`` by
reshape with zero gather; oversubscribed pools (explicit ``num_pages``)
resolve pages through the Pallas paged-attention kernel's block-table
variant (scalar-prefetched tables; pure-jnp grouped-GQA oracle on CPU).
The jitted step donates the pools, so backends with buffer donation
update the cache in place.

**Scheduler states.**  A request moves QUEUED -> RUNNING -> FINISHED
(``repro.serving.request.SeqState``).  Admission fills freed slots from
the queue *mid-decode* (continuous batching): prefill runs for the new
request (bucketed to power-of-two lengths to bound recompiles, or only
the uncached suffix on a SkyMemory hit), its pages are written, and the
next fused step simply includes the slot.  Admission reserves the
worst-case page span (prompt + max_new_tokens, capped at max_seq_len),
so a running sequence never exhausts the pool mid-decode and block
tables only change at admission/release; unused pages return to the
free list at early EOS.  Finish reasons: ``eos``, ``max_new_tokens``,
``max_seq_len``.

**Sync points.**  The decode loop launches ONE jitted program per step
(embed -> layers -> paged attention -> vectorized per-slot sampler) and
performs ONE host sync per step: reading the sampled token ids, which the
host scheduler needs for EOS detection, page allocation, and admission.
Prefill and first-token sampling sync once per *admission* (amortized
over the whole generation).  Sampling parameters (temperature / top-k /
top-p) are stacked into [B] arrays and re-uploaded only when slot
membership changes.

Non-paged families (MLA latent, SSM state, hybrid, encoder-decoder) keep
a dense batched cache but share the vectorized sampler and the
one-sync-per-step loop; paging their decode state is future work.
"""
from repro.serving.engine import Engine, EngineStats
from repro.serving.request import (
    FinishReason,
    GenerationResult,
    Request,
    SeqState,
)
from repro.serving.sampler import (
    SamplingParams,
    sample,
    sample_batch,
    stack_sampling,
)
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer

__all__ = [
    "Engine",
    "EngineStats",
    "FinishReason",
    "GenerationResult",
    "Request",
    "SamplingParams",
    "SeqState",
    "sample",
    "sample_batch",
    "stack_sampling",
    "SkyKVCAdapter",
    "ByteTokenizer",
]
