"""Serving layer: the paged, continuously-batched SkyMemory runtime.

Engine architecture
===================

**Paged layout.**  Dense-attention families decode against a
``repro.models.cache.PagedKVCache``: one device-resident pool of K/V pages
per layer (``[L, N_pages, page, Hkv, hd]``) whose page size equals the
SkyMemory block size (the paper's 128-token KVC blocks).  Each batch slot
owns a page list through an int32 block table; pages come from a shared
free list and are recycled when a sequence finishes.  Because pages and
constellation blocks coincide, a prefix fetched from the LEO cache is
reshaped ``[L, n_blocks, page, Hkv, hd]`` and scattered straight into pool
pages -- there is no dense per-sequence restacking between prefill and
decode.  Full-size pools (the default) use fixed per-slot page regions,
so decode attention reads the pool as ``[B, P, page, Hkv, hd]`` by
reshape with zero gather; oversubscribed pools (explicit ``num_pages``)
resolve pages through the Pallas paged-attention kernel's block-table
variant (scalar-prefetched tables; pure-jnp grouped-GQA oracle on CPU).
The jitted step donates the pools, so backends with buffer donation
update the cache in place.

**Chunk scheduler.**  A request moves QUEUED -> PREFILLING -> RUNNING
-> FINISHED (``repro.serving.request.SeqState``).  Admission fills
freed slots from the queue *mid-decode* (continuous batching) and
reserves the worst-case page span (prompt + max_new_tokens, capped at
max_seq_len), so a running sequence never exhausts the pool mid-decode
and block tables only change at admission/release; unused pages return
to the free list at early EOS.  Prompts are then prefilled in
page-aligned *chunks* of at most ``chunk_tokens`` (the per-step budget)
that ride the decode step: each fused step decodes every running slot
AND retires one chunk, which writes its K/V into the slot's pool pages
and attends over the SkyMemory-restored prefix + earlier chunks *in
place* through the paged chunked-prefill kernel (scalar-prefetched
block tables, runtime offsets) -- decode never pauses for an admission,
and there is no dense ``prefix_state`` restaging anywhere in the paged
families.  Chunks are FIFO across PREFILLING sequences; a sequence's
SkyMemory lookup happens when it reaches the head (after earlier
write-backs, so duplicate contexts queued together still hit), its
payload->pages decode runs on the adapter's fetch-ahead thread
overlapping a live decode step, and a whole-prompt hit keeps every
restored block, replaying only the final token as a one-token chunk.
When *nothing* is decoding (cold start), the admission wave prefills
together as lockstep batched chunk steps instead -- the throughput of a
batched prefill without whole-prompt compile buckets (chunk buffers are
power-of-two bucketed up to the budget, so compile count is bounded by
the chunk size, not max_seq_len).  A sequence's first token is sampled
inside the step in which its last chunk lands.  MoE families keep
stop-the-world admission (``chunk_tokens=0`` forces it everywhere, as
the pre-chunked baseline): capacity routing is group-composition
dependent, so chunk splits would change real tokens' routing.  Finish
reasons: ``eos``, ``max_new_tokens``, ``max_seq_len``.

**Sync points.**  The decode loop launches ONE jitted program per step
(embed -> layers -> paged attention -> vectorized per-slot sampler,
plus the riding prefill chunk while an admission is in flight) and
performs ONE host sync per step: reading the sampled token ids, which the
host scheduler needs for EOS detection, page allocation, and admission
(a final chunk's first token rides the same vector as row ``B``).
Cold-start chunk waves sample their first tokens in one call with one
sync.  Sampling parameters (temperature / top-k / top-p) are stacked
into [B] arrays and re-uploaded only when slot membership changes.
``EngineStats`` records TTFT and inter-token-latency samples (plus the
during-admission ITL subset) for p50/p95/p99 reporting.

Non-paged families (MLA latent, SSM state, hybrid, encoder-decoder) keep
a dense batched cache but share the vectorized sampler and the
one-sync-per-step loop; paging their decode state is future work.
"""
from repro.serving.engine import Engine, EngineStats
from repro.serving.request import (
    FinishReason,
    GenerationResult,
    Request,
    SeqState,
)
from repro.serving.sampler import (
    SamplingParams,
    sample,
    sample_batch,
    stack_sampling,
)
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer

__all__ = [
    "Engine",
    "EngineStats",
    "FinishReason",
    "GenerationResult",
    "Request",
    "SamplingParams",
    "SeqState",
    "sample",
    "sample_batch",
    "stack_sampling",
    "SkyKVCAdapter",
    "ByteTokenizer",
]
