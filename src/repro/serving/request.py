"""Serving request/response types."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.serving.sampler import SamplingParams

_ids = itertools.count()


@dataclass
class Request:
    prompt: str
    sampling: SamplingParams = field(default_factory=SamplingParams)
    request_id: int = field(default_factory=lambda: next(_ids))


@dataclass
class GenerationResult:
    request_id: int
    prompt: str
    text: str
    token_ids: list[int]
    prompt_tokens: int
    cached_tokens: int          # tokens restored from SkyMemory (prefix hit)
    prefill_tokens: int         # tokens actually prefilled
    wall_time_s: float = 0.0
