"""Serving request/response types and scheduler states."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.serving.sampler import SamplingParams

_ids = itertools.count()


class SeqState(enum.Enum):
    """Lifecycle of a request inside the continuous-batching scheduler."""

    QUEUED = "queued"            # waiting for a free slot + pages
    PREFILLING = "prefilling"    # owns a slot; prompt chunks ride the
    #                              decode step until the last one lands
    RUNNING = "running"          # decoded every step
    PREEMPTED = "preempted"      # pages offloaded to the host/constellation
    #                              tiers; requeued at the front, resumes
    #                              via restore + tail replay
    FINISHED = "finished"        # slot and pages released


class FinishReason(enum.Enum):
    EOS = "eos"
    MAX_NEW_TOKENS = "max_new_tokens"
    MAX_SEQ_LEN = "max_seq_len"


@dataclass
class Request:
    prompt: str
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # preemption policy input: when the pool or the slots oversubscribe,
    # the scheduler offloads the lowest-priority running sequence first
    # (ties broken against the most recently admitted)
    priority: int = 0
    # multi-tenant streams: which tenant's SLO this request counts
    # against (empty for single-tenant callers -- nothing downstream
    # requires it)
    tenant: str = ""
    request_id: int = field(default_factory=lambda: next(_ids))


@dataclass
class GenerationResult:
    request_id: int
    prompt: str
    text: str
    token_ids: list[int]
    prompt_tokens: int
    cached_tokens: int          # tokens restored from SkyMemory (prefix hit)
    prefill_tokens: int         # tokens actually prefilled
    wall_time_s: float = 0.0
    ttft_s: float = 0.0         # queue-entry -> first token latency
    finish_reason: str = FinishReason.MAX_NEW_TOKENS.value
    preemptions: int = 0        # times this sequence was swapped out
    tenant: str = ""            # copied from the request (SLO accounting)
    # this request's own inter-token gaps (streaming SLO attainment
    # judges each request's ITL tail, not the engine-wide distribution)
    itl_samples_s: list[float] = field(default_factory=list)


@dataclass
class Seq:
    """Scheduler-side state of one in-flight request (all host data)."""

    request: Request
    tokens: list[int]
    state: SeqState = SeqState.QUEUED
    cached: int = 0
    out_ids: list[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = FinishReason.MAX_NEW_TOKENS.value
    enqueue_t: float = 0.0
    ttft_s: float = 0.0
    wall_s: float = 0.0
    # chunked-prefill state machine:
    reserve: int = 0                  # worst-case token footprint (park pos)
    cursor: int = 0                   # next prompt token to prefill
    looked_up: bool = False           # SkyMemory lookup done for this seq
    pages_future: object | None = None   # in-flight payload -> pages decode
    # clocked fabric: virtual completion time of this seq's L2 Get -- the
    # fetched payload may not be consumed before the clock passes it
    fetch_ready_at: float | None = None
    dev_ops: tuple | None = None      # per-admission device operands
    admit_seq: int = 0                # admission order (preemption tiebreak)
    # preemption/restore state: while PREEMPTED, ``replay_tokens`` is the
    # exact token sequence whose K/V the pool held (prompt + emitted
    # tokens up to the offload point) and ``replay_next`` the already-
    # sampled token the next decode step feeds -- restore rebuilds pages
    # for replay_tokens (host tier: bit-exact import; constellation /
    # recompute: block prefix + chunked tail replay) and resumes without
    # sampling anything again
    replay_tokens: list[int] | None = None
    replay_next: int | None = None
    preempt_count: int = 0
    # streaming: the submit()-returned future this seq resolves on
    # finish (None on the closed-batch path until run() attaches one),
    # and this seq's own inter-token gaps for per-request ITL tails
    future: object | None = None
    itl: list[float] = field(default_factory=list)
    # legacy (non-paged) path only:
    dense_state: dict | None = None
    last_logits: jnp.ndarray | None = None

    @property
    def prefill_tokens(self) -> list[int]:
        """The token sequence the chunk planner must cover with pages:
        the prompt for a fresh admission, the offloaded-KV token span for
        a restore replay."""
        return self.tokens if self.replay_tokens is None else self.replay_tokens


def seq_finished(s: Seq, tid: int, *, eos_id: int, max_seq_len: int) -> bool:
    """Finish-reason bookkeeping shared by the paged and dense runtimes."""
    if tid == eos_id:
        s.done, s.finish_reason = True, FinishReason.EOS.value
    elif len(s.out_ids) >= s.request.sampling.max_new_tokens:
        s.done = True
        s.finish_reason = FinishReason.MAX_NEW_TOKENS.value
    elif len(s.tokens) + len(s.out_ids) >= max_seq_len:
        s.done = True
        s.finish_reason = FinishReason.MAX_SEQ_LEN.value
    return s.done


def seq_result(s: Seq, tokenizer) -> GenerationResult:
    return GenerationResult(
        request_id=s.request.request_id,
        prompt=s.request.prompt,
        text=tokenizer.decode(s.out_ids),
        token_ids=s.out_ids,
        prompt_tokens=len(s.tokens),
        cached_tokens=s.cached,
        prefill_tokens=len(s.tokens) - s.cached,
        wall_time_s=s.wall_s,
        ttft_s=s.ttft_s,
        finish_reason=s.finish_reason,
        preemptions=s.preempt_count,
        tenant=s.request.tenant,
        itl_samples_s=list(s.itl),
    )
