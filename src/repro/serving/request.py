"""Serving request/response types and scheduler states."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field

from repro.serving.sampler import SamplingParams

_ids = itertools.count()


class SeqState(enum.Enum):
    """Lifecycle of a request inside the continuous-batching scheduler."""

    QUEUED = "queued"            # waiting for a free slot + pages
    PREFILLING = "prefilling"    # owns a slot; prompt chunks ride the
    #                              decode step until the last one lands
    RUNNING = "running"          # decoded every step
    FINISHED = "finished"        # slot and pages released


class FinishReason(enum.Enum):
    EOS = "eos"
    MAX_NEW_TOKENS = "max_new_tokens"
    MAX_SEQ_LEN = "max_seq_len"


@dataclass
class Request:
    prompt: str
    sampling: SamplingParams = field(default_factory=SamplingParams)
    request_id: int = field(default_factory=lambda: next(_ids))


@dataclass
class GenerationResult:
    request_id: int
    prompt: str
    text: str
    token_ids: list[int]
    prompt_tokens: int
    cached_tokens: int          # tokens restored from SkyMemory (prefix hit)
    prefill_tokens: int         # tokens actually prefilled
    wall_time_s: float = 0.0
    ttft_s: float = 0.0         # queue-entry -> first token latency
    finish_reason: str = FinishReason.MAX_NEW_TOKENS.value
