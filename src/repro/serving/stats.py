"""Engine-level counters and latency percentiles.

One ``EngineStats`` object is shared by the facade, the scheduler, and
the executor-side runtimes; benchmarks reset it between timed runs by
assigning a fresh instance to ``Engine.stats``.  A scale-out cluster
keeps one instance per replica and folds them with ``EngineStats.merge``
/ ``EngineStats.merged`` -- counters add and the raw TTFT/ITL sample
lists concatenate, so ``latency_percentiles`` on the merged object are
true cluster-level percentiles, not averages of per-replica percentiles.

The TTFT/ITL sample fields are ``SampleReservoir`` lists: open-ended
streaming serves decode without a natural end, so unbounded per-token
sample lists would grow without limit.  Below the cap the reservoir IS
the full sample list (closed-batch runs and their percentile tests see
exact data); past it, uniform reservoir sampling keeps the percentiles
honest at O(1) memory -- the same scheme ``TransportStats`` uses for
transport op latencies.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field
from typing import Iterable

import numpy as np


def _percentiles(xs: list[float]) -> dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    p50, p95, p99 = np.percentile(np.asarray(xs, np.float64), [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99)}


class SampleReservoir(list):
    """A ``list`` whose growth is bounded by uniform reservoir sampling.

    Drop-in for the plain sample lists ``EngineStats`` carried before
    streaming: equality, ``len``, indexing, and iteration behave like a
    list, and every sample lands in arrival order until ``cap`` -- so
    short (closed-batch) runs see exactly the data they always did.
    Past ``cap``, each new sample replaces a uniformly random slot with
    probability ``cap / n_seen`` (seeded, like ``TransportStats``), so
    percentiles over an open-ended stream stay unbiased at fixed memory.
    """

    __slots__ = ("cap", "n_seen", "_rng")

    def __init__(self, iterable: Iterable[float] = (), *,
                 cap: int = 8192, seed: int = 0x5EED) -> None:
        super().__init__()
        self.cap = cap
        self.n_seen = 0
        self._rng = random.Random(seed)
        self.extend(iterable)

    def append(self, x: float) -> None:
        self.n_seen += 1
        if len(self) < self.cap:
            super().append(x)
        else:
            j = self._rng.randrange(self.n_seen)
            if j < self.cap:
                self[j] = x

    def extend(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.append(x)


@dataclass
class EngineStats:
    requests: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    decode_steps: int = 0             # jitted step programs launched
    mid_decode_admissions: int = 0    # requests admitted into a live batch
    prefill_chunks: int = 0           # chunk programs fused into steps
    # tiered-KV swap activity (preemption-by-offload):
    preemptions: int = 0              # sequences offloaded out of the pool
    restores: int = 0                 # preempted sequences brought back
    offloaded_pages: int = 0          # pool pages exported to the host tier
    spilled_blocks: int = 0           # host-tier blocks spilled to L2
    replayed_tokens: int = 0          # tail tokens recomputed at restore
    # experienced constellation latency (clocked fabrics only): an L2 Get
    # completes at a virtual time; chunks are deferred to overlap the
    # flight with decode steps, and whatever cannot be hidden is waited
    # out -- the nonzero cost that makes the orbital tier real
    l2_wait_s: float = 0.0            # virtual seconds blocked on fetches
    l2_fetch_waits: int = 0           # fetches with un-hidden flight time
    l2_deferred_chunks: int = 0       # chunk slots spent overlapping flights
    # fault tolerance (k-replica constellation under churn): degraded
    # reads served this replica after falling through dead replicas;
    # lost_blocks counts L2 lookups/restores where the index pointed at
    # blocks the constellation could no longer serve (the prefix --
    # or part of it -- was recomputed instead of crashing)
    degraded_reads: int = 0
    lost_blocks: int = 0
    # graceful degradation (graded link faults + the L3 ground tier):
    # chunk ops this replica's L2 calls completed over rerouted paths,
    # and lookups/restores the ground tier answered after every orbital
    # replica fell through -- the reads that would have been lost_blocks
    # (recompute) without a durable tier below the constellation
    detoured_ops: int = 0
    ground_hits: int = 0
    # decentralized directory (striped replicated metadata): lookups
    # this replica's L2 calls resolved only after probing >=1 dead
    # directory-stripe home, and promised prefixes the fabric degraded
    # to a shorter served prefix (a later chunk gone from every replica)
    degraded_lookups: int = 0
    shortened_prefixes: int = 0
    # payload codec: wall-clock seconds the quantized-payload dequantize
    # leg spent on the fetch-ahead worker -- decompression that ran
    # overlapped with live decode steps instead of on the serving loop
    dequant_overlap_s: float = 0.0
    ttft_s: list[float] = field(default_factory=SampleReservoir)
    # per decoded token:
    itl_s: list[float] = field(default_factory=SampleReservoir)
    # the subset of itl_s observed by running sequences while an
    # admission was in flight -- the tail the chunked scheduler exists
    # to flatten (a whole-run p99 dilutes a few admission stalls away)
    itl_admission_s: list[float] = field(default_factory=SampleReservoir)

    def __post_init__(self) -> None:
        # callers (and tests) may pass plain lists; rebind them as
        # reservoirs so an open-ended stream cannot grow them unbounded
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, list) and not isinstance(v, SampleReservoir):
                setattr(self, f.name, SampleReservoir(v))

    def latency_percentiles(self) -> dict[str, dict[str, float]]:
        """p50/p95/p99 of time-to-first-token and inter-token latency --
        the serving SLO view of the run (tokens/s hides admission
        stalls; the ITL tail is where stop-the-world prefill shows)."""
        return {"ttft_s": _percentiles(self.ttft_s),
                "itl_s": _percentiles(self.itl_s),
                "itl_admission_s": _percentiles(self.itl_admission_s)}

    # ------------------------------------------------------------------
    def merge(self, other: "EngineStats") -> "EngineStats":
        """Fold ``other`` into this object (cluster aggregation): numeric
        counters add, sample lists concatenate.  Returns self."""
        for f in dataclasses.fields(self):
            mine, theirs = getattr(self, f.name), getattr(other, f.name)
            if isinstance(mine, list):
                mine.extend(theirs)
            else:
                setattr(self, f.name, mine + theirs)
        return self

    @classmethod
    def merged(cls, parts: Iterable["EngineStats"]) -> "EngineStats":
        """Cluster-level stats from per-replica parts (parts unchanged)."""
        out = cls()
        for p in parts:
            out.merge(p)
        return out
