"""Engine-level counters and latency percentiles.

One ``EngineStats`` object is shared by the facade, the scheduler, and
the executor-side runtimes; benchmarks reset it between timed runs by
assigning a fresh instance to ``Engine.stats``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def _percentiles(xs: list[float]) -> dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    p50, p95, p99 = np.percentile(np.asarray(xs, np.float64), [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99)}


@dataclass
class EngineStats:
    requests: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    decode_steps: int = 0             # jitted step programs launched
    mid_decode_admissions: int = 0    # requests admitted into a live batch
    prefill_chunks: int = 0           # chunk programs fused into steps
    # tiered-KV swap activity (preemption-by-offload):
    preemptions: int = 0              # sequences offloaded out of the pool
    restores: int = 0                 # preempted sequences brought back
    offloaded_pages: int = 0          # pool pages exported to the host tier
    spilled_blocks: int = 0           # host-tier blocks spilled to L2
    replayed_tokens: int = 0          # tail tokens recomputed at restore
    ttft_s: list[float] = field(default_factory=list)   # per request
    itl_s: list[float] = field(default_factory=list)    # per decoded token
    # the subset of itl_s observed by running sequences while an
    # admission was in flight -- the tail the chunked scheduler exists
    # to flatten (a whole-run p99 dilutes a few admission stalls away)
    itl_admission_s: list[float] = field(default_factory=list)

    def latency_percentiles(self) -> dict[str, dict[str, float]]:
        """p50/p95/p99 of time-to-first-token and inter-token latency --
        the serving SLO view of the run (tokens/s hides admission
        stalls; the ITL tail is where stop-the-world prefill shows)."""
        return {"ttft_s": _percentiles(self.ttft_s),
                "itl_s": _percentiles(self.itl_s),
                "itl_admission_s": _percentiles(self.itl_admission_s)}
