"""Engine: the thin orchestration facade over the three serving layers.

The runtime itself lives in three separately importable, separately
tested modules (see the ``repro.serving`` package docstring for the full
map):

* ``repro.serving.scheduler``  -- admission, chunk budgeting, and the
  preemption-by-offload policy (host-side state machine);
* ``repro.serving.executor``   -- the jitted mixed decode/prefill steps,
  sampling, and device state (plus the dense runtime for non-paged
  families);
* ``repro.serving.kv_manager`` -- the ``TieredKVManager``: L0 device
  page pool -> L1 host-RAM page cache -> L2 constellation Set/Get KVC.

``Engine`` wires them together and preserves the public API every test,
benchmark, and example drives: construct with a model + params (+ an
optional ``ConstellationKVC``), call ``generate``, read ``stats`` /
``chunk_log`` / ``cache``.  Per request the flow is: tokenize ->
SkyMemory longest-prefix lookup -> fetched 128-token blocks drop
straight into KV pages -> the uncached suffix prefills in page-aligned
chunks that ride the decode step -> continuous-batching decode, with
preemption-by-offload absorbing pool pressure -- the paper's §5 testbed
loop with the LEO cache simulated in-process and used as a real swap
tier.
"""
from __future__ import annotations

from concurrent.futures import Future

from repro.core.chunking import PayloadCodec
from repro.core.protocol import ConstellationKVC, KVCManager
from repro.models.model import Model
from repro.serving.executor import DenseRuntime, PagedExecutor
from repro.serving.kv_manager import TieredKVManager
from repro.serving.request import GenerationResult, Request
from repro.serving.scheduler import (  # noqa: F401  (re-exported API)
    Scheduler,
    chunk_spans,
    head_span,
)
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.stats import EngineStats
from repro.serving.tokenizer import ByteTokenizer
from repro.serving.worker import StreamWorker


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        kvc: ConstellationKVC | None = None,
        manager: KVCManager | None = None,
        block_size: int = 128,
        max_seq_len: int = 512,
        max_batch: int = 8,
        write_back: bool = True,
        seed: int = 0,
        num_pages: int | None = None,
        chunk_tokens: int | None = None,
        host_cache_pages: int | None = None,
        payload_codec: "PayloadCodec | str | None" = None,
    ) -> None:
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.tokenizer = ByteTokenizer(self.cfg.vocab_size)
        self.max_seq_len = max_seq_len
        self.max_batch = max_batch
        self.block_size = block_size
        # the codec's scale-table chunk (and delta block) is the engine's
        # block size, so per-chunk scales align with constellation blocks
        self.adapter = SkyKVCAdapter(
            model, params,
            codec=PayloadCodec.parse(payload_codec, block_size))
        # a cluster replica receives a pre-built KVCManager (a sibling
        # over the shared radix index, bound to this replica's anchored
        # constellation view); a standalone engine builds its own from
        # ``kvc``
        if manager is not None:
            if manager.block_size != block_size:
                raise ValueError(
                    f"manager block_size {manager.block_size} != engine "
                    f"block_size {block_size}")
            self.manager: KVCManager | None = manager
        elif kvc is not None:
            self.manager = KVCManager(
                self.tokenizer.encode, self.adapter.kvc_fn, kvc,
                block_size=block_size,
            )
        else:
            self.manager = None
        self.paged = model.supports_paged_decode
        if self.paged:
            # page size == SkyMemory block size: fetched blocks are pages
            self.page_size = block_size
            self.cache = model.init_paged_cache(
                num_slots=max_batch, page_size=block_size,
                max_seq_len=max_seq_len, num_pages=num_pages,
            )
            # chunk budget: tokens of prompt prefilled per step, fused
            # with decode.  Page-aligned so every chunk starts on a block
            # boundary; 0 disables chunking (stop-the-world admission,
            # the pre-chunked baseline).  MoE families always take the
            # stop-the-world path: capacity routing is group-composition
            # dependent, so chunk splits would change real tokens'
            # routing (same reason their prefill is never padded).
            if chunk_tokens is None:
                chunk_tokens = 2 * block_size
            if chunk_tokens and self.cfg.num_experts > 0:
                chunk_tokens = 0
            if chunk_tokens:
                chunk_tokens = min(chunk_tokens,
                                   self.cache.pages_per_seq * block_size)
                if chunk_tokens % block_size:
                    raise ValueError("chunk_tokens must be a multiple of "
                                     "the page/block size")
            self.chunk_tokens = chunk_tokens
            self.chunked = bool(chunk_tokens)
            self.kv = TieredKVManager(
                self.cache, self.adapter, self.manager,
                host_cache_pages=host_cache_pages, write_back=write_back,
            )
            self.executor = PagedExecutor(
                model, params, self.cache, chunk_tokens=chunk_tokens,
                max_seq_len=max_seq_len, seed=seed,
            )
            self.scheduler = Scheduler(
                self.executor, self.kv, self.tokenizer,
                max_batch=max_batch, max_seq_len=max_seq_len,
                chunk_tokens=chunk_tokens,
            )
            self._dense = None
        else:
            self.kv = None
            self.scheduler = None
            self._dense = DenseRuntime(
                model, params, self.tokenizer, self.adapter, self.manager,
                max_seq_len=max_seq_len, max_batch=max_batch,
                write_back=write_back, seed=seed,
            )
        self.stats = EngineStats()
        # streaming front door (worker thread started on demand)
        self.worker = StreamWorker(self)

    # ------------------------------------------------------------------
    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        if not requests:
            return []
        if self.running:
            raise RuntimeError(
                "engine worker loop is running; submit() requests instead "
                "of calling generate(), or stop() the worker first")
        if self.paged:
            return self.scheduler.run(requests)
        return self._dense.generate(requests)

    # ------------------------------------------------------------------
    # streaming: delegated to the StreamWorker (see serving/worker.py
    # for the loop, the single-writer queue-ownership invariant, and the
    # dense micro-batching inbox)
    # ------------------------------------------------------------------
    @property
    def running(self) -> bool:
        return self.worker.running

    @property
    def backlog(self) -> bool:
        return self.worker.backlog

    def submit(self, request: Request) -> Future:
        return self.worker.submit(request)

    def pump(self) -> bool:
        return self.worker.pump()

    def start(self) -> None:
        self.worker.start()

    def stop(self, *, drain: bool = True) -> None:
        self.worker.stop(drain=drain)

    # ------------------------------------------------------------------
    # facade surface: one stats / chunk-log / write-back view across the
    # layers (benchmarks reset stats by assignment; tests reset chunk_log)
    # ------------------------------------------------------------------
    @property
    def stats(self) -> EngineStats:
        return self._stats

    @stats.setter
    def stats(self, value: EngineStats) -> None:
        self._stats = value
        if self.paged:
            self.scheduler.stats = value
            self.kv.stats = value
        else:
            self._dense.stats = value

    @property
    def chunk_log(self) -> list[tuple[int, int, int]]:
        return self.scheduler.chunk_log

    @chunk_log.setter
    def chunk_log(self, value) -> None:
        self.scheduler.chunk_log = value

    @property
    def write_back(self) -> bool:
        return self.kv.write_back if self.paged else self._dense.write_back

    @write_back.setter
    def write_back(self, value: bool) -> None:
        if self.paged:
            self.kv.write_back = value
        else:
            self._dense.write_back = value

    def _chunk_buf(self, v: int) -> int:
        return self.executor.chunk_buf(v)
