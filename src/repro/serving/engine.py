"""Serving engine: batched generation with a SkyMemory prefix cache.

Per request: tokenize -> SkyMemory longest-prefix lookup (radix index +
constellation fetch) -> restore the block state -> prefill only the
uncached suffix -> batched decode.  New full blocks are written back to the
constellation (Set KVC), so repeated prompts/contexts hit more blocks --
the paper's §5 testbed loop, with the LEO cache simulated in-process.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import ConstellationKVC, KVCManager
from repro.models.model import Model
from repro.serving.request import GenerationResult, Request
from repro.serving.sampler import SamplingParams, sample
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer


@dataclass
class EngineStats:
    requests: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0


@dataclass
class _Seq:
    request: Request
    tokens: list[int]
    cached: int
    state: dict
    last_logits: jnp.ndarray  # [V] logits at the final prompt position
    out_ids: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        kvc: ConstellationKVC | None = None,
        block_size: int = 128,
        max_seq_len: int = 512,
        max_batch: int = 8,
        write_back: bool = True,
        seed: int = 0,
    ) -> None:
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.tokenizer = ByteTokenizer(self.cfg.vocab_size)
        self.max_seq_len = max_seq_len
        self.max_batch = max_batch
        self.write_back = write_back
        self.block_size = block_size
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self.adapter = SkyKVCAdapter(model, params)
        self.manager: KVCManager | None = None
        if kvc is not None:
            self.manager = KVCManager(
                self.tokenizer.encode, self.adapter.kvc_fn, kvc,
                block_size=block_size,
            )
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------------
    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        results: list[GenerationResult] = []
        for lo in range(0, len(requests), self.max_batch):
            results.extend(self._run_batch(requests[lo : lo + self.max_batch]))
        return results

    # ------------------------------------------------------------------
    def _prefill_one(self, req: Request) -> _Seq:
        t0 = time.perf_counter()
        tokens = self.tokenizer.encode(req.prompt)[: self.max_seq_len - 64]
        cached = 0
        prefix_state = None
        if self.manager is not None:
            # token-level lookup: coverage matches the (truncated) sequence
            # this engine will actually run
            payload, cached = self.manager.get_cache_tokens(tokens)
            if payload is not None:
                prefix_state = self.adapter.payload_to_state(payload)
        toks = jnp.asarray(tokens, jnp.int32)[None]
        if cached >= len(tokens):
            # whole prompt cached: replay the final token so the decode loop
            # has a starting distribution
            cached = len(tokens) - 1
        if cached:
            lg, _, state = self.model.forward(
                self.params, toks[:, cached:], q_offset=cached,
                prefix_state=prefix_state, collect_state=True,
            )
        else:
            lg, _, state = self.model.forward(
                self.params, toks, collect_state=True
            )
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.stats.cached_tokens += cached
        self.stats.prefilled_tokens += len(tokens) - cached
        if self.write_back and self.manager is not None:
            self.manager.add_blocks_tokens(tokens)
        return _Seq(request=req, tokens=tokens, cached=cached, state=state,
                    last_logits=lg[0, -1])

    def _stack_caches(self, seqs: list[_Seq]):
        cache = self.model.init_cache(len(seqs), self.max_seq_len)
        for i, s in enumerate(seqs):
            n = len(s.tokens)
            st = s.state
            if "kv" in st and "kv" in cache:
                cache["kv"]["k"] = cache["kv"]["k"].at[:, i, :n].set(
                    st["kv"]["k"][:, 0, :n])
                cache["kv"]["v"] = cache["kv"]["v"].at[:, i, :n].set(
                    st["kv"]["v"][:, 0, :n])
            if "mla" in st:
                cache["mla"]["ckv"] = cache["mla"]["ckv"].at[:, i, :n].set(
                    st["mla"]["ckv"][:, 0, :n])
                cache["mla"]["kr"] = cache["mla"]["kr"].at[:, i, :n].set(
                    st["mla"]["kr"][:, 0, :n])
            if "ssm" in st:
                cache["ssm"]["conv"] = cache["ssm"]["conv"].at[:, i].set(
                    st["ssm"]["conv"][:, 0])
                cache["ssm"]["state"] = cache["ssm"]["state"].at[:, i].set(
                    st["ssm"]["state"][:, 0].astype(cache["ssm"]["state"].dtype))
        return cache

    def _run_batch(self, requests: list[Request]) -> list[GenerationResult]:
        t_start = time.perf_counter()
        seqs = [self._prefill_one(r) for r in requests]
        cache = self._stack_caches(seqs)
        b = len(seqs)
        pos = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)

        # first token from each sequence's prefill logits
        logits = jnp.stack([s.last_logits for s in seqs])

        max_new = max(s.request.sampling.max_new_tokens for s in seqs)
        t_dec = time.perf_counter()
        for _step in range(max_new):
            self._key, k = jax.random.split(self._key)
            nxt = _sample_per_seq(logits, k, seqs)
            for i, s in enumerate(seqs):
                if s.done:
                    continue
                tid = int(nxt[i])
                s.out_ids.append(tid)
                if (tid == self.tokenizer.eos_id
                        or len(s.out_ids) >= s.request.sampling.max_new_tokens
                        or len(s.tokens) + len(s.out_ids) >= self.max_seq_len):
                    s.done = True
            self.stats.decoded_tokens += sum(0 if s.done else 1 for s in seqs)
            if all(s.done for s in seqs):
                break
            lg, cache = self._decode(self.params, cache, nxt[:, None], pos)
            logits = lg[:, 0]
            pos = pos + 1
        self.stats.decode_time_s += time.perf_counter() - t_dec

        out = []
        wall = time.perf_counter() - t_start
        for s in seqs:
            self.stats.requests += 1
            out.append(GenerationResult(
                request_id=s.request.request_id,
                prompt=s.request.prompt,
                text=self.tokenizer.decode(s.out_ids),
                token_ids=s.out_ids,
                prompt_tokens=len(s.tokens),
                cached_tokens=s.cached,
                prefill_tokens=len(s.tokens) - s.cached,
                wall_time_s=wall,
            ))
        return out


def _sample_per_seq(logits, key, seqs) -> jnp.ndarray:
    keys = jax.random.split(key, len(seqs))
    out = []
    for i, s in enumerate(seqs):
        out.append(sample(logits[i : i + 1], keys[i], s.request.sampling)[0])
    return jnp.stack(out)
