"""Serving engine: a paged, continuously-batched, chunked-prefill runtime.

Per request: tokenize -> SkyMemory longest-prefix lookup (radix index +
constellation fetch) -> drop fetched 128-token blocks straight into KV
pages -> prefill the uncached suffix in page-aligned *chunks* that ride
the decode step -> continuous-batching decode.  New full blocks are
written back to the constellation (Set KVC), so repeated prompts/contexts
hit more blocks -- the paper's §5 testbed loop, with the LEO cache
simulated in-process.

Architecture (see ``repro.serving`` package docstring for the full map):

* dense-attention families run the **paged runtime**: a ``PagedKVCache``
  pool (page size = the SkyMemory block size) lives on device across
  requests; each step is ONE jitted program -- decode for every slot
  (embed -> layers -> block-table paged attention -> vectorized sampler)
  plus, while an admission is in flight, one token-budgeted prefill
  chunk that writes its K/V into pool pages and attends over the
  SkyMemory-restored prefix *in place* (the paged chunked-prefill
  kernel).  Decode never pauses for admissions; a sequence's first
  token is sampled inside the step in which its last chunk lands.
* MoE families keep stop-the-world admission (capacity-based expert
  routing is group-composition dependent, so splitting a prompt into
  chunks would change its routing); their restored prefixes still live
  in pool pages.
* MLA / SSM / hybrid / encoder-decoder families keep the dense per-batch
  cache (their decode state is not plain per-token K/V) but share the
  vectorized sampler and the one-sync-per-step decode loop.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import ConstellationKVC, KVCManager
from repro.models.model import Model
from repro.serving.request import (
    FinishReason,
    GenerationResult,
    Request,
    SeqState,
)
from repro.serving.sampler import SamplingParams, sample_batch, stack_sampling
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer


def head_span(n_tokens: int, cursor: int, budget: int) -> tuple[int, int]:
    """The next chunk for a prompt of ``n_tokens`` prefilled up to
    ``cursor``: ``(start, length)`` with length at most ``budget``.  The
    scheduler consumes exactly this, one span per step."""
    return cursor, min(budget, n_tokens - cursor)


def chunk_spans(n_tokens: int, start: int, budget: int
                ) -> list[tuple[int, int]]:
    """The full chunk plan for a prompt of ``n_tokens`` whose pages are
    already valid up to ``start`` (a restored SkyMemory prefix, or the
    replay point of a whole-prompt hit): the ``head_span`` sequence,
    covering ``[start, n_tokens)`` in order.  Only the final span may be
    ragged, so every split lands on a page boundary whenever ``start``
    and ``budget`` are page-aligned."""
    spans = []
    cursor = start
    while cursor < n_tokens:
        s, v = head_span(n_tokens, cursor, budget)
        spans.append((s, v))
        cursor = s + v
    return spans


def _percentiles(xs: list[float]) -> dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    p50, p95, p99 = np.percentile(np.asarray(xs, np.float64), [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99)}


@dataclass
class EngineStats:
    requests: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    decode_steps: int = 0             # jitted step programs launched
    mid_decode_admissions: int = 0    # requests admitted into a live batch
    prefill_chunks: int = 0           # chunk programs fused into steps
    ttft_s: list[float] = field(default_factory=list)   # per request
    itl_s: list[float] = field(default_factory=list)    # per decoded token
    # the subset of itl_s observed by running sequences while an
    # admission was in flight -- the tail the chunked scheduler exists
    # to flatten (a whole-run p99 dilutes a few admission stalls away)
    itl_admission_s: list[float] = field(default_factory=list)

    def latency_percentiles(self) -> dict[str, dict[str, float]]:
        """p50/p95/p99 of time-to-first-token and inter-token latency --
        the serving SLO view of the run (tokens/s hides admission
        stalls; the ITL tail is where stop-the-world prefill shows)."""
        return {"ttft_s": _percentiles(self.ttft_s),
                "itl_s": _percentiles(self.itl_s),
                "itl_admission_s": _percentiles(self.itl_admission_s)}


@dataclass
class _Seq:
    request: Request
    tokens: list[int]
    state: SeqState = SeqState.QUEUED
    cached: int = 0
    out_ids: list[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = FinishReason.MAX_NEW_TOKENS.value
    enqueue_t: float = 0.0
    ttft_s: float = 0.0
    wall_s: float = 0.0
    # chunked-prefill state machine:
    reserve: int = 0                  # worst-case token footprint reserved
    cursor: int = 0                   # next prompt token to prefill
    looked_up: bool = False           # SkyMemory lookup done for this seq
    pages_future: object | None = None   # in-flight payload -> pages decode
    dev_ops: tuple | None = None      # per-admission device operands
    # legacy (non-paged) path only:
    dense_state: dict | None = None
    last_logits: jnp.ndarray | None = None


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        kvc: ConstellationKVC | None = None,
        block_size: int = 128,
        max_seq_len: int = 512,
        max_batch: int = 8,
        write_back: bool = True,
        seed: int = 0,
        num_pages: int | None = None,
        chunk_tokens: int | None = None,
    ) -> None:
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.tokenizer = ByteTokenizer(self.cfg.vocab_size)
        self.max_seq_len = max_seq_len
        self.max_batch = max_batch
        self.write_back = write_back
        self.block_size = block_size
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self.adapter = SkyKVCAdapter(model, params)
        self.manager: KVCManager | None = None
        self._wb_future = None        # in-flight async Set KVC write-back
        self.chunk_log: list[tuple[int, int, int]] = []  # (slot, start, n)
        if kvc is not None:
            self.manager = KVCManager(
                self.tokenizer.encode, self.adapter.kvc_fn, kvc,
                block_size=block_size,
            )
        self.paged = model.supports_paged_decode
        if self.paged:
            # page size == SkyMemory block size: fetched blocks are pages
            self.page_size = block_size
            self.cache = model.init_paged_cache(
                num_slots=max_batch, page_size=block_size,
                max_seq_len=max_seq_len, num_pages=num_pages,
            )
            # chunk budget: tokens of prompt prefilled per step, fused
            # with decode.  Page-aligned so every chunk starts on a block
            # boundary; 0 disables chunking (stop-the-world admission,
            # the pre-chunked baseline).  MoE families always take the
            # stop-the-world path: capacity routing is group-composition
            # dependent, so chunk splits would change real tokens'
            # routing (same reason their prefill is never padded).
            if chunk_tokens is None:
                chunk_tokens = 2 * block_size
            if chunk_tokens and self.cfg.num_experts > 0:
                chunk_tokens = 0
            if chunk_tokens:
                chunk_tokens = min(chunk_tokens,
                                   self.cache.pages_per_seq * block_size)
                if chunk_tokens % block_size:
                    raise ValueError("chunk_tokens must be a multiple of "
                                     "the page/block size")
            self.chunk_tokens = chunk_tokens
            self.chunked = bool(chunk_tokens)
            # pools are donated: on backends with donation support the
            # one-token write updates the cache in place instead of
            # copying the whole pool every step (CPU falls back to copy)
            self._step = jax.jit(self._paged_step,
                                 static_argnames=("mode",),
                                 donate_argnums=(1, 2))
            self._mixed = jax.jit(self._mixed_step,
                                  static_argnames=("mode",),
                                  donate_argnums=(1, 2))
            # cold-start admission waves: batched chunk steps (nothing is
            # decoding, so the whole wave prefills together)
            self._chunk_wave = jax.jit(self.model.prefill_chunk_paged,
                                       donate_argnums=(1, 2))
            self._prefill = jax.jit(
                lambda p, t: self.model.forward(p, t, collect_state=True)
            )
        else:
            self._decode = jax.jit(model.decode_step)
            self._sample = jax.jit(sample_batch)

    # ------------------------------------------------------------------
    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        if not requests:
            return []
        if self.paged:
            return self._generate_paged(requests)
        results: list[GenerationResult] = []
        for lo in range(0, len(requests), self.max_batch):
            results.extend(self._run_batch(requests[lo : lo + self.max_batch]))
        return results

    # ==================================================================
    # Paged runtime (dense-attention families)
    # ==================================================================
    def _decode_sample(self, params, k_pool, v_pool, block_tables, lengths,
                      tokens, key, temps, top_ks, top_ps, mode):
        """Decode every slot and sample its next token: the shared tail of
        the plain and mixed steps.

        ``mode`` is decided host-side from the *active slots'* sampling
        params (it only changes on admission/finish, so at most a few
        compilations): ``greedy`` is a pure argmax, ``temp`` skips the
        top-k/top-p sort machinery, ``full`` runs the general sampler.
        """
        logits, k_pool, v_pool = self.model.decode_step_paged(
            params, k_pool, v_pool, tokens[:, None], block_tables, lengths,
            contiguous=self.cache.contiguous,
        )
        lg = logits[:, 0]
        if mode == "greedy":
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        elif mode == "temp":
            lg32 = lg.astype(jnp.float32)
            greedy = jnp.argmax(lg32, axis=-1).astype(jnp.int32)
            is_greedy = temps <= 0.0
            scaled = lg32 / jnp.where(is_greedy, 1.0, temps)[:, None]
            sampled = jax.random.categorical(key, scaled, -1).astype(jnp.int32)
            nxt = jnp.where(is_greedy, greedy, sampled)
        else:
            nxt = sample_batch(lg, key, temps, top_ks, top_ps)
        return nxt, k_pool, v_pool

    def _paged_step(self, params, k_pool, v_pool, block_tables, lengths,
                    tokens, key, temps, top_ks, top_ps, *, mode):
        """One fused decode step: model + sampler, one device program."""
        return self._decode_sample(params, k_pool, v_pool, block_tables,
                                   lengths, tokens, key, temps, top_ks,
                                   top_ps, mode)

    def _mixed_step(self, params, k_pool, v_pool, block_tables, lengths,
                    tokens, key, temps, top_ks, top_ps,
                    c_toks, c_bt, c_off, c_valid, c_temp, c_tk, c_tp,
                    *, mode):
        """One fused mixed step: a prefill chunk rides the decode step.

        The chunk (``c_toks`` [1, C] at absolute offset ``c_off``,
        ``c_valid`` real tokens) writes its K/V into pool pages and
        attends over the SkyMemory-restored prefix + earlier chunks in
        place; then every slot decodes exactly as in the plain step, so
        running sequences never stall for an admission.  If this is the
        sequence's final chunk, its first output token is the extra id
        sampled here from the last valid chunk logit -- returned as row
        ``B`` of the token vector so the host still does ONE sync.
        ``c_off``/``c_valid`` are traced, so one compilation serves every
        chunk of every admission (no power-of-two prefill buckets).
        """
        kd, kc = jax.random.split(key)
        c_logits, k_pool, v_pool = self.model.prefill_chunk_paged(
            params, k_pool, v_pool, c_toks, c_bt, c_off, c_valid)
        c_tid = sample_batch(c_logits, kc, c_temp, c_tk, c_tp)
        nxt, k_pool, v_pool = self._decode_sample(
            params, k_pool, v_pool, block_tables, lengths, tokens, kd,
            temps, top_ks, top_ps, mode)
        return jnp.concatenate([nxt, c_tid]), k_pool, v_pool

    @staticmethod
    def _sampler_mode(samp: list[SamplingParams]) -> str:
        if any(p.top_k > 0 or p.top_p < 1.0 for p in samp
               if p.temperature > 0.0):
            return "full"
        if any(p.temperature > 0.0 for p in samp):
            return "temp"
        return "greedy"

    def _generate_paged(
        self, requests: list[Request]
    ) -> list[GenerationResult]:
        t_start = time.perf_counter()
        seqs = [self._make_seq(r) for r in requests]
        pending: deque[_Seq] = deque(seqs)
        active: dict[int, _Seq] = {}
        prefilling: dict[int, _Seq] = {}   # insertion order == chunk FIFO
        free_slots = list(range(self.max_batch - 1, -1, -1))
        b = self.max_batch
        self.chunk_log = []

        lengths_h = np.zeros(b, np.int32)
        tokens_h = np.zeros(b, np.int32)
        samp = [SamplingParams() for _ in range(b)]
        last_tok_t = [0.0] * b
        samp_dirty = bt_dirty = True
        admit_stall = False   # a stop-the-world wave ran under live decodes

        while pending or active or prefilling:
            # -- admission: fill freed slots from the queue ------------
            admitted: list[tuple[_Seq, int]] = []
            while (pending and free_slots
                   and self.cache.can_admit(
                       self._reserve_tokens(pending[0]))):
                s = pending.popleft()
                slot = free_slots.pop()
                # reserve pages NOW so can_admit for the rest of the wave
                # sees the shrunken free list (free-list pools)
                s.reserve = self._reserve_tokens(s)
                self.cache.ensure_capacity(slot, s.reserve)
                if active or prefilling:
                    self.stats.mid_decode_admissions += 1
                admitted.append((s, slot))
            if admitted:
                bt_dirty = True
                if self.chunked and (active or prefilling):
                    # decode is live: chunks ride the decode steps so no
                    # running sequence stalls for this admission
                    for s, slot in admitted:
                        s.state = SeqState.PREFILLING
                        prefilling[slot] = s
                        # park the slot's decode lane on its last reserved
                        # position: the idle lane's unconditional write
                        # lands where no chunk data lives and where any
                        # real decode write would overwrite it anyway
                        lengths_h[slot] = s.reserve - 1
                        tokens_h[slot] = 0
                else:
                    # nothing is decoding, so nothing can starve: prefill
                    # the whole wave now (as batched chunk steps when
                    # chunked, else the bucketed stop-the-world wave)
                    admit_stall = bool(active)
                    if self.chunked:
                        self._admit_wave_chunked(admitted, lengths_h,
                                                 tokens_h, samp)
                    else:
                        self._admit_wave(admitted, lengths_h, tokens_h,
                                         samp)
                    samp_dirty = True
                    now = time.perf_counter()
                    for s, slot in admitted:
                        if s.done:    # finished on its very first token
                            self._release(s, slot, lengths_h, tokens_h,
                                          samp)
                            free_slots.append(slot)
                        else:
                            active[slot] = s
                            last_tok_t[slot] = now
            if not (active or prefilling):
                if pending:
                    raise RuntimeError(
                        "cannot admit request: KV page pool too small for a "
                        f"{self._reserve_tokens(pending[0])}-token worst-case"
                        " footprint (prompt + max_new_tokens)")
                break

            # -- chunk scheduling: at most chunk_tokens prompt tokens ----
            chunk = self._plan_chunk(prefilling, bool(active))

            if samp_dirty:
                temps_d, tks_d, tps_d = stack_sampling(samp)
                mode = self._sampler_mode(samp)
                samp_dirty = False
            if bt_dirty:
                # contiguous slot regions need no table on device; free-list
                # pools upload the table only when admission/release (the
                # full worst-case span is reserved up front) changed it
                bt_d = (None if self.cache.contiguous
                        else jnp.asarray(self.cache.block_tables))
                bt_dirty = False
            len_d = jnp.asarray(lengths_h)
            tok_d = jnp.asarray(tokens_h)

            # -- one fused device step; ONE host sync (the token read) --
            self._key, k = jax.random.split(self._key)
            t0 = time.perf_counter()
            if chunk is None:
                nxt, k_pool, v_pool = self._step(
                    self.params, self.cache.k_pool, self.cache.v_pool,
                    bt_d, len_d, tok_d, k, temps_d, tks_d, tps_d, mode=mode,
                )
            else:
                s_c, slot_c, start_c, v_c, ops_c = chunk
                nxt, k_pool, v_pool = self._mixed(
                    self.params, self.cache.k_pool, self.cache.v_pool,
                    bt_d, len_d, tok_d, k, temps_d, tks_d, tps_d,
                    *ops_c, mode=mode,
                )
            self.cache.k_pool, self.cache.v_pool = k_pool, v_pool
            nxt_h = np.asarray(nxt)           # the step's single host sync
            now = time.perf_counter()
            self.stats.decode_time_s += now - t0
            self.stats.decode_steps += 1

            # -- host-side scheduling on the synced token ids ----------
            in_admission = bool(prefilling) or admit_stall
            admit_stall = False
            for slot, s in list(active.items()):
                tid = int(nxt_h[slot])
                s.out_ids.append(tid)
                self.stats.decoded_tokens += 1
                itl = now - last_tok_t[slot]
                self.stats.itl_s.append(itl)
                if in_admission:
                    self.stats.itl_admission_s.append(itl)
                last_tok_t[slot] = now
                lengths_h[slot] += 1
                if self._finished(s, tid):
                    active.pop(slot)
                    self._release(s, slot, lengths_h, tokens_h, samp)
                    free_slots.append(slot)
                    samp_dirty = bt_dirty = True
                else:
                    tokens_h[slot] = tid

            # -- chunk retirement --------------------------------------
            if chunk is not None:
                self.stats.prefill_chunks += 1
                s_c.cursor = start_c + v_c
                if s_c.cursor >= len(s_c.tokens):
                    # last chunk landed: its first token was sampled
                    # in-step (row b of the synced vector)
                    prefilling.pop(slot_c)
                    if self.write_back and self.manager is not None:
                        # Set KVC on the worker thread; the next
                        # sequence's lookup drains it, so duplicate
                        # contexts queued together still hit without the
                        # payload computation stalling running decodes
                        self._write_back_async(s_c.tokens)
                    self._finish_prefill(s_c, slot_c, int(nxt_h[b]), now,
                                         lengths_h, tokens_h, samp)
                    if s_c.done:
                        self._release(s_c, slot_c, lengths_h, tokens_h,
                                      samp)
                        free_slots.append(slot_c)
                    else:
                        active[slot_c] = s_c
                        last_tok_t[slot_c] = now
                    samp_dirty = bt_dirty = True

        self._drain_write_back()     # settle Set KVC before handing back
        wall = time.perf_counter() - t_start
        out = []
        for s in seqs:
            s.wall_s = wall
            out.append(self._result(s))
        return out

    def _plan_chunk(self, prefilling: dict[int, _Seq], have_active: bool):
        """Pick the next prefill chunk (FIFO over prefilling sequences).

        The head sequence's SkyMemory lookup happens lazily here -- after
        any earlier sequence's write-back, so duplicate contexts queued
        together still hit -- and its payload->pages decode runs on the
        adapter's fetch-ahead thread: when other sequences are decoding,
        the chunk is deferred one step so the deserialization overlaps
        that step's device compute instead of stalling the loop.
        Returns ``(seq, slot, start, n_valid, device_operands)`` or None.
        """
        if not self.chunked or not prefilling:
            return None
        slot = next(iter(prefilling))
        s = prefilling[slot]
        n = len(s.tokens)
        if not s.looked_up:
            t0 = time.perf_counter()
            self._lookup_and_prefetch(s)
            self.stats.prefill_time_s += time.perf_counter() - t0
        if s.pages_future is not None:
            if have_active and not s.pages_future.done():
                return None       # overlap payload decode with this step
            k_blocks, v_blocks = s.pages_future.result()
            s.pages_future = None
            self.cache.write_pages(slot, 0, k_blocks, v_blocks)
        start, v = head_span(n, s.cursor, self.chunk_tokens)
        self.cache.note_span(slot, start, v)
        self.chunk_log.append((slot, start, v))
        if s.dev_ops is None:
            # per-sequence invariants, uploaded once per admission: the
            # block-table row is frozen (worst-case pages reserved up
            # front) and sampling params never change per request
            s.dev_ops = (
                jnp.asarray(self.cache.table_row(slot)[None], jnp.int32),
                *stack_sampling([s.request.sampling]),
            )
        buf = np.zeros((1, self._chunk_buf(v)), np.int32)
        buf[0, :v] = s.tokens[start:start + v]
        bt_row, c_temp, c_tk, c_tp = s.dev_ops
        ops_c = (
            jnp.asarray(buf), bt_row,
            jnp.asarray([start], jnp.int32), jnp.asarray([v], jnp.int32),
            c_temp, c_tk, c_tp,
        )
        return s, slot, start, v, ops_c

    def _chunk_buf(self, v: int) -> int:
        """Chunk-buffer length for ``v`` valid tokens: the next power of
        two (floor 32), capped at the chunk budget.  Short prompts and
        ragged final chunks don't pay for a full-budget buffer, and the
        compile count is bounded by the (small) budget instead of
        max_seq_len -- the legacy O(log^2) whole-prompt buckets reduce to
        a handful of chunk-sized shapes."""
        b = 32
        while b < v:
            b *= 2
        return min(b, max(self.chunk_tokens, v))

    def _admit_wave_chunked(self, admitted: list[tuple[_Seq, int]],
                            lengths_h, tokens_h, samp) -> None:
        """Cold-start admission wave, chunked flavor: nothing is decoding,
        so the wave's prompts prefill *together* as lockstep batched chunk
        steps over the page pool -- the throughput of the old batched wave
        without its dense restaging or whole-prompt compile buckets.

        Phase 1 walks the wave in order: SkyMemory lookup, fetch-ahead
        payload decode (submitted per sequence, resolved after the loop so
        deserialization overlaps the later members' lookups/write-backs),
        and Set KVC write-back -- before the NEXT member's lookup, so
        duplicate contexts within one wave still hit.  Phase 2 runs
        batched chunk steps until every prompt is covered; each
        sequence's final-chunk logits are kept and the wave's first
        tokens are sampled in one call with one host sync."""
        t0 = time.perf_counter()
        for s, slot in admitted:
            s.state = SeqState.PREFILLING
            self._lookup_and_prefetch(s)
            if self.write_back and self.manager is not None:
                self._write_back_async(s.tokens)
        for s, slot in admitted:
            if s.pages_future is not None:
                k_blocks, v_blocks = s.pages_future.result()
                s.pages_future = None
                self.cache.write_pages(slot, 0, k_blocks, v_blocks)

        last_logits: dict[int, jnp.ndarray] = {}
        live = [(s, slot) for s, slot in admitted]
        while live:
            c_b = self._chunk_buf(max(
                min(self.chunk_tokens, len(s.tokens) - s.cursor)
                for s, _ in live))
            rows = 1
            while rows < len(live):          # pad batch rows to a power
                rows *= 2                    # of two: O(log max_batch)
            buf = np.zeros((rows, c_b), np.int32)
            offs = np.zeros(rows, np.int32)
            valids = np.zeros(rows, np.int32)   # padding rows are no-ops
            bts = np.zeros((rows, self.cache.pages_per_seq), np.int32)
            for i, (s, slot) in enumerate(live):
                start = s.cursor
                v = min(c_b, len(s.tokens) - start)
                buf[i, :v] = s.tokens[start:start + v]
                offs[i], valids[i] = start, v
                bts[i] = self.cache.table_row(slot)
                self.cache.note_span(slot, start, v)
                self.chunk_log.append((slot, start, v))
            lg, k_pool, v_pool = self._chunk_wave(
                self.params, self.cache.k_pool, self.cache.v_pool,
                jnp.asarray(buf), jnp.asarray(bts), jnp.asarray(offs),
                jnp.asarray(valids),
            )
            self.cache.k_pool, self.cache.v_pool = k_pool, v_pool
            self.stats.prefill_chunks += 1
            nxt_live = []
            for i, (s, slot) in enumerate(live):
                s.cursor = int(offs[i] + valids[i])
                if s.cursor >= len(s.tokens):
                    last_logits[id(s)] = lg[i]
                else:
                    nxt_live.append((s, slot))
            live = nxt_live

        self.stats.prefill_time_s += time.perf_counter() - t0

        # first tokens for the wave: one sample call, one host sync
        self._key, k = jax.random.split(self._key)
        t_arr, tk_arr, tp_arr = stack_sampling(
            [s.request.sampling for s, _ in admitted])
        tids = np.asarray(sample_batch(
            jnp.stack([last_logits[id(s)] for s, _ in admitted]),
            k, t_arr, tk_arr, tp_arr))
        now = time.perf_counter()
        for (s, slot), tid in zip(admitted, tids):
            self._finish_prefill(s, slot, int(tid), now, lengths_h,
                                 tokens_h, samp)

    def _lookup_and_prefetch(self, s: _Seq) -> None:
        """SkyMemory longest-prefix lookup for ``s``: on a hit, start the
        sequence at the cached boundary -- a whole-prompt hit keeps every
        restored block and replays only the final token through the paged
        chunk path (a one-token recompute, not a full page through a
        dense prefill) -- and submit the payload->pages decode to the
        adapter's fetch-ahead thread.  Any in-flight Set KVC write-back
        is drained first, so duplicate contexts queued together still
        hit (the paper's repeated-context workload)."""
        s.looked_up = True
        if self.manager is None:
            return
        self._drain_write_back()
        payload, cached = self.manager.get_cache_tokens(s.tokens)
        if payload is not None and cached:
            restore = cached
            if cached >= len(s.tokens):
                cached = len(s.tokens) - 1
            s.cached = cached
            s.cursor = cached
            s.pages_future = self.adapter.pages_async(
                payload, restore, self.page_size)

    def _write_back_async(self, tokens: list[int]) -> None:
        """Set KVC for a finished prefill *off* the decode loop: the
        block payload computation (one forward per uncached block) runs
        on the adapter's worker thread and the next sequence's lookup
        drains it, so write-back no longer stalls running decodes."""
        self._wb_future = self.adapter.run_async(
            self.manager.add_blocks_tokens, tokens)

    def _drain_write_back(self) -> None:
        if self._wb_future is not None:
            self._wb_future.result()
            self._wb_future = None

    def _finish_prefill(self, s: _Seq, slot: int, tid: int, now: float,
                        lengths_h, tokens_h, samp) -> None:
        """A sequence's last chunk landed: book its first token."""
        s.out_ids.append(tid)
        s.ttft_s = now - s.enqueue_t
        self.stats.ttft_s.append(s.ttft_s)
        self.stats.decoded_tokens += 1
        self.stats.cached_tokens += s.cached
        self.stats.prefilled_tokens += len(s.tokens) - s.cached
        s.state = SeqState.RUNNING
        if not self._finished(s, tid):
            lengths_h[slot] = len(s.tokens)
            tokens_h[slot] = tid
            samp[slot] = s.request.sampling

    def _make_seq(self, req: Request) -> _Seq:
        tokens = self.tokenizer.encode(req.prompt)[: self.max_seq_len - 64]
        return _Seq(request=req, tokens=tokens, enqueue_t=time.perf_counter())

    def _reserve_tokens(self, s: _Seq) -> int:
        """Worst-case token footprint: pages for this many tokens are
        reserved at admission so decode can never exhaust the pool."""
        return min(len(s.tokens) + s.request.sampling.max_new_tokens,
                   self.max_seq_len)

    def _bucket(self, n: int) -> int:
        """Prefill length bucket for stop-the-world admission (next power
        of two, floor 32, capped at max_seq_len).  The chunked scheduler
        needs no buckets: its one fixed chunk shape serves every prompt."""
        b = 32
        while b < n:
            b *= 2
        return min(b, max(n, self.max_seq_len))

    def _admit_wave(self, admitted: list[tuple[_Seq, int]],
                    lengths_h, tokens_h, samp) -> None:
        """Stop-the-world admission (MoE families / ``chunk_tokens=0``):
        SkyMemory hits restore blocks straight into pages and prefill only
        their suffix (per sequence); misses prefill as ONE batched,
        bucketed forward.  First tokens for the whole wave are sampled in
        one call with one host sync."""
        t0 = time.perf_counter()
        last_logits: list = []
        fresh: list[tuple[_Seq, int]] = []
        for s, slot in admitted:
            # (pages were already reserved in the admission loop)
            self._lookup_and_prefetch(s)
            if s.pages_future is not None:
                last_logits.append(self._prefill_suffix_paged(s, slot))
            elif self.cfg.num_experts > 0:
                # MoE: capacity-based expert routing is group-composition
                # dependent, so bucket padding would alter real tokens'
                # routing -- prefill exactly, one sequence at a time
                s.cached = 0
                last_logits.append(self._prefill_exact(s, slot))
            else:
                s.cached = 0
                fresh.append((s, slot))
                last_logits.append(None)
            if self.write_back and self.manager is not None:
                # Set KVC now, before the NEXT wave member's lookup, so
                # duplicate contexts within one admission wave still hit
                # (the paper's repeated-context workload)
                self.manager.add_blocks_tokens(s.tokens)

        if fresh:
            # one batched forward per length bucket; causal masking makes
            # the zero padding past each row's length invisible
            by_bucket: dict[int, list[int]] = {}
            for i, (s, _) in enumerate(fresh):
                by_bucket.setdefault(self._bucket(len(s.tokens)), []).append(i)
            fresh_logits: dict[int, jnp.ndarray] = {}
            for bucket, idxs in by_bucket.items():
                rows = 1
                while rows < len(idxs):      # pad batch dim to a power of
                    rows *= 2                # two: O(log^2) compilations
                toks = np.zeros((rows, bucket), np.int32)
                for row, i in enumerate(idxs):
                    toks[row, : len(fresh[i][0].tokens)] = fresh[i][0].tokens
                lg, _, state = self._prefill(self.params, jnp.asarray(toks))
                for row, i in enumerate(idxs):
                    s, slot = fresh[i]
                    n = len(s.tokens)
                    self.cache.write_token_span(
                        slot, 0,
                        state["kv"]["k"][:, row, :n],
                        state["kv"]["v"][:, row, :n],
                    )
                    fresh_logits[i] = lg[row, n - 1]
            fi = 0
            for j, lgt in enumerate(last_logits):
                if lgt is None:
                    last_logits[j] = fresh_logits[fi]
                    fi += 1

        self.stats.prefill_time_s += time.perf_counter() - t0

        # first tokens for the wave from the prefill logits: one sample
        # call, one host sync (at admission, not in the decode loop)
        self._key, k = jax.random.split(self._key)
        t_arr, tk_arr, tp_arr = stack_sampling(
            [s.request.sampling for s, _ in admitted])
        tids = np.asarray(sample_batch(
            jnp.stack(last_logits), k, t_arr, tk_arr, tp_arr))
        now = time.perf_counter()
        for (s, slot), tid in zip(admitted, tids):
            self._finish_prefill(s, slot, int(tid), now, lengths_h,
                                 tokens_h, samp)

    def _prefill_exact(self, s: _Seq, slot: int):
        """Unpadded, per-sequence prefill (MoE families, where padding
        would perturb capacity-based routing of real tokens)."""
        n = len(s.tokens)
        toks = jnp.asarray(s.tokens, jnp.int32)[None]
        lg, _, state = self.model.forward(
            self.params, toks, collect_state=True)
        self.cache.write_token_span(
            slot, 0,
            state["kv"]["k"][:, 0, :n],
            state["kv"]["v"][:, 0, :n],
        )
        return lg[0, n - 1]

    def _prefill_suffix_paged(self, s: _Seq, slot: int):
        """SkyMemory hit under stop-the-world admission (the sequence's
        lookup already ran): fetched blocks drop straight into pool pages
        and the uncached suffix runs as ONE paged chunk attending over
        them *in place* -- no dense ``prefix_state`` restaging anywhere
        in the paged families.  A whole-prompt hit keeps every restored
        block and replays only the final token (the chunk machinery
        handles the one-token, unaligned-start span)."""
        n = len(s.tokens)
        k_blocks, v_blocks = s.pages_future.result()
        s.pages_future = None
        self.cache.write_pages(slot, 0, k_blocks, v_blocks)
        start = s.cursor
        v = n - start
        self.cache.note_span(slot, start, v)
        self.chunk_log.append((slot, start, v))
        toks = np.asarray(s.tokens[start:], np.int32)[None]
        lg, k_pool, v_pool = self.model.prefill_chunk_paged(
            self.params, self.cache.k_pool, self.cache.v_pool,
            jnp.asarray(toks),
            jnp.asarray(self.cache.table_row(slot)[None], jnp.int32),
            jnp.asarray([start], jnp.int32), jnp.asarray([v], jnp.int32),
        )
        self.cache.k_pool, self.cache.v_pool = k_pool, v_pool
        return lg[0]

    def _finished(self, s: _Seq, tid: int) -> bool:
        if tid == self.tokenizer.eos_id:
            s.done, s.finish_reason = True, FinishReason.EOS.value
        elif len(s.out_ids) >= s.request.sampling.max_new_tokens:
            s.done = True
            s.finish_reason = FinishReason.MAX_NEW_TOKENS.value
        elif len(s.tokens) + len(s.out_ids) >= self.max_seq_len:
            s.done = True
            s.finish_reason = FinishReason.MAX_SEQ_LEN.value
        return s.done

    def _release(self, s: _Seq, slot: int, lengths_h, tokens_h, samp):
        s.state = SeqState.FINISHED
        self.cache.free_slot(slot)
        lengths_h[slot] = 0
        tokens_h[slot] = 0
        samp[slot] = SamplingParams()
        self.stats.requests += 1

    def _result(self, s: _Seq) -> GenerationResult:
        return GenerationResult(
            request_id=s.request.request_id,
            prompt=s.request.prompt,
            text=self.tokenizer.decode(s.out_ids),
            token_ids=s.out_ids,
            prompt_tokens=len(s.tokens),
            cached_tokens=s.cached,
            prefill_tokens=len(s.tokens) - s.cached,
            wall_time_s=s.wall_s,
            ttft_s=s.ttft_s,
            finish_reason=s.finish_reason,
        )

    # ==================================================================
    # Dense runtime (MLA / SSM / hybrid / enc-dec families)
    # ==================================================================
    def _prefill_one(self, req: Request) -> _Seq:
        t0 = time.perf_counter()
        s = self._make_seq(req)
        tokens = s.tokens
        cached = 0
        prefix_state = None
        if self.manager is not None:
            payload, cached = self.manager.get_cache_tokens(tokens)
            if payload is not None:
                prefix_state = self.adapter.payload_to_state(payload)
        toks = jnp.asarray(tokens, jnp.int32)[None]
        if cached >= len(tokens):
            # whole prompt cached: replay the final token so the decode
            # loop has a starting distribution
            cached = len(tokens) - 1
        if cached:
            lg, _, state = self.model.forward(
                self.params, toks[:, cached:], q_offset=cached,
                prefix_state=prefix_state, collect_state=True,
            )
        else:
            lg, _, state = self.model.forward(
                self.params, toks, collect_state=True
            )
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.stats.cached_tokens += cached
        self.stats.prefilled_tokens += len(tokens) - cached
        if self.write_back and self.manager is not None:
            self.manager.add_blocks_tokens(tokens)
        s.cached = cached
        s.dense_state = state
        s.last_logits = lg[0, -1]
        s.state = SeqState.RUNNING
        return s

    def _stack_dense_caches(self, seqs: list[_Seq]):
        """Dense prefill->decode handoff for the NON-paged families only
        (MLA latents, SSM state, hybrid, enc-dec): per-sequence states are
        restacked into one batched cache.  Paged families never come here
        -- their blocks were written into pool pages at admission."""
        cache = self.model.init_cache(len(seqs), self.max_seq_len)
        for i, s in enumerate(seqs):
            n = len(s.tokens)
            st = s.dense_state
            if "kv" in st and "kv" in cache:
                cache["kv"]["k"] = cache["kv"]["k"].at[:, i, :n].set(
                    st["kv"]["k"][:, 0, :n])
                cache["kv"]["v"] = cache["kv"]["v"].at[:, i, :n].set(
                    st["kv"]["v"][:, 0, :n])
            if "mla" in st:
                cache["mla"]["ckv"] = cache["mla"]["ckv"].at[:, i, :n].set(
                    st["mla"]["ckv"][:, 0, :n])
                cache["mla"]["kr"] = cache["mla"]["kr"].at[:, i, :n].set(
                    st["mla"]["kr"][:, 0, :n])
            if "ssm" in st:
                cache["ssm"]["conv"] = cache["ssm"]["conv"].at[:, i].set(
                    st["ssm"]["conv"][:, 0])
                cache["ssm"]["state"] = cache["ssm"]["state"].at[:, i].set(
                    st["ssm"]["state"][:, 0].astype(cache["ssm"]["state"].dtype))
        return cache

    def _run_batch(self, requests: list[Request]) -> list[GenerationResult]:
        t_start = time.perf_counter()
        seqs = [self._prefill_one(r) for r in requests]
        cache = self._stack_dense_caches(seqs)
        pos = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)

        # first token of each sequence from its prefill logits
        logits = jnp.stack([s.last_logits for s in seqs])
        temps_d, tks_d, tps_d = stack_sampling(
            [s.request.sampling for s in seqs])

        max_new = max(s.request.sampling.max_new_tokens for s in seqs)
        t_dec = time.perf_counter()
        first = True
        last_tok_t = [0.0] * len(seqs)
        for _step in range(max_new):
            self._key, k = jax.random.split(self._key)
            nxt = self._sample(logits, k, temps_d, tks_d, tps_d)
            nxt_h = np.asarray(nxt)           # the step's single host sync
            now = time.perf_counter()
            for i, s in enumerate(seqs):
                if s.done:
                    continue
                tid = int(nxt_h[i])
                s.out_ids.append(tid)
                if first:
                    s.ttft_s = now - s.enqueue_t
                    self.stats.ttft_s.append(s.ttft_s)
                else:
                    self.stats.itl_s.append(now - last_tok_t[i])
                last_tok_t[i] = now
                self._finished(s, tid)
            first = False
            self.stats.decoded_tokens += sum(
                0 if s.done else 1 for s in seqs)
            if all(s.done for s in seqs):
                break
            lg, cache = self._decode(self.params, cache, nxt[:, None], pos)
            self.stats.decode_steps += 1
            logits = lg[:, 0]
            pos = pos + 1
        self.stats.decode_time_s += time.perf_counter() - t_dec

        out = []
        wall = time.perf_counter() - t_start
        for s in seqs:
            self.stats.requests += 1
            s.state = SeqState.FINISHED
            s.wall_s = wall
            out.append(self._result(s))
        return out
