"""Serving engine: a paged, continuously-batched, device-resident runtime.

Per request: tokenize -> SkyMemory longest-prefix lookup (radix index +
constellation fetch) -> drop fetched 128-token blocks straight into KV
pages -> prefill only the uncached suffix -> continuous-batching decode.
New full blocks are written back to the constellation (Set KVC), so
repeated prompts/contexts hit more blocks -- the paper's §5 testbed loop,
with the LEO cache simulated in-process.

Architecture (see ``repro.serving`` package docstring for the full map):

* dense-attention families run the **paged runtime**: a ``PagedKVCache``
  pool (page size = the SkyMemory block size) lives on device across
  requests; each decode step is ONE jitted program (embed -> layers ->
  block-table paged attention -> vectorized sampler) over every slot, and
  the only host sync per step is reading the sampled token ids for EOS /
  scheduling.  Freed slots readmit queued requests mid-decode.
* MLA / SSM / hybrid / encoder-decoder families keep the dense per-batch
  cache (their decode state is not plain per-token K/V) but share the
  vectorized sampler and the one-sync-per-step decode loop.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import ConstellationKVC, KVCManager
from repro.models.model import Model
from repro.serving.request import (
    FinishReason,
    GenerationResult,
    Request,
    SeqState,
)
from repro.serving.sampler import SamplingParams, sample_batch, stack_sampling
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.tokenizer import ByteTokenizer


@dataclass
class EngineStats:
    requests: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    decoded_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    decode_steps: int = 0             # jitted step programs launched
    mid_decode_admissions: int = 0    # requests admitted into a live batch


@dataclass
class _Seq:
    request: Request
    tokens: list[int]
    state: SeqState = SeqState.QUEUED
    cached: int = 0
    out_ids: list[int] = field(default_factory=list)
    done: bool = False
    finish_reason: str = FinishReason.MAX_NEW_TOKENS.value
    enqueue_t: float = 0.0
    ttft_s: float = 0.0
    wall_s: float = 0.0
    # legacy (non-paged) path only:
    dense_state: dict | None = None
    last_logits: jnp.ndarray | None = None


class Engine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        kvc: ConstellationKVC | None = None,
        block_size: int = 128,
        max_seq_len: int = 512,
        max_batch: int = 8,
        write_back: bool = True,
        seed: int = 0,
        num_pages: int | None = None,
    ) -> None:
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.tokenizer = ByteTokenizer(self.cfg.vocab_size)
        self.max_seq_len = max_seq_len
        self.max_batch = max_batch
        self.write_back = write_back
        self.block_size = block_size
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self.adapter = SkyKVCAdapter(model, params)
        self.manager: KVCManager | None = None
        if kvc is not None:
            self.manager = KVCManager(
                self.tokenizer.encode, self.adapter.kvc_fn, kvc,
                block_size=block_size,
            )
        self.paged = model.supports_paged_decode
        if self.paged:
            # page size == SkyMemory block size: fetched blocks are pages
            self.page_size = block_size
            self.cache = model.init_paged_cache(
                num_slots=max_batch, page_size=block_size,
                max_seq_len=max_seq_len, num_pages=num_pages,
            )
            # pools are donated: on backends with donation support the
            # one-token write updates the cache in place instead of
            # copying the whole pool every step (CPU falls back to copy)
            self._step = jax.jit(self._paged_step,
                                 static_argnames=("mode",),
                                 donate_argnums=(1, 2))
            self._prefill = jax.jit(
                lambda p, t: self.model.forward(p, t, collect_state=True)
            )
        else:
            self._decode = jax.jit(model.decode_step)
            self._sample = jax.jit(sample_batch)

    # ------------------------------------------------------------------
    def generate(self, requests: list[Request]) -> list[GenerationResult]:
        if not requests:
            return []
        if self.paged:
            return self._generate_paged(requests)
        results: list[GenerationResult] = []
        for lo in range(0, len(requests), self.max_batch):
            results.extend(self._run_batch(requests[lo : lo + self.max_batch]))
        return results

    # ==================================================================
    # Paged runtime (dense-attention families)
    # ==================================================================
    def _paged_step(self, params, k_pool, v_pool, block_tables, lengths,
                    tokens, key, temps, top_ks, top_ps, *, mode):
        """One fused decode step: model + sampler, one device program.

        ``mode`` is decided host-side from the *active slots'* sampling
        params (it only changes on admission/finish, so at most a few
        compilations): ``greedy`` is a pure argmax, ``temp`` skips the
        top-k/top-p sort machinery, ``full`` runs the general sampler.
        """
        logits, k_pool, v_pool = self.model.decode_step_paged(
            params, k_pool, v_pool, tokens[:, None], block_tables, lengths,
            contiguous=self.cache.contiguous,
        )
        lg = logits[:, 0]
        if mode == "greedy":
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        elif mode == "temp":
            lg32 = lg.astype(jnp.float32)
            greedy = jnp.argmax(lg32, axis=-1).astype(jnp.int32)
            is_greedy = temps <= 0.0
            scaled = lg32 / jnp.where(is_greedy, 1.0, temps)[:, None]
            sampled = jax.random.categorical(key, scaled, -1).astype(jnp.int32)
            nxt = jnp.where(is_greedy, greedy, sampled)
        else:
            nxt = sample_batch(lg, key, temps, top_ks, top_ps)
        return nxt, k_pool, v_pool

    @staticmethod
    def _sampler_mode(samp: list[SamplingParams]) -> str:
        if any(p.top_k > 0 or p.top_p < 1.0 for p in samp
               if p.temperature > 0.0):
            return "full"
        if any(p.temperature > 0.0 for p in samp):
            return "temp"
        return "greedy"

    def _generate_paged(
        self, requests: list[Request]
    ) -> list[GenerationResult]:
        t_start = time.perf_counter()
        seqs = [self._make_seq(r) for r in requests]
        pending: deque[_Seq] = deque(seqs)
        active: dict[int, _Seq] = {}
        free_slots = list(range(self.max_batch - 1, -1, -1))
        b = self.max_batch

        lengths_h = np.zeros(b, np.int32)
        tokens_h = np.zeros(b, np.int32)
        samp = [SamplingParams() for _ in range(b)]
        samp_dirty = bt_dirty = True

        while pending or active:
            # -- admission: fill freed slots from the queue ------------
            admitted: list[tuple[_Seq, int]] = []
            while (pending and free_slots
                   and self.cache.can_admit(
                       self._reserve_tokens(pending[0]))):
                s = pending.popleft()
                slot = free_slots.pop()
                # reserve pages NOW so can_admit for the rest of the wave
                # sees the shrunken free list (free-list pools)
                self.cache.ensure_capacity(slot, self._reserve_tokens(s))
                if active:
                    self.stats.mid_decode_admissions += 1
                admitted.append((s, slot))
            if admitted:
                self._admit_wave(admitted, lengths_h, tokens_h, samp)
                samp_dirty = bt_dirty = True
                for s, slot in admitted:
                    if s.done:        # finished on its very first token
                        self._release(s, slot, lengths_h, tokens_h, samp)
                        free_slots.append(slot)
                    else:
                        active[slot] = s
            if not active:
                if pending:
                    raise RuntimeError(
                        "cannot admit request: KV page pool too small for a "
                        f"{self._reserve_tokens(pending[0])}-token worst-case"
                        " footprint (prompt + max_new_tokens)")
                break

            if samp_dirty:
                temps_d, tks_d, tps_d = stack_sampling(samp)
                mode = self._sampler_mode(samp)
                samp_dirty = False
            if bt_dirty:
                # contiguous slot regions need no table on device; free-list
                # pools upload the table only when admission/release (the
                # full worst-case span is reserved up front) changed it
                bt_d = (None if self.cache.contiguous
                        else jnp.asarray(self.cache.block_tables))
                bt_dirty = False
            len_d = jnp.asarray(lengths_h)
            tok_d = jnp.asarray(tokens_h)

            # -- one fused device step; ONE host sync (the token read) --
            self._key, k = jax.random.split(self._key)
            t0 = time.perf_counter()
            nxt, k_pool, v_pool = self._step(
                self.params, self.cache.k_pool, self.cache.v_pool,
                bt_d, len_d, tok_d, k, temps_d, tks_d, tps_d, mode=mode,
            )
            self.cache.k_pool, self.cache.v_pool = k_pool, v_pool
            nxt_h = np.asarray(nxt)           # the step's single host sync
            self.stats.decode_time_s += time.perf_counter() - t0
            self.stats.decode_steps += 1

            # -- host-side scheduling on the synced token ids ----------
            for slot, s in list(active.items()):
                tid = int(nxt_h[slot])
                s.out_ids.append(tid)
                self.stats.decoded_tokens += 1
                lengths_h[slot] += 1
                if self._finished(s, tid):
                    active.pop(slot)
                    self._release(s, slot, lengths_h, tokens_h, samp)
                    free_slots.append(slot)
                    samp_dirty = bt_dirty = True
                else:
                    tokens_h[slot] = tid

        wall = time.perf_counter() - t_start
        out = []
        for s in seqs:
            s.wall_s = wall
            out.append(self._result(s))
        return out

    def _make_seq(self, req: Request) -> _Seq:
        tokens = self.tokenizer.encode(req.prompt)[: self.max_seq_len - 64]
        return _Seq(request=req, tokens=tokens, enqueue_t=time.perf_counter())

    def _reserve_tokens(self, s: _Seq) -> int:
        """Worst-case token footprint: pages for this many tokens are
        reserved at admission so decode can never exhaust the pool."""
        return min(len(s.tokens) + s.request.sampling.max_new_tokens,
                   self.max_seq_len)

    def _bucket(self, n: int) -> int:
        """Prefill length bucket (next power of two, floor 32, capped at
        max_seq_len): bounds the number of distinct prefill compilations
        to O(log max_seq_len) without padding past the sequence cap."""
        b = 32
        while b < n:
            b *= 2
        return min(b, max(n, self.max_seq_len))

    def _admit_wave(self, admitted: list[tuple[_Seq, int]],
                    lengths_h, tokens_h, samp) -> None:
        """Prefill a wave of admissions: SkyMemory hits restore blocks
        straight into pages and prefill only their suffix (per sequence);
        misses prefill as ONE batched, bucketed forward.  First tokens for
        the whole wave are sampled in one call with one host sync."""
        t0 = time.perf_counter()
        last_logits: list = []
        fresh: list[tuple[_Seq, int]] = []
        for s, slot in admitted:
            # (pages were already reserved in the admission loop)
            n = len(s.tokens)
            payload = cached = None
            if self.manager is not None:
                payload, cached = self.manager.get_cache_tokens(s.tokens)
                if payload is not None and cached >= n:
                    # whole prompt cached: replay the final block so the
                    # decode loop has a starting distribution (keeps page
                    # alignment)
                    cached = max(0, cached - self.page_size)
            if payload is not None and cached:
                last_logits.append(
                    self._prefill_with_prefix(s, slot, payload, cached))
            elif self.cfg.num_experts > 0:
                # MoE: capacity-based expert routing is group-composition
                # dependent, so bucket padding would alter real tokens'
                # routing -- prefill exactly, one sequence at a time
                s.cached = 0
                last_logits.append(self._prefill_exact(s, slot))
            else:
                s.cached = 0
                fresh.append((s, slot))
                last_logits.append(None)
            if self.write_back and self.manager is not None:
                # Set KVC now, before the NEXT wave member's lookup, so
                # duplicate contexts within one admission wave still hit
                # (the paper's repeated-context workload)
                self.manager.add_blocks_tokens(s.tokens)

        if fresh:
            # one batched forward per length bucket; causal masking makes
            # the zero padding past each row's length invisible
            by_bucket: dict[int, list[int]] = {}
            for i, (s, _) in enumerate(fresh):
                by_bucket.setdefault(self._bucket(len(s.tokens)), []).append(i)
            fresh_logits: dict[int, jnp.ndarray] = {}
            for bucket, idxs in by_bucket.items():
                rows = 1
                while rows < len(idxs):      # pad batch dim to a power of
                    rows *= 2                # two: O(log^2) compilations
                toks = np.zeros((rows, bucket), np.int32)
                for row, i in enumerate(idxs):
                    toks[row, : len(fresh[i][0].tokens)] = fresh[i][0].tokens
                lg, _, state = self._prefill(self.params, jnp.asarray(toks))
                for row, i in enumerate(idxs):
                    s, slot = fresh[i]
                    n = len(s.tokens)
                    self.cache.write_token_span(
                        slot, 0,
                        state["kv"]["k"][:, row, :n],
                        state["kv"]["v"][:, row, :n],
                    )
                    fresh_logits[i] = lg[row, n - 1]
            fi = 0
            for j, lgt in enumerate(last_logits):
                if lgt is None:
                    last_logits[j] = fresh_logits[fi]
                    fi += 1

        for s, slot in admitted:
            self.stats.cached_tokens += s.cached
            self.stats.prefilled_tokens += len(s.tokens) - s.cached
            s.state = SeqState.RUNNING
        self.stats.prefill_time_s += time.perf_counter() - t0

        # first tokens for the wave from the prefill logits: one sample
        # call, one host sync (at admission, not in the decode loop)
        self._key, k = jax.random.split(self._key)
        t_arr, tk_arr, tp_arr = stack_sampling(
            [s.request.sampling for s, _ in admitted])
        tids = np.asarray(sample_batch(
            jnp.stack(last_logits), k, t_arr, tk_arr, tp_arr))
        now = time.perf_counter()
        for (s, slot), tid in zip(admitted, tids):
            tid = int(tid)
            s.out_ids.append(tid)
            s.ttft_s = now - s.enqueue_t
            self.stats.decoded_tokens += 1
            if not self._finished(s, tid):
                lengths_h[slot] = len(s.tokens)
                tokens_h[slot] = tid
                samp[slot] = s.request.sampling

    def _prefill_exact(self, s: _Seq, slot: int):
        """Unpadded, per-sequence prefill (MoE families, where padding
        would perturb capacity-based routing of real tokens)."""
        n = len(s.tokens)
        toks = jnp.asarray(s.tokens, jnp.int32)[None]
        lg, _, state = self.model.forward(
            self.params, toks, collect_state=True)
        self.cache.write_token_span(
            slot, 0,
            state["kv"]["k"][:, 0, :n],
            state["kv"]["v"][:, 0, :n],
        )
        return lg[0, n - 1]

    def _prefill_with_prefix(self, s: _Seq, slot: int, payload: bytes,
                             cached: int):
        """SkyMemory hit: fetched blocks drop straight into pool pages (no
        dense restacking) and only the uncached suffix runs through the
        model, attending over the restored prefix."""
        n = len(s.tokens)
        # 1. constellation blocks -> pages
        k_blocks, v_blocks = self.adapter.payload_to_pages(
            payload, cached, self.page_size)
        self.cache.write_pages(slot, 0, k_blocks, v_blocks)
        # 2. suffix prefill attends over the restored prefix -- built from
        # the page tensors already decoded above (one deserialization)
        la, _, _, hkv, hd = k_blocks.shape
        prefix_state = {
            "kv": {
                "k": k_blocks.reshape(la, cached, hkv, hd)[:, None],
                "v": v_blocks.reshape(la, cached, hkv, hd)[:, None],
            }
        }
        toks = jnp.asarray(s.tokens, jnp.int32)[None]
        lg, _, state = self.model.forward(
            self.params, toks[:, cached:], q_offset=cached,
            prefix_state=prefix_state, collect_state=True,
        )
        # forward returns prefix+suffix K/V; only the suffix is new
        self.cache.write_token_span(
            slot, cached,
            state["kv"]["k"][:, 0, cached:n],
            state["kv"]["v"][:, 0, cached:n],
        )
        s.cached = cached
        return lg[0, -1]

    def _finished(self, s: _Seq, tid: int) -> bool:
        if tid == self.tokenizer.eos_id:
            s.done, s.finish_reason = True, FinishReason.EOS.value
        elif len(s.out_ids) >= s.request.sampling.max_new_tokens:
            s.done = True
            s.finish_reason = FinishReason.MAX_NEW_TOKENS.value
        elif len(s.tokens) + len(s.out_ids) >= self.max_seq_len:
            s.done = True
            s.finish_reason = FinishReason.MAX_SEQ_LEN.value
        return s.done

    def _release(self, s: _Seq, slot: int, lengths_h, tokens_h, samp):
        s.state = SeqState.FINISHED
        self.cache.free_slot(slot)
        lengths_h[slot] = 0
        tokens_h[slot] = 0
        samp[slot] = SamplingParams()
        self.stats.requests += 1

    def _result(self, s: _Seq) -> GenerationResult:
        return GenerationResult(
            request_id=s.request.request_id,
            prompt=s.request.prompt,
            text=self.tokenizer.decode(s.out_ids),
            token_ids=s.out_ids,
            prompt_tokens=len(s.tokens),
            cached_tokens=s.cached,
            prefill_tokens=len(s.tokens) - s.cached,
            wall_time_s=s.wall_s,
            ttft_s=s.ttft_s,
            finish_reason=s.finish_reason,
        )

    # ==================================================================
    # Dense runtime (MLA / SSM / hybrid / enc-dec families)
    # ==================================================================
    def _prefill_one(self, req: Request) -> _Seq:
        t0 = time.perf_counter()
        s = self._make_seq(req)
        tokens = s.tokens
        cached = 0
        prefix_state = None
        if self.manager is not None:
            payload, cached = self.manager.get_cache_tokens(tokens)
            if payload is not None:
                prefix_state = self.adapter.payload_to_state(payload)
        toks = jnp.asarray(tokens, jnp.int32)[None]
        if cached >= len(tokens):
            # whole prompt cached: replay the final token so the decode
            # loop has a starting distribution
            cached = len(tokens) - 1
        if cached:
            lg, _, state = self.model.forward(
                self.params, toks[:, cached:], q_offset=cached,
                prefix_state=prefix_state, collect_state=True,
            )
        else:
            lg, _, state = self.model.forward(
                self.params, toks, collect_state=True
            )
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.stats.cached_tokens += cached
        self.stats.prefilled_tokens += len(tokens) - cached
        if self.write_back and self.manager is not None:
            self.manager.add_blocks_tokens(tokens)
        s.cached = cached
        s.dense_state = state
        s.last_logits = lg[0, -1]
        s.state = SeqState.RUNNING
        return s

    def _stack_dense_caches(self, seqs: list[_Seq]):
        """Dense prefill->decode handoff for the NON-paged families only
        (MLA latents, SSM state, hybrid, enc-dec): per-sequence states are
        restacked into one batched cache.  Paged families never come here
        -- their blocks were written into pool pages at admission."""
        cache = self.model.init_cache(len(seqs), self.max_seq_len)
        for i, s in enumerate(seqs):
            n = len(s.tokens)
            st = s.dense_state
            if "kv" in st and "kv" in cache:
                cache["kv"]["k"] = cache["kv"]["k"].at[:, i, :n].set(
                    st["kv"]["k"][:, 0, :n])
                cache["kv"]["v"] = cache["kv"]["v"].at[:, i, :n].set(
                    st["kv"]["v"][:, 0, :n])
            if "mla" in st:
                cache["mla"]["ckv"] = cache["mla"]["ckv"].at[:, i, :n].set(
                    st["mla"]["ckv"][:, 0, :n])
                cache["mla"]["kr"] = cache["mla"]["kr"].at[:, i, :n].set(
                    st["mla"]["kr"][:, 0, :n])
            if "ssm" in st:
                cache["ssm"]["conv"] = cache["ssm"]["conv"].at[:, i].set(
                    st["ssm"]["conv"][:, 0])
                cache["ssm"]["state"] = cache["ssm"]["state"].at[:, i].set(
                    st["ssm"]["state"][:, 0].astype(cache["ssm"]["state"].dtype))
        return cache

    def _run_batch(self, requests: list[Request]) -> list[GenerationResult]:
        t_start = time.perf_counter()
        seqs = [self._prefill_one(r) for r in requests]
        cache = self._stack_dense_caches(seqs)
        pos = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)

        # first token of each sequence from its prefill logits
        logits = jnp.stack([s.last_logits for s in seqs])
        temps_d, tks_d, tps_d = stack_sampling(
            [s.request.sampling for s in seqs])

        max_new = max(s.request.sampling.max_new_tokens for s in seqs)
        t_dec = time.perf_counter()
        first = True
        for _step in range(max_new):
            self._key, k = jax.random.split(self._key)
            nxt = self._sample(logits, k, temps_d, tks_d, tps_d)
            nxt_h = np.asarray(nxt)           # the step's single host sync
            for i, s in enumerate(seqs):
                if s.done:
                    continue
                tid = int(nxt_h[i])
                s.out_ids.append(tid)
                if first:
                    s.ttft_s = time.perf_counter() - s.enqueue_t
                self._finished(s, tid)
            first = False
            self.stats.decoded_tokens += sum(
                0 if s.done else 1 for s in seqs)
            if all(s.done for s in seqs):
                break
            lg, cache = self._decode(self.params, cache, nxt[:, None], pos)
            self.stats.decode_steps += 1
            logits = lg[:, 0]
            pos = pos + 1
        self.stats.decode_time_s += time.perf_counter() - t_dec

        out = []
        wall = time.perf_counter() - t_start
        for s in seqs:
            self.stats.requests += 1
            s.state = SeqState.FINISHED
            s.wall_s = wall
            out.append(self._result(s))
        return out
