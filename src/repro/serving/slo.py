"""Per-tenant SLOs, goodput accounting, and overload admission control.

Under sustained arrivals the honest serving metric is not tokens/s but
*goodput*: tokens delivered inside their tenant's latency SLO
(TTFT + per-request ITL tail), measured over the run.  This module is
the accounting side of the streaming tier:

* ``SLO`` -- one tenant's targets: time-to-first-token and the p95 of
  the request's own inter-token gaps (a per-request tail, so one stalled
  request cannot hide inside an engine-wide distribution).
* ``SLOTracker`` -- folds completed/shed requests into per-tenant
  attainment, goodput tokens, and stream-wide ITL tail percentiles;
  ``report(elapsed_s)`` is the counter block benchmarks and the example
  print.  With ``window_s`` set it additionally buckets every arrival
  into fixed *virtual-time* windows keyed by the arrival's ``t_s`` --
  attribution by arrival time is a pure function of the seeded stream,
  so windowed goodput replays deterministically -- and with ``phases``
  (a ``FaultPhases``) each window is tagged ``pre_churn`` / ``churn`` /
  ``post_heal``, making "goodput holds within X% through churn and
  recovers after heal" a computable bar.
* ``AdmissionController`` -- the overload valve at the cluster's front
  door: when outstanding routed work exceeds ``capacity_tokens``, new
  requests *below* ``protect_priority`` are shed; protected tenants are
  always admitted and additionally ride the scheduler's
  preemption-by-offload priority inside each engine.  Deciding on
  committed-token load (not latency) keeps the shed set a pure function
  of the arrival history, so deterministic replays stay deterministic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.serving.stats import SampleReservoir


@dataclass(frozen=True)
class SLO:
    """Latency targets for one tenant (virtual==wall seconds at the
    serving host; ``inf`` disables a bound)."""

    ttft_s: float = math.inf
    itl_p95_s: float = math.inf


def itl_tail(samples_s: list[float], q: float = 95.0) -> float:
    """The q-th percentile of one request's inter-token gaps."""
    if not samples_s:
        return 0.0
    return float(np.percentile(np.asarray(samples_s, np.float64), q))


@dataclass
class TenantCounters:
    offered: int = 0
    shed: int = 0
    completed: int = 0
    attained: int = 0
    tokens: int = 0
    attained_tokens: int = 0

    def attainment(self) -> float:
        return self.attained / max(self.completed, 1)


@dataclass(frozen=True)
class FaultPhases:
    """A fault arc's phase boundaries on the virtual timeline (both
    relative to stream start, i.e. ``FaultPlan.churn_span``): churn
    opens at the first kill and closes at the last heal.  A goodput
    window is ``pre_churn`` only when it ends before the first kill and
    ``post_heal`` only when it starts at/after the last heal; anything
    straddling a boundary is (conservatively) ``churn``."""

    churn_start_s: float
    heal_s: float = math.inf

    def tag(self, t0_s: float, t1_s: float) -> str:
        if t1_s <= self.churn_start_s:
            return "pre_churn"
        if t0_s >= self.heal_s:
            return "post_heal"
        return "churn"


class SLOTracker:
    """Stream-wide SLO bookkeeping (one instance per serve_stream)."""

    def __init__(self, slos: dict[str, SLO] | None = None, *,
                 default: SLO | None = None,
                 window_s: float | None = None,
                 phases: FaultPhases | None = None) -> None:
        if window_s is not None and window_s <= 0:
            raise ValueError(f"window_s must be > 0 (got {window_s})")
        self.slos = dict(slos or {})
        self.default = default if default is not None else SLO()
        self.per_tenant: dict[str, TenantCounters] = {}
        self.itl_all_s = SampleReservoir()
        self.window_s = window_s
        self.phases = phases
        self.windows: dict[int, TenantCounters] = {}

    def slo_for(self, tenant: str) -> SLO:
        return self.slos.get(tenant, self.default)

    def _bucket(self, tenant: str) -> TenantCounters:
        return self.per_tenant.setdefault(tenant, TenantCounters())

    def _window(self, t_s: float | None) -> TenantCounters | None:
        if self.window_s is None or t_s is None:
            return None
        return self.windows.setdefault(
            int(t_s // self.window_s), TenantCounters())

    # ------------------------------------------------------------------
    def note_offered(self, tenant: str, *, t_s: float | None = None) -> None:
        self._bucket(tenant).offered += 1
        w = self._window(t_s)
        if w is not None:
            w.offered += 1

    def note_shed(self, tenant: str, *, t_s: float | None = None) -> None:
        self._bucket(tenant).shed += 1
        w = self._window(t_s)
        if w is not None:
            w.shed += 1

    def observe(self, tenant: str, *, ttft_s: float,
                itl_samples_s: list[float], new_tokens: int,
                t_s: float | None = None) -> bool:
        """Fold one completed request; returns whether it attained its
        tenant's SLO (TTFT within target AND the request's own ITL p95
        within target).  ``t_s`` (the request's *arrival* virtual time)
        additionally credits the request to its goodput window."""
        slo = self.slo_for(tenant)
        ok = (ttft_s <= slo.ttft_s
              and itl_tail(itl_samples_s) <= slo.itl_p95_s)
        for b in filter(None, (self._bucket(tenant), self._window(t_s))):
            b.completed += 1
            b.tokens += new_tokens
            if ok:
                b.attained += 1
                b.attained_tokens += new_tokens
        self.itl_all_s.extend(itl_samples_s)
        return ok

    # ------------------------------------------------------------------
    def timeline(self) -> list[dict]:
        """The windowed goodput timeline: one row per fixed virtual-time
        window from 0 through the last populated one (gaps materialize
        as empty windows -- a silent traffic hole should READ as zero
        goodput, not vanish), tagged with its fault phase.  Goodput here
        is attained tokens per *virtual* window second; ratios between
        windows are unit-free."""
        if self.window_s is None or not self.windows:
            return []
        out = []
        for i in range(max(self.windows) + 1):
            w = self.windows.get(i, TenantCounters())
            t0 = i * self.window_s
            t1 = t0 + self.window_s
            out.append({
                "t0_s": t0,
                "t1_s": t1,
                "phase": (self.phases.tag(t0, t1)
                          if self.phases is not None else "steady"),
                "offered": w.offered,
                "shed": w.shed,
                "completed": w.completed,
                "attained": w.attained,
                "tokens": w.tokens,
                "attained_tokens": w.attained_tokens,
                "goodput_tokens_per_s": w.attained_tokens / self.window_s,
            })
        return out

    def phase_report(self) -> dict:
        """Per-phase aggregates over the timeline -- the numbers the
        "goodput holds through churn / recovers after heal" bars divide:
        each phase's windows folded, plus its goodput per virtual
        second of phase duration."""
        phases: dict[str, dict] = {}
        for row in self.timeline():
            agg = phases.setdefault(row["phase"], {
                "windows": 0, "duration_s": 0.0, "offered": 0, "shed": 0,
                "completed": 0, "attained": 0, "tokens": 0,
                "attained_tokens": 0,
            })
            agg["windows"] += 1
            agg["duration_s"] += row["t1_s"] - row["t0_s"]
            for k in ("offered", "shed", "completed", "attained",
                      "tokens", "attained_tokens"):
                agg[k] += row[k]
        for agg in phases.values():
            agg["goodput_tokens_per_s"] = (
                agg["attained_tokens"] / max(agg["duration_s"], 1e-9))
        return phases

    def report(self, elapsed_s: float) -> dict:
        """The goodput/attainment counter block."""
        total = TenantCounters()
        for b in self.per_tenant.values():
            total.offered += b.offered
            total.shed += b.shed
            total.completed += b.completed
            total.attained += b.attained
            total.tokens += b.tokens
            total.attained_tokens += b.attained_tokens
        xs = np.asarray(self.itl_all_s or [0.0], np.float64)
        windowed = ({"windows": self.timeline(),
                     "phases": self.phase_report()}
                    if self.window_s is not None else {})
        return {
            **windowed,
            "elapsed_s": elapsed_s,
            "offered": total.offered,
            "shed": total.shed,
            "completed": total.completed,
            "attained": total.attained,
            "attainment": total.attainment(),
            "tokens": total.tokens,
            "tokens_per_s": total.tokens / max(elapsed_s, 1e-9),
            "goodput_tokens_per_s":
                total.attained_tokens / max(elapsed_s, 1e-9),
            "itl_tail_s": {
                "p95": float(np.percentile(xs, 95)),
                "p99": float(np.percentile(xs, 99)),
                "max": float(xs.max()),
            },
            "per_tenant": {
                name: {
                    "offered": b.offered,
                    "shed": b.shed,
                    "completed": b.completed,
                    "attained": b.attained,
                    "attainment": b.attainment(),
                    "tokens": b.tokens,
                    "attained_tokens": b.attained_tokens,
                }
                for name, b in sorted(self.per_tenant.items())
            },
        }


@dataclass
class AdmissionController:
    """Load-threshold shedding at the streaming front door.

    ``admit`` is a pure function of the router's outstanding committed
    tokens: below ``capacity_tokens`` everyone enters; above it only
    priorities >= ``protect_priority`` do (they are never shed -- inside
    the engines the scheduler's priority preemption then defers the
    admitted low-priority work too).  ``shed_count`` is the controller's
    own tally, independent of any tracker."""

    capacity_tokens: int
    protect_priority: int = 1
    shed_count: int = field(default=0, init=False)

    def admit(self, priority: int, load_tokens: int) -> bool:
        if priority >= self.protect_priority:
            return True
        if load_tokens < self.capacity_tokens:
            return True
        self.shed_count += 1
        return False
