"""Per-tenant SLOs, goodput accounting, and overload admission control.

Under sustained arrivals the honest serving metric is not tokens/s but
*goodput*: tokens delivered inside their tenant's latency SLO
(TTFT + per-request ITL tail), measured over the run.  This module is
the accounting side of the streaming tier:

* ``SLO`` -- one tenant's targets: time-to-first-token and the p95 of
  the request's own inter-token gaps (a per-request tail, so one stalled
  request cannot hide inside an engine-wide distribution).
* ``SLOTracker`` -- folds completed/shed requests into per-tenant
  attainment, goodput tokens, and stream-wide ITL tail percentiles;
  ``report(elapsed_s)`` is the counter block benchmarks and the example
  print.
* ``AdmissionController`` -- the overload valve at the cluster's front
  door: when outstanding routed work exceeds ``capacity_tokens``, new
  requests *below* ``protect_priority`` are shed; protected tenants are
  always admitted and additionally ride the scheduler's
  preemption-by-offload priority inside each engine.  Deciding on
  committed-token load (not latency) keeps the shed set a pure function
  of the arrival history, so deterministic replays stay deterministic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.serving.stats import SampleReservoir


@dataclass(frozen=True)
class SLO:
    """Latency targets for one tenant (virtual==wall seconds at the
    serving host; ``inf`` disables a bound)."""

    ttft_s: float = math.inf
    itl_p95_s: float = math.inf


def itl_tail(samples_s: list[float], q: float = 95.0) -> float:
    """The q-th percentile of one request's inter-token gaps."""
    if not samples_s:
        return 0.0
    return float(np.percentile(np.asarray(samples_s, np.float64), q))


@dataclass
class TenantCounters:
    offered: int = 0
    shed: int = 0
    completed: int = 0
    attained: int = 0
    tokens: int = 0
    attained_tokens: int = 0

    def attainment(self) -> float:
        return self.attained / max(self.completed, 1)


class SLOTracker:
    """Stream-wide SLO bookkeeping (one instance per serve_stream)."""

    def __init__(self, slos: dict[str, SLO] | None = None, *,
                 default: SLO | None = None) -> None:
        self.slos = dict(slos or {})
        self.default = default if default is not None else SLO()
        self.per_tenant: dict[str, TenantCounters] = {}
        self.itl_all_s = SampleReservoir()

    def slo_for(self, tenant: str) -> SLO:
        return self.slos.get(tenant, self.default)

    def _bucket(self, tenant: str) -> TenantCounters:
        return self.per_tenant.setdefault(tenant, TenantCounters())

    # ------------------------------------------------------------------
    def note_offered(self, tenant: str) -> None:
        self._bucket(tenant).offered += 1

    def note_shed(self, tenant: str) -> None:
        b = self._bucket(tenant)
        b.shed += 1

    def observe(self, tenant: str, *, ttft_s: float,
                itl_samples_s: list[float], new_tokens: int) -> bool:
        """Fold one completed request; returns whether it attained its
        tenant's SLO (TTFT within target AND the request's own ITL p95
        within target)."""
        slo = self.slo_for(tenant)
        ok = (ttft_s <= slo.ttft_s
              and itl_tail(itl_samples_s) <= slo.itl_p95_s)
        b = self._bucket(tenant)
        b.completed += 1
        b.tokens += new_tokens
        self.itl_all_s.extend(itl_samples_s)
        if ok:
            b.attained += 1
            b.attained_tokens += new_tokens
        return ok

    # ------------------------------------------------------------------
    def report(self, elapsed_s: float) -> dict:
        """The goodput/attainment counter block."""
        total = TenantCounters()
        for b in self.per_tenant.values():
            total.offered += b.offered
            total.shed += b.shed
            total.completed += b.completed
            total.attained += b.attained
            total.tokens += b.tokens
            total.attained_tokens += b.attained_tokens
        xs = np.asarray(self.itl_all_s or [0.0], np.float64)
        return {
            "elapsed_s": elapsed_s,
            "offered": total.offered,
            "shed": total.shed,
            "completed": total.completed,
            "attained": total.attained,
            "attainment": total.attainment(),
            "tokens": total.tokens,
            "tokens_per_s": total.tokens / max(elapsed_s, 1e-9),
            "goodput_tokens_per_s":
                total.attained_tokens / max(elapsed_s, 1e-9),
            "itl_tail_s": {
                "p95": float(np.percentile(xs, 95)),
                "p99": float(np.percentile(xs, 99)),
                "max": float(xs.max()),
            },
            "per_tenant": {
                name: {
                    "offered": b.offered,
                    "shed": b.shed,
                    "completed": b.completed,
                    "attained": b.attained,
                    "attainment": b.attainment(),
                    "tokens": b.tokens,
                    "attained_tokens": b.attained_tokens,
                }
                for name, b in sorted(self.per_tenant.items())
            },
        }


@dataclass
class AdmissionController:
    """Load-threshold shedding at the streaming front door.

    ``admit`` is a pure function of the router's outstanding committed
    tokens: below ``capacity_tokens`` everyone enters; above it only
    priorities >= ``protect_priority`` do (they are never shed -- inside
    the engines the scheduler's priority preemption then defers the
    admitted low-priority work too).  ``shed_count`` is the controller's
    own tally, independent of any tracker."""

    capacity_tokens: int
    protect_priority: int = 1
    shed_count: int = field(default=0, init=False)

    def admit(self, priority: int, load_tokens: int) -> bool:
        if priority >= self.protect_priority:
            return True
        if load_tokens < self.capacity_tokens:
            return True
        self.shed_count += 1
        return False
