"""Executors: every jitted device program the serving stack launches.

``PagedExecutor`` owns the paged-runtime programs -- the fused decode
step, the mixed decode+chunk step, the cold-start chunk wave, the dense
prefill used by stop-the-world admission -- plus the PRNG stream and the
compile-shape policies (chunk buffers, length buckets).  It reads and
writes K/V through the L0 pool held by the ``TieredKVManager``; the
scheduler never touches device arrays directly.

``DenseRuntime`` is the non-paged serving loop for the families whose
decode state is not plain per-token K/V (MLA latents, SSM state, hybrid,
encoder-decoder): dense batched caches, the vectorized sampler, one host
sync per step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import Seq, SeqState, seq_finished, seq_result
from repro.serving.sampler import SamplingParams, sample_batch, stack_sampling
from repro.serving.stats import EngineStats
from repro.serving.tokenizer import truncate_prompt


class PagedExecutor:
    """Jitted mixed decode/prefill steps, sampling, and device state."""

    def __init__(self, model, params, pool, *, chunk_tokens: int,
                 max_seq_len: int, seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.pool = pool
        self.cfg = model.cfg
        self.chunk_tokens = chunk_tokens
        self.max_seq_len = max_seq_len
        self._key = jax.random.PRNGKey(seed)
        # pools are donated: on backends with donation support the
        # one-token write updates the cache in place instead of copying
        # the whole pool every step (CPU falls back to copy)
        self._step = jax.jit(self._paged_step,
                             static_argnames=("mode",),
                             donate_argnums=(1, 2))
        self._mixed = jax.jit(self._mixed_step,
                              static_argnames=("mode",),
                              donate_argnums=(1, 2))
        # cold-start admission waves: batched chunk steps (nothing is
        # decoding, so the whole wave prefills together)
        self._chunk_wave = jax.jit(self.model.prefill_chunk_paged,
                                   donate_argnums=(1, 2))
        self._prefill = jax.jit(
            lambda p, t: self.model.forward(p, t, collect_state=True)
        )

    def next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    # -- the fused device programs --------------------------------------
    def _decode_sample(self, params, k_pool, v_pool, block_tables, lengths,
                       tokens, key, temps, top_ks, top_ps, mode):
        """Decode every slot and sample its next token: the shared tail of
        the plain and mixed steps.

        ``mode`` is decided host-side from the *active slots'* sampling
        params (it only changes on admission/finish, so at most a few
        compilations): ``greedy`` is a pure argmax, ``temp`` skips the
        top-k/top-p sort machinery, ``full`` runs the general sampler.
        """
        logits, k_pool, v_pool = self.model.decode_step_paged(
            params, k_pool, v_pool, tokens[:, None], block_tables, lengths,
            contiguous=self.pool.contiguous,
        )
        lg = logits[:, 0]
        if mode == "greedy":
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        elif mode == "temp":
            lg32 = lg.astype(jnp.float32)
            greedy = jnp.argmax(lg32, axis=-1).astype(jnp.int32)
            is_greedy = temps <= 0.0
            scaled = lg32 / jnp.where(is_greedy, 1.0, temps)[:, None]
            sampled = jax.random.categorical(key, scaled, -1).astype(jnp.int32)
            nxt = jnp.where(is_greedy, greedy, sampled)
        else:
            nxt = sample_batch(lg, key, temps, top_ks, top_ps)
        return nxt, k_pool, v_pool

    def _paged_step(self, params, k_pool, v_pool, block_tables, lengths,
                    tokens, key, temps, top_ks, top_ps, *, mode):
        """One fused decode step: model + sampler, one device program."""
        return self._decode_sample(params, k_pool, v_pool, block_tables,
                                   lengths, tokens, key, temps, top_ks,
                                   top_ps, mode)

    def _mixed_step(self, params, k_pool, v_pool, block_tables, lengths,
                    tokens, key, temps, top_ks, top_ps,
                    c_toks, c_bt, c_off, c_valid, c_temp, c_tk, c_tp,
                    *, mode):
        """One fused mixed step: a prefill chunk rides the decode step.

        The chunk (``c_toks`` [1, C] at absolute offset ``c_off``,
        ``c_valid`` real tokens) writes its K/V into pool pages and
        attends over the restored prefix + earlier chunks in place; then
        every slot decodes exactly as in the plain step, so running
        sequences never stall for an admission.  If this is the
        sequence's final chunk, its first output token is the extra id
        sampled here from the last valid chunk logit -- returned as row
        ``B`` of the token vector so the host still does ONE sync.
        ``c_off``/``c_valid`` are traced, so one compilation serves every
        chunk of every admission (no power-of-two prefill buckets).
        """
        kd, kc = jax.random.split(key)
        c_logits, k_pool, v_pool = self.model.prefill_chunk_paged(
            params, k_pool, v_pool, c_toks, c_bt, c_off, c_valid)
        c_tid = sample_batch(c_logits, kc, c_temp, c_tk, c_tp)
        nxt, k_pool, v_pool = self._decode_sample(
            params, k_pool, v_pool, block_tables, lengths, tokens, kd,
            temps, top_ks, top_ps, mode)
        return jnp.concatenate([nxt, c_tid]), k_pool, v_pool

    # -- scheduler-facing wrappers (pool updated in place) --------------
    def step(self, bt_d, len_d, tok_d, temps, tks, tps, mode,
             chunk_ops=None):
        """Launch one fused step; returns the device token vector (the
        caller's ``np.asarray`` is the step's single host sync)."""
        k = self.next_key()
        if chunk_ops is None:
            nxt, kp, vp = self._step(
                self.params, self.pool.k_pool, self.pool.v_pool,
                bt_d, len_d, tok_d, k, temps, tks, tps, mode=mode)
        else:
            nxt, kp, vp = self._mixed(
                self.params, self.pool.k_pool, self.pool.v_pool,
                bt_d, len_d, tok_d, k, temps, tks, tps,
                *chunk_ops, mode=mode)
        self.pool.k_pool, self.pool.v_pool = kp, vp
        return nxt

    def chunk_wave(self, buf, bts, offs, valids):
        """One lockstep batched chunk step (cold-start admission wave)."""
        lg, kp, vp = self._chunk_wave(
            self.params, self.pool.k_pool, self.pool.v_pool,
            jnp.asarray(buf), jnp.asarray(bts), jnp.asarray(offs),
            jnp.asarray(valids),
        )
        self.pool.k_pool, self.pool.v_pool = kp, vp
        return lg

    def prefill_chunk_eager(self, tokens_row, bt_row, start: int, v: int):
        """A single unjitted chunk over the pool (stop-the-world suffix
        prefill and restore-tail replay; shapes vary per call, so jitting
        would only grow the compile cache)."""
        lg, kp, vp = self.model.prefill_chunk_paged(
            self.params, self.pool.k_pool, self.pool.v_pool,
            jnp.asarray(tokens_row), jnp.asarray(bt_row),
            jnp.asarray([start], jnp.int32), jnp.asarray([v], jnp.int32),
        )
        self.pool.k_pool, self.pool.v_pool = kp, vp
        return lg[0]

    def prefill_dense(self, toks):
        """Batched bucketed dense prefill (stop-the-world misses)."""
        return self._prefill(self.params, toks)

    def prefill_exact(self, tokens: list[int]):
        """Unpadded, per-sequence prefill (MoE families, where padding
        would perturb capacity-based routing of real tokens).  Returns
        (last_logits, state)."""
        toks = jnp.asarray(tokens, jnp.int32)[None]
        lg, _, state = self.model.forward(
            self.params, toks, collect_state=True)
        return lg[0, len(tokens) - 1], state

    def sample_first(self, logits_rows, samplings) -> np.ndarray:
        """First tokens for an admission wave: one call, one host sync."""
        t_arr, tk_arr, tp_arr = stack_sampling(samplings)
        return np.asarray(sample_batch(
            jnp.stack(logits_rows), self.next_key(), t_arr, tk_arr, tp_arr))

    # -- compile-shape policy -------------------------------------------
    @staticmethod
    def sampler_mode(samp: list[SamplingParams]) -> str:
        if any(p.top_k > 0 or p.top_p < 1.0 for p in samp
               if p.temperature > 0.0):
            return "full"
        if any(p.temperature > 0.0 for p in samp):
            return "temp"
        return "greedy"

    def chunk_buf(self, v: int) -> int:
        """Chunk-buffer length for ``v`` valid tokens: the next power of
        two (floor 32), capped at the chunk budget.  Short prompts and
        ragged final chunks don't pay for a full-budget buffer, and the
        compile count is bounded by the (small) budget instead of
        max_seq_len -- the legacy O(log^2) whole-prompt buckets reduce to
        a handful of chunk-sized shapes."""
        b = 32
        while b < v:
            b *= 2
        return min(b, max(self.chunk_tokens, v))

    def bucket(self, n: int) -> int:
        """Prefill length bucket for stop-the-world admission (next power
        of two, floor 32, capped at max_seq_len).  The chunked scheduler
        needs no buckets: its one fixed chunk shape serves every prompt."""
        b = 32
        while b < n:
            b *= 2
        return min(b, max(n, self.max_seq_len))


class DenseRuntime:
    """Non-paged serving loop (MLA / SSM / hybrid / enc-dec families):
    dense batched caches with the vectorized sampler and one host sync
    per step.  Shares the SkyMemory protocol objects with the paged path
    but not the page pool -- paging these decode states is future work."""

    def __init__(self, model, params, tokenizer, adapter, manager, *,
                 max_seq_len: int, max_batch: int, write_back: bool,
                 seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.tokenizer = tokenizer
        self.adapter = adapter
        self.manager = manager
        self.max_seq_len = max_seq_len
        self.max_batch = max_batch
        self.write_back = write_back
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self._sample = jax.jit(sample_batch)

    def generate(self, requests) -> list:
        results = []
        for lo in range(0, len(requests), self.max_batch):
            results.extend(self._run_batch(requests[lo: lo + self.max_batch]))
        return results

    def _make_seq(self, req) -> Seq:
        tokens = truncate_prompt(self.tokenizer.encode(req.prompt),
                                 self.max_seq_len)
        return Seq(request=req, tokens=tokens, enqueue_t=time.perf_counter())

    def _prefill_one(self, req) -> Seq:
        t0 = time.perf_counter()
        s = self._make_seq(req)
        tokens = s.tokens
        cached = 0
        prefix_state = None
        if self.manager is not None:
            payload, cached = self.manager.get_cache_tokens(tokens)
            if payload is not None:
                prefix_state = self.adapter.payload_to_state(payload)
        toks = jnp.asarray(tokens, jnp.int32)[None]
        if cached >= len(tokens):
            # whole prompt cached: replay the final token so the decode
            # loop has a starting distribution
            cached = len(tokens) - 1
        if cached:
            lg, _, state = self.model.forward(
                self.params, toks[:, cached:], q_offset=cached,
                prefix_state=prefix_state, collect_state=True,
            )
        else:
            lg, _, state = self.model.forward(
                self.params, toks, collect_state=True
            )
        self.stats.prefill_time_s += time.perf_counter() - t0
        self.stats.cached_tokens += cached
        self.stats.prefilled_tokens += len(tokens) - cached
        if self.write_back and self.manager is not None:
            self.manager.add_blocks_tokens(tokens)
        s.cached = cached
        s.dense_state = state
        s.last_logits = lg[0, -1]
        s.state = SeqState.RUNNING
        return s

    def _stack_dense_caches(self, seqs: list[Seq]):
        """Dense prefill->decode handoff: per-sequence states are
        restacked into one batched cache.  Paged families never come here
        -- their blocks were written into pool pages at admission."""
        cache = self.model.init_cache(len(seqs), self.max_seq_len)
        for i, s in enumerate(seqs):
            n = len(s.tokens)
            st = s.dense_state
            if "kv" in st and "kv" in cache:
                cache["kv"]["k"] = cache["kv"]["k"].at[:, i, :n].set(
                    st["kv"]["k"][:, 0, :n])
                cache["kv"]["v"] = cache["kv"]["v"].at[:, i, :n].set(
                    st["kv"]["v"][:, 0, :n])
            if "mla" in st:
                cache["mla"]["ckv"] = cache["mla"]["ckv"].at[:, i, :n].set(
                    st["mla"]["ckv"][:, 0, :n])
                cache["mla"]["kr"] = cache["mla"]["kr"].at[:, i, :n].set(
                    st["mla"]["kr"][:, 0, :n])
            if "ssm" in st:
                cache["ssm"]["conv"] = cache["ssm"]["conv"].at[:, i].set(
                    st["ssm"]["conv"][:, 0])
                cache["ssm"]["state"] = cache["ssm"]["state"].at[:, i].set(
                    st["ssm"]["state"][:, 0].astype(cache["ssm"]["state"].dtype))
        return cache

    def _run_batch(self, requests) -> list:
        t_start = time.perf_counter()
        seqs = [self._prefill_one(r) for r in requests]
        cache = self._stack_dense_caches(seqs)
        pos = jnp.asarray([len(s.tokens) for s in seqs], jnp.int32)

        # first token of each sequence from its prefill logits
        logits = jnp.stack([s.last_logits for s in seqs])
        temps_d, tks_d, tps_d = stack_sampling(
            [s.request.sampling for s in seqs])

        max_new = max(s.request.sampling.max_new_tokens for s in seqs)
        t_dec = time.perf_counter()
        first = True
        last_tok_t = [0.0] * len(seqs)
        for _step in range(max_new):
            self._key, k = jax.random.split(self._key)
            nxt = self._sample(logits, k, temps_d, tks_d, tps_d)
            nxt_h = np.asarray(nxt)           # the step's single host sync
            now = time.perf_counter()
            for i, s in enumerate(seqs):
                if s.done:
                    continue
                tid = int(nxt_h[i])
                s.out_ids.append(tid)
                if first:
                    s.ttft_s = now - s.enqueue_t
                    self.stats.ttft_s.append(s.ttft_s)
                else:
                    self.stats.itl_s.append(now - last_tok_t[i])
                last_tok_t[i] = now
                seq_finished(s, tid, eos_id=self.tokenizer.eos_id,
                             max_seq_len=self.max_seq_len)
            first = False
            self.stats.decoded_tokens += sum(
                0 if s.done else 1 for s in seqs)
            if all(s.done for s in seqs):
                break
            lg, cache = self._decode(self.params, cache, nxt[:, None], pos)
            self.stats.decode_steps += 1
            logits = lg[:, 0]
            pos = pos + 1
        self.stats.decode_time_s += time.perf_counter() - t_dec

        out = []
        wall = time.perf_counter() - t_start
        for s in seqs:
            self.stats.requests += 1
            s.state = SeqState.FINISHED
            s.wall_s = wall
            out.append(seq_result(s, self.tokenizer))
        return out
