"""Request routing across Engine replicas sharing one constellation.

The cluster's front door: every request is scored against each replica
before it is handed to that replica's engine.  Two policies:

* ``PrefixAffinityRouter`` -- the hop-aware, prefix-affinity policy the
  scale-out design is built around.  Per candidate replica the score
  combines three signals, all in token units:

  - **affinity**: the longest leading run of the request's block-hash
    chain this router previously sent to the replica.  Duplicated
    contexts (the paper's RAG workload) land on the replica whose
    write-back is already in flight or indexed, so they hit instead of
    racing a concurrent miss on another replica.
  - **hop cost**: when the shared radix index says a prefix is already
    in the constellation, fetching it costs a Get KVC whose latency
    depends on the replica's *anchor* satellite
    (``ConstellationView.estimate_get_latency_s`` -- the same transport
    model the fetch will later experience).  Nearer anchors win among
    replicas whose affinity/load score ties; hop distance never outbids
    cached history.
  - **load**: outstanding assigned tokens, as a weighted penalty
    (``w_load``, 0 by default) AND as the explicit tie-break -- equal
    scores go to the emptier replica, so fresh traffic round-robins.

* ``RandomRouter`` -- the seeded uniform baseline every benchmark
  compares against.

Routers are deliberately engine-agnostic: they speak token lists and
replica indices, and track their own assignment state, so they can be
unit-tested without building a single engine.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field

from repro.core.hashing import chain_hashes


@dataclass
class ReplicaHandle:
    """What the router knows about one replica.

    ``view`` is the replica's anchored ``ConstellationView`` (or None for
    a fabric-less cluster): its only use here is hop-cost estimation.
    ``load_tokens`` counts outstanding assigned work (prompt plus
    requested new tokens); ``seen_blocks`` are the block hashes of
    prompts routed to this replica -- the affinity memory, an
    insertion-ordered dict so it can be FIFO-bounded
    (``Router.max_seen_blocks``) instead of accreting every hash a
    long-lived cluster ever routed.
    """

    index: int
    view: object | None = None
    load_tokens: int = 0
    seen_blocks: dict = field(default_factory=dict)

    def affinity_blocks(self, hashes: list[bytes]) -> int:
        """Longest leading run of ``hashes`` previously routed here."""
        n = 0
        for h in hashes:
            if h not in self.seen_blocks:
                break
            n += 1
        return n

    def note_blocks(self, hashes: list[bytes], cap: int) -> None:
        """Record routed hashes; re-insertion refreshes recency, and the
        oldest entries are dropped past ``cap``."""
        for h in hashes:
            self.seen_blocks.pop(h, None)
            self.seen_blocks[h] = None
        while len(self.seen_blocks) > cap:
            del self.seen_blocks[next(iter(self.seen_blocks))]

    def reset(self) -> None:
        self.load_tokens = 0
        self.seen_blocks.clear()


@dataclass(frozen=True)
class RouteDecision:
    """One routing verdict, with the signals that produced it (the
    benchmark and the tests read these instead of re-deriving them)."""

    replica: int
    affinity_tokens: int      # router-local prefix match on the winner
    cached_blocks: int        # shared-index cached prefix (any replica)
    hop_latency_s: float      # est. Get latency from the winner's anchor
    load_tokens: int          # winner's load BEFORE this assignment
    committed_tokens: int = 0  # load this assignment added (for release)


class Router:
    """Base: assignment bookkeeping shared by every policy."""

    def __init__(self, handles: list[ReplicaHandle], *,
                 manager=None, block_size: int | None = None,
                 max_seen_blocks: int = 65536,
                 bytes_per_token: float | None = None,
                 delta_payloads: bool = False) -> None:
        if not handles:
            raise ValueError("router needs at least one replica")
        self.handles = handles
        self.manager = manager          # shared KVCManager (index + lock)
        self.block_size = (block_size if block_size is not None
                           else (manager.block_size if manager else 128))
        self.max_seen_blocks = max_seen_blocks
        # codec-derived size model (SkyKVCAdapter.payload_bytes_per_token):
        # when a cached block carries no registered payload_bytes, hop
        # estimates price encoded bytes from this instead of assuming a
        # full f32 stripe -- so a quantized fabric's router and its
        # experienced fetches agree on sizes by construction.  Under
        # delta payloads the tail Get ships one block, not the prefix.
        self.bytes_per_token = bytes_per_token
        self.delta_payloads = delta_payloads
        # streaming serves route at the front door while per-request
        # releases arrive from engine worker threads (future callbacks):
        # one lock keeps load accounting and affinity memory coherent.
        # Always taken BEFORE the manager lock (never after), so it
        # cannot deadlock against engines holding the fabric lock.
        self.lock = threading.Lock()

    # -- shared signals -------------------------------------------------
    def _cached_prefix(
        self, hashes: list[bytes]
    ) -> tuple[int, int | None, bytes | None]:
        """(blocks, payload_bytes, tail_hash) of the request's longest
        prefix in the shared radix index.  ``payload_bytes`` sizes the
        single Get KVC a hit will actually issue (the final block's
        cumulative payload) and ``tail_hash`` is that block's hash, so
        hop estimates can price the chunk servers the block really spans
        AND the exact directory-stripe lookup leg the fetch will pay --
        keeping the router's estimate and the experienced latency on the
        same path."""
        if self.manager is None or not hashes:
            return 0, None, None
        with self.manager.lock:
            n, meta = self.manager.index.longest_cached_prefix(hashes)
        tail = hashes[n - 1] if n else None
        if n and meta is not None and meta.payload_bytes:
            return n, meta.payload_bytes, tail
        if n and self.bytes_per_token:
            # unregistered block: fall back to the codec's size model
            blocks = 1 if self.delta_payloads else n
            return n, max(1, round(blocks * self.block_size
                                   * self.bytes_per_token)), tail
        return n, None, tail

    def _commit(self, h: ReplicaHandle, hashes: list[bytes],
                n_tokens: int, est_new_tokens: int) -> int:
        committed = n_tokens + est_new_tokens
        h.load_tokens += committed
        h.note_blocks(hashes, self.max_seen_blocks)
        return committed

    def release(self, replica: int, n_tokens: int) -> None:
        """Return finished work's tokens to the load accounting (per
        request on the streaming path; batch serves release at end)."""
        with self.lock:
            h = self.handles[replica]
            h.load_tokens = max(0, h.load_tokens - n_tokens)

    def total_load(self) -> int:
        """Outstanding committed tokens across every replica -- the
        overload signal the streaming admission controller sheds on."""
        with self.lock:
            return sum(h.load_tokens for h in self.handles)

    def reset(self) -> None:
        with self.lock:
            for h in self.handles:
                h.reset()

    def route(self, tokens: list[int], *,
              est_new_tokens: int = 0) -> RouteDecision:
        raise NotImplementedError


class RandomRouter(Router):
    """Uniform seeded assignment -- the scale-out baseline."""

    def __init__(self, handles: list[ReplicaHandle], *, manager=None,
                 block_size: int | None = None, seed: int = 0,
                 max_seen_blocks: int = 65536) -> None:
        super().__init__(handles, manager=manager, block_size=block_size,
                         max_seen_blocks=max_seen_blocks)
        self._rng = random.Random(seed)

    def route(self, tokens: list[int], *,
              est_new_tokens: int = 0) -> RouteDecision:
        hashes = chain_hashes(tokens, self.block_size)
        cached = self._cached_prefix(hashes)[0]
        with self.lock:
            h = self.handles[self._rng.randrange(len(self.handles))]
            load_before = h.load_tokens
            return RouteDecision(
                replica=h.index,
                affinity_tokens=h.affinity_blocks(hashes) * self.block_size,
                cached_blocks=cached,
                hop_latency_s=0.0,
                load_tokens=load_before,
                committed_tokens=self._commit(h, hashes, len(tokens),
                                              est_new_tokens),
            )


class PrefixAffinityRouter(Router):
    """Hop-aware, prefix-affinity scoring (see the module docstring).

    The criteria are *lexicographic*: the primary score is affinity
    tokens minus the (optional, ``w_load``-weighted) load penalty; the
    anchor-to-home-satellite fetch latency decides only between
    replicas whose primary scores tie.  Hop distance therefore stays
    fully discriminative among equal-affinity candidates but can never
    outbid cached history -- on wide-window constellations anchor
    latencies differ by >100 ms, which a weighted sum would let split a
    duplicate group away from its affinity home.  Remaining ties go to
    the emptier replica, then the lower index.  ``w_load`` defaults to
    0 (load is still the tie-break); raise it to trade affinity against
    queue balance.
    """

    def __init__(self, handles: list[ReplicaHandle], *, manager=None,
                 block_size: int | None = None, w_affinity: float = 1.0,
                 w_load: float = 0.0, max_seen_blocks: int = 65536,
                 bytes_per_token: float | None = None,
                 delta_payloads: bool = False) -> None:
        super().__init__(handles, manager=manager, block_size=block_size,
                         max_seen_blocks=max_seen_blocks,
                         bytes_per_token=bytes_per_token,
                         delta_payloads=delta_payloads)
        self.w_affinity = w_affinity
        self.w_load = w_load

    def route(self, tokens: list[int], *,
              est_new_tokens: int = 0) -> RouteDecision:
        hashes = chain_hashes(tokens, self.block_size)
        cached, payload_bytes, tail_hash = self._cached_prefix(hashes)
        with self.lock:
            best_h: ReplicaHandle | None = None
            best_key = None
            best_aff = 0
            best_hop = 0.0
            for h in self.handles:
                aff_tokens = h.affinity_blocks(hashes) * self.block_size
                hop_s = 0.0
                if cached and h.view is not None:
                    hop_s = h.view.estimate_get_latency_s(
                        payload_bytes=payload_bytes, block_hash=tail_hash)
                score = (self.w_affinity * aff_tokens
                         - self.w_load * h.load_tokens)
                # hop latency splits equal-score candidates; remaining
                # ties go to the emptier replica, then the lower index
                key = (score, -hop_s, -h.load_tokens, -h.index)
                if best_key is None or key > best_key:
                    best_h, best_key = h, key
                    best_aff, best_hop = aff_tokens, hop_s
            load_before = best_h.load_tokens
            return RouteDecision(
                replica=best_h.index,
                affinity_tokens=best_aff,
                cached_blocks=cached,
                hop_latency_s=best_hop,
                load_tokens=load_before,
                committed_tokens=self._commit(best_h, hashes, len(tokens),
                                              est_new_tokens),
            )


def make_router(policy: str, handles: list[ReplicaHandle], *,
                manager=None, block_size: int | None = None,
                seed: int = 0, bytes_per_token: float | None = None,
                delta_payloads: bool = False) -> Router:
    """``"prefix_affinity"`` or ``"random"`` -> a configured router."""
    if policy == "prefix_affinity":
        return PrefixAffinityRouter(handles, manager=manager,
                                    block_size=block_size,
                                    bytes_per_token=bytes_per_token,
                                    delta_payloads=delta_payloads)
    if policy == "random":
        return RandomRouter(handles, manager=manager,
                            block_size=block_size, seed=seed)
    raise ValueError(f"unknown routing policy: {policy!r}")
