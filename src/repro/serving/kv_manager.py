"""TieredKVManager: the serving stack's three-level KV fabric.

* **L0 -- device page pool** (``repro.models.cache.PagedKVCache``): the
  pages decode and chunked prefill read/write in place.  Pages are
  allocated *lazily* as sequences grow (no worst-case reservation), so
  the pool can run more live sequences than it could hold at their
  maximum lengths.
* **L1 -- host-RAM page cache** (``HostPageCache``): preempted
  sequences' pages, exported in one gathered device read per pool.  A
  hit restores bit-identical K/V -- including the non-block-aligned tail
  page -- so a resumed sequence replays nothing.
* **L2 -- the constellation** (``core.protocol.KVCManager`` over
  ``ConstellationKVC``): when the host cache overflows, the shared LRU
  policy picks a victim whose *block-aligned* prefix is spilled as Set
  KVC payloads built directly from the exported pages (no model
  recompute) and indexed in the same radix tree as ordinary write-backs.
  A restore that misses L1 runs Get KVC on the sequence's exact token
  chain, drops fetched blocks into pool pages, and leaves only the
  unaligned tail for the scheduler to replay through the chunked-prefill
  path.  On a *clocked* fabric (``core.protocol.SimClock`` on the
  transport) every Get completes at a virtual time: ``lookup_prefix``
  hands the scheduler a ``ready_at`` so it can defer consuming the
  payload (overlapping the ISL flight with decode steps), and
  ``wait_fetch`` settles -- and accounts, as ``EngineStats.l2_wait_s``
  -- whatever flight time could not be hidden.
* **L3 -- the ground-station tier** (``core.protocol.
  GroundStationTier`` attached to the ``ConstellationKVC``): the
  durable store below the constellation.  Nothing here talks to it
  directly -- that is the point: spill victims land on ground through
  the same Set KVC path (the KVC's ``ground_write`` policy), and a
  restore prefers orbit but falls back to ground inside ``get_block``'s
  replicas -> ground fall-through, at an uplink-priced round trip on
  the same clock.  ``_observe_l2`` attributes those ``ground_hits`` (and
  detoured chunk ops under link faults) to this replica's
  ``EngineStats``.

One ``LRUClock`` (``core.eviction``) stamps accesses across all three
levels plus the radix index, so "least recently used" is one timeline,
not three.  Admission refusal and pool exhaustion stop being failure
modes: under memory pressure the scheduler calls ``offload`` on a
victim and the fabric absorbs it.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.core.eviction import LRUClock
from repro.core.protocol import KVCManager
from repro.models.cache import PagedKVCache
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.stats import EngineStats


@dataclass
class HostEntry:
    """One offloaded sequence's pages in host RAM.

    ``pinned`` entries are exempt from capacity eviction: MoE sequences
    must restore bit-exact from here (replaying their tail as a chunk
    group would re-route experts -- capacity routing is group-composition
    dependent -- and change the rebuilt K/V), so their pages may not be
    spilled-and-dropped the way dense families' can.
    """

    k: object                 # np [layers, n_pages, page, Hkv, hd]
    v: object
    tokens: list[int]         # the tokens those pages cover, in order
    pinned: bool = False
    n_pages: int = field(init=False)

    def __post_init__(self) -> None:
        self.n_pages = int(self.k.shape[1])

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


class HostPageCache:
    """L1: offloaded page sets keyed by sequence, bounded in pages.

    ``capacity_pages=None`` means unbounded (host RAM is the backstop);
    ``0`` disables the tier (every offload spills straight to L2 /
    recompute -- the ablation knob).  Victims are chosen by the shared
    ``LRUClock``; the ``spill`` callback receives each evicted entry
    before it is dropped.
    """

    def __init__(self, capacity_pages: int | None, policy: LRUClock,
                 spill=None) -> None:
        self.capacity_pages = capacity_pages
        self.policy = policy
        self.spill = spill
        self._entries: dict[object, HostEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def used_pages(self) -> int:
        return sum(e.n_pages for e in self._entries.values())

    def put(self, key, entry: HostEntry) -> None:
        self._entries[key] = entry
        self.policy.touch(("l1", key))
        if self.capacity_pages is None:
            return
        while self.used_pages > self.capacity_pages:
            victim = self.policy.victim(
                ("l1", k) for k, e in self._entries.items()
                if not e.pinned)
            if victim is None:
                break             # only pinned entries remain: keep them
            _, vkey = victim
            evicted = self._entries.pop(vkey)
            self.policy.forget(victim)
            if self.spill is not None:
                self.spill(vkey, evicted)

    def pop(self, key) -> HostEntry | None:
        entry = self._entries.pop(key, None)
        if entry is not None:
            self.policy.forget(("l1", key))
        return entry


class TieredKVManager:
    """Owns the page pool and moves K/V between the three tiers.

    The scheduler speaks tokens (``*_tokens`` arguments); this class
    translates to pages.  All device writes happen between jitted steps,
    exactly like the pre-tiered engine's page drops.
    """

    def __init__(
        self,
        pool: PagedKVCache,
        adapter: SkyKVCAdapter,
        manager: KVCManager | None,
        *,
        host_cache_pages: int | None = None,
        write_back: bool = True,
    ) -> None:
        self.pool = pool
        self.adapter = adapter
        self.manager = manager
        self.write_back = write_back
        self.stats = EngineStats()       # facade re-points this per run
        self.policy: LRUClock = (
            manager.policy if manager is not None else LRUClock())
        self.host = HostPageCache(host_cache_pages, self.policy,
                                  spill=self._spill_to_l2)
        self._wb_future = None           # in-flight async Set KVC
        # clocked fabric: L2 Gets complete at a virtual time on the
        # constellation transport's SimClock (None = legacy instant L2)
        self._transport = (None if manager is None
                           else getattr(manager.cache, "transport", None))
        self.clock = None if self._transport is None else self._transport.clock

    # -- L0: lazy page accounting --------------------------------------
    def can_admit_tokens(self, n_tokens: int) -> bool:
        return self.pool.can_admit(n_tokens)

    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Allocate pages for ``n_tokens`` now (admission/restore); True
        when the block table changed."""
        return self.pool.ensure_capacity(slot, n_tokens)

    def try_grow(self, slot: int, n_tokens: int) -> tuple[bool, bool]:
        """Grow ``slot`` to hold ``n_tokens`` tokens if the free list
        allows: ``(ok, table_changed)``.  ``ok=False`` means the pool is
        exhausted -- the scheduler's cue to preempt a victim, never an
        exception."""
        need = self.pool.pages_for(n_tokens)
        have = self.pool.pages_allocated(slot)
        if need <= have:
            return True, False
        if self.pool.free_pages < need - have:
            return False, False
        return True, self.pool.ensure_capacity(slot, n_tokens)

    def release(self, slot: int) -> None:
        self.pool.free_slot(slot)

    # -- preemption-by-offload ------------------------------------------
    def offload(self, key, slot: int, tokens: list[int]) -> int:
        """Export the pages covering ``tokens`` (one gathered read per
        pool) into the host tier under ``key``.  Returns pages moved.
        The slot itself is NOT freed here -- the scheduler releases it,
        keeping page bookkeeping in one place."""
        n_pages = self.pool.pages_for(len(tokens))
        if n_pages == 0:
            return 0
        if self.manager is not None:
            # the spill path mutates the radix index; settle any async
            # write-back first so index updates stay single-threaded
            self.drain_write_back()
        k, v = self.pool.export_pages(slot, n_pages)
        # MoE restores must be bit-exact (tail replay would re-route
        # experts), so their host entries are pinned against eviction
        pinned = self.pool.cfg.num_experts > 0
        self.host.put(key, HostEntry(k=k, v=v, tokens=list(tokens),
                                     pinned=pinned))
        self.stats.offloaded_pages += n_pages
        return n_pages

    def take_host(self, key) -> HostEntry | None:
        """Claim ``key``'s host-tier pages (bit-exact restore source)."""
        return self.host.pop(key)

    def restore(self, key, slot: int, tokens: list[int]) -> int:
        """Repopulate ``slot``'s pages for ``tokens``; returns how many
        leading tokens are covered (the scheduler replays the rest).

        L1 hit: the exact exported pages come back -- full coverage,
        including the unaligned tail page, nothing to replay.  L1 miss:
        Get KVC on the token chain restores the longest block-aligned
        prefix the constellation still holds (possibly spilled there by
        the host tier, possibly written back long ago, possibly gone --
        then the whole sequence replays, the recompute flavor of
        preemption)."""
        entry = self.take_host(key)
        if entry is not None:
            self.pool.write_pages(slot, 0, jnp.asarray(entry.k),
                                  jnp.asarray(entry.v))
            return min(entry.n_tokens, len(tokens))
        if self.manager is None:
            return 0
        self.drain_write_back()
        if self._transport is not None:
            self._transport.last_ready_at = None
        with self._observe_l2():
            payload, cached = self.manager.get_cache_tokens(tokens)
        if payload is None or not cached:
            return 0
        # a restore is already a stall point: experience the Get's flight
        # time here rather than deferring (nothing else can run for this
        # slot until its pages are back)
        if self._transport is not None:
            self.wait_fetch(self._transport.last_ready_at)
        cached = min(cached, len(tokens))
        k_blocks, v_blocks = self.adapter.payload_to_pages(
            payload, cached, self.pool.page_size)
        self.pool.write_pages(slot, 0, k_blocks, v_blocks)
        return cached

    def _spill_to_l2(self, key, entry: HostEntry) -> None:
        """Host-tier eviction: push the entry's block-aligned prefix to
        the constellation as exact-page payloads (no model recompute);
        the unaligned tail is dropped and recomputed at restore."""
        if self.manager is None:
            return
        bs = self.manager.block_size
        n_blocks = entry.n_tokens // bs
        if n_blocks == 0:
            return
        added = self.manager.add_precomputed_blocks(
            entry.tokens[: n_blocks * bs],
            # tokens let a +delta codec recompute back-pointer hashes,
            # so spilled chains are O(1) bytes per block too
            lambda nb: self.adapter.pages_to_payload(
                entry.k, entry.v, nb * bs,
                tokens=entry.tokens[: n_blocks * bs]),
        )
        self.stats.spilled_blocks += added

    # -- L2: SkyMemory prefix lookups / write-back ----------------------
    @contextmanager
    def _observe_l2(self):
        """Attribute the fabric's fault counters to this replica: any
        degraded reads (dead-replica fallthrough) the wrapped L2 call
        experienced land in ``EngineStats.degraded_reads``, detoured
        chunk ops (killed links rerouted around) in
        ``EngineStats.detoured_ops``, ground-tier answers (every orbital
        replica out, the durable tier served) in
        ``EngineStats.ground_hits``, degraded directory lookups (a dead
        metadata-stripe home probed before a surviving replica answered)
        in ``EngineStats.degraded_lookups``, fabric-shortened prefixes
        (a promised later chunk gone from every replica, served shorter)
        in ``EngineStats.shortened_prefixes``, and a block-miss delta --
        the radix index pointed at blocks the fabric could no longer
        serve from *any* tier, so (part of) the prefix falls back to
        recompute, never an exception -- bumps
        ``EngineStats.lost_blocks``."""
        # resolved per call: benchmarks re-point a view's CacheStats
        # between the warmup and the timed run
        cs = (None if self.manager is None
              else getattr(self.manager.cache, "stats", None))
        if cs is None:
            yield
            return
        degraded0, misses0 = cs.degraded_reads, cs.block_misses
        detoured0, ground0 = cs.detoured_ops, cs.ground_hits
        dlook0, short0 = cs.degraded_lookups, cs.shortened_prefixes
        try:
            yield
        finally:
            self.stats.degraded_reads += cs.degraded_reads - degraded0
            self.stats.detoured_ops += cs.detoured_ops - detoured0
            self.stats.ground_hits += cs.ground_hits - ground0
            self.stats.degraded_lookups += cs.degraded_lookups - dlook0
            self.stats.shortened_prefixes += (
                cs.shortened_prefixes - short0)
            if cs.block_misses > misses0:
                self.stats.lost_blocks += 1

    def lookup_prefix(
        self, tokens: list[int]
    ) -> tuple[bytes | None, int, float | None]:
        """Get KVC for the longest cached prefix, draining any in-flight
        write-back first so duplicate contexts queued together still hit
        (the paper's repeated-context workload).

        Returns ``(payload, n_cached_tokens, ready_at)``.  ``ready_at``
        is the Get's completion time on the fabric clock (None when the
        fabric is unclocked or nothing was fetched): the payload bytes
        are in hand, but the scheduler must not *use* them before the
        clock passes ``ready_at`` -- it defers the consuming chunk to
        overlap the flight with decode steps, and ``wait_fetch`` settles
        whatever could not be hidden.

        Under constellation faults an unrecoverable block simply
        shortens (or zeroes) the returned prefix: the KVC manager walks
        back to the longest still-servable boundary, and the scheduler
        recomputes the rest -- churn degrades the hit rate, never a
        request."""
        if self.manager is None:
            return None, 0, None
        self.drain_write_back()
        if self._transport is not None:
            self._transport.last_ready_at = None
        with self._observe_l2():
            payload, cached = self.manager.get_cache_tokens(tokens)
        ready_at = None
        if (payload is not None and self._transport is not None
                and self.clock is not None):
            ready_at = self._transport.last_ready_at
        return payload, cached, ready_at

    def fetch_pending(self, ready_at: float | None) -> bool:
        """True while a fetched payload is still in simulated flight."""
        return (ready_at is not None and self.clock is not None
                and self.clock.now() < ready_at)

    def wait_fetch(self, ready_at: float | None) -> float:
        """Block until the clock passes ``ready_at`` -- the experienced
        part of an L2 flight the scheduler could not hide behind decode
        steps.  Returns virtual seconds waited."""
        if ready_at is None or self.clock is None:
            return 0.0
        waited = self.clock.wait_until(ready_at)
        if waited > 0.0:
            self.stats.l2_wait_s += waited
            self.stats.l2_fetch_waits += 1
        return waited

    def pages_async(self, payload: bytes, n_tokens: int):
        """Fetch-ahead payload -> pages decode on the adapter worker.

        Under a quantized codec this is where the dequantize leg runs:
        on the worker, overlapped with live decode steps, never on the
        serving loop.  The wall-clock it spends there is accounted as
        ``EngineStats.dequant_overlap_s`` -- decompression time the
        requests did not experience."""
        def decode():
            t0 = time.perf_counter()
            out = self.adapter.payload_to_pages(payload, n_tokens,
                                                self.pool.page_size)
            self.stats.dequant_overlap_s += time.perf_counter() - t0
            return out

        return self.adapter.run_async(decode)

    def write_back_async(self, tokens: list[int]) -> None:
        """Set KVC for a finished prefill *off* the decode loop: the
        block payload computation (one forward per uncached block) runs
        on the adapter's worker thread and the next lookup drains it, so
        write-back no longer stalls running decodes."""
        if self.manager is None:
            return
        self._wb_future = self.adapter.run_async(
            self.manager.add_blocks_tokens, tokens)

    def write_back_sync(self, tokens: list[int]) -> None:
        if self.manager is not None:
            self.manager.add_blocks_tokens(tokens)

    def drain_write_back(self) -> None:
        if self._wb_future is not None:
            self._wb_future.result()
            self._wb_future = None
