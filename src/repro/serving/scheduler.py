"""Scheduler: admission, chunk budgeting, and preemption policy.

This is the paged runtime's host-side brain.  It owns the request
lifecycle (QUEUED -> PREFILLING -> RUNNING -> FINISHED, with PREEMPTED
as the swap detour), the per-slot lane state the jitted steps consume
(lengths / input tokens / sampling params), and three policies:

* **Admission** is continuous and *lazy*: a request needs a free slot
  and pages for its prompt plus one decode write -- not its worst-case
  footprint.  The pool can therefore run more live sequences than it
  could hold at their maximum lengths.
* **Chunk budgeting**: prompts prefill in page-aligned chunks of at most
  ``chunk_tokens`` that ride the decode step (see ``chunk_spans``);
  cold-start waves prefill together as lockstep batched chunk steps.
* **Preemption-by-offload**: when a running sequence needs a page and
  the pool has none (growth pressure), or a strictly higher-priority
  request is queued behind a full machine (priority pressure), the
  lowest-priority sequence -- ties broken against the most recently
  admitted -- is offloaded through the ``TieredKVManager`` (device ->
  host -> constellation) and requeued at the front.  It resumes via
  ``restore``: a host-tier hit imports bit-identical pages (nothing
  replayed); a miss restores the longest block-aligned prefix the
  constellation holds and replays only the unaligned tail through the
  chunked-prefill path, with the already-sampled next token carried
  across the swap so outputs are unchanged.  Admission refusal and pool
  exhaustion are no longer failure modes.

The scheduler never touches device arrays: the ``PagedExecutor`` runs
the programs, the ``TieredKVManager`` moves K/V between tiers.
"""
from __future__ import annotations

import time
from collections import deque
from concurrent.futures import Future, InvalidStateError

import jax.numpy as jnp
import numpy as np

from repro.serving.executor import PagedExecutor
from repro.serving.kv_manager import TieredKVManager
from repro.serving.request import (
    GenerationResult,
    Request,
    Seq,
    SeqState,
    seq_finished,
    seq_result,
)
from repro.serving.sampler import SamplingParams, stack_sampling
from repro.serving.stats import EngineStats
from repro.serving.tokenizer import truncate_prompt


def head_span(n_tokens: int, cursor: int, budget: int) -> tuple[int, int]:
    """The next chunk for a prompt of ``n_tokens`` prefilled up to
    ``cursor``: ``(start, length)`` with length at most ``budget``.  The
    scheduler consumes exactly this, one span per step."""
    return cursor, min(budget, n_tokens - cursor)


def chunk_spans(n_tokens: int, start: int, budget: int
                ) -> list[tuple[int, int]]:
    """The full chunk plan for a prompt of ``n_tokens`` whose pages are
    already valid up to ``start`` (a restored SkyMemory prefix, or the
    replay point of a whole-prompt hit): the ``head_span`` sequence,
    covering ``[start, n_tokens)`` in order.  Only the final span may be
    ragged, so every split lands on a page boundary whenever ``start``
    and ``budget`` are page-aligned."""
    spans = []
    cursor = start
    while cursor < n_tokens:
        s, v = head_span(n_tokens, cursor, budget)
        spans.append((s, v))
        cursor = s + v
    return spans


class Scheduler:
    """Continuous-batching scheduler over one executor + KV fabric."""

    def __init__(
        self,
        executor: PagedExecutor,
        kv: TieredKVManager,
        tokenizer,
        *,
        max_batch: int,
        max_seq_len: int,
        chunk_tokens: int,
    ) -> None:
        self.ex = executor
        self.kv = kv
        self.tokenizer = tokenizer
        self.max_batch = max_batch
        self.max_seq_len = max_seq_len
        self.chunk_tokens = chunk_tokens
        self.chunked = bool(chunk_tokens)
        self.stats = EngineStats()
        self.chunk_log: list[tuple[int, int, int]] = []  # (slot, start, n)
        self._admit_counter = 0
        self._reset_stream()

    def _reset_stream(self) -> None:
        """(Re)initialize the persistent streaming machine state.  The
        scheduler is long-lived now: ``submit``/``service`` operate on
        this state across an open-ended stream, and ``run`` is a closed
        batch riding the same machinery."""
        b = self.max_batch
        # submit() appends here from any thread; the servicing thread
        # drains it into _pending (deque append/popleft are atomic, and
        # _pending stays single-threaded for the preemption requeues)
        self._inbox: deque[Seq] = deque()
        self._pending: deque[Seq] = deque()
        self._active: dict[int, Seq] = {}
        self._prefilling: dict[int, Seq] = {}  # insertion order == FIFO
        self._free_slots = list(range(b - 1, -1, -1))
        self._lengths = np.zeros(b, np.int32)
        self._tokens = np.zeros(b, np.int32)
        self._samp = [SamplingParams() for _ in range(b)]
        self._last_tok_t = [0.0] * b
        self._samp_dirty = self._bt_dirty = True
        self._admit_stall = False  # a stop-the-world wave ran under decodes

    # ------------------------------------------------------------------
    # streaming entry points
    # ------------------------------------------------------------------
    @property
    def backlog(self) -> bool:
        """Anything submitted but not yet finished/failed."""
        return bool(self._inbox or self._pending
                    or self._active or self._prefilling)

    def submit(self, request: Request) -> Future:
        """Enqueue one request; the returned future resolves to its
        ``GenerationResult`` when it finishes (or raises if it can never
        be admitted).  Thread-safe: the worker loop (or ``run``) does the
        actual stepping."""
        s = self._make_seq(request)
        s.future = Future()
        self._inbox.append(s)
        return s.future

    def service(self) -> bool:
        """One scheduling round: drain the inbox, grow/admit, and run one
        fused device step if anything is live.  Returns whether backlog
        remains.  Single-threaded: only the worker loop or ``run`` may
        call this."""
        self._drain_inbox()
        # -- growth: running sequences claim next-write pages first -----
        if self._active:
            self._grow_active()
        # -- admission: fill freed slots from the queue ------------------
        self._admit()
        if self._active or self._prefilling:
            self._step_once()
        elif self._pending:
            # the machine is idle (every slot free, nothing to preempt)
            # and the head still cannot admit: its footprint can never
            # fit.  Fail that request alone; the stream continues.
            s = self._pending.popleft()
            self._fail_seq(s, RuntimeError(
                "cannot admit request: KV page pool too small for "
                f"a {self._need_tokens(s)}-token "
                "footprint even with every slot preempted"))
        return self.backlog

    def _drain_inbox(self) -> None:
        while self._inbox:
            self._pending.append(self._inbox.popleft())

    def cancel_queued(self) -> int:
        """Cancel every submitted-but-unstarted request (fresh QUEUED
        seqs; preempted ones are mid-request and keep their claim).
        Returns how many were cancelled -- the ``stop(drain=False)``
        path."""
        self._drain_inbox()
        kept: deque[Seq] = deque()
        n = 0
        for s in self._pending:
            if (s.state is SeqState.QUEUED and s.future is not None
                    and s.future.cancel()):
                n += 1
            else:
                kept.append(s)
        self._pending = kept
        return n

    def fail_all(self, exc: BaseException) -> None:
        """A worker-loop crash: fail every in-flight future so no waiter
        hangs, release their slots/pages, and reset the machine."""
        self._drain_inbox()
        seqs = list(self._pending)
        for slot, s in (list(self._active.items())
                        + list(self._prefilling.items())):
            self.kv.release(slot)
            seqs.append(s)
        for s in seqs:
            if s.future is not None:
                try:
                    s.future.set_exception(exc)
                except InvalidStateError:
                    pass
        self._reset_stream()

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[GenerationResult]:
        """Closed-batch serve: a thin wrapper over the streaming path.
        Submits everything, services until the stream drains, and returns
        results in request order with the legacy batch-wall stamping."""
        t_start = time.perf_counter()
        self.chunk_log = []
        futs = [self.submit(r) for r in requests]
        while self.service():
            pass

        self.kv.drain_write_back()   # settle Set KVC before handing back
        wall = time.perf_counter() - t_start
        out = []
        first_err: BaseException | None = None
        for fut in futs:
            err = fut.exception()
            if err is not None:
                first_err = first_err or err
                continue
            res = fut.result()
            res.wall_time_s = wall
            out.append(res)
        if first_err is not None:
            raise first_err
        return out

    # ------------------------------------------------------------------
    # one fused device step + host bookkeeping
    # ------------------------------------------------------------------
    def _step_once(self) -> None:
        b = self.max_batch
        chunk = self._plan_chunk()

        if self._samp_dirty:
            self._samp_dev = stack_sampling(self._samp)
            self._mode = self.ex.sampler_mode(self._samp)
            self._samp_dirty = False
        if self._bt_dirty:
            # contiguous slot regions need no table on device; free-list
            # pools upload the table only when admission/release/growth
            # changed it
            self._bt_dev = (None if self.kv.pool.contiguous
                            else jnp.asarray(self.kv.pool.block_tables))
            self._bt_dirty = False
        len_d = jnp.asarray(self._lengths)
        tok_d = jnp.asarray(self._tokens)

        # -- one fused device step; ONE host sync (the token read) ------
        t0 = time.perf_counter()
        temps_d, tks_d, tps_d = self._samp_dev
        ops_c = None if chunk is None else chunk[4]
        nxt = self.ex.step(self._bt_dev, len_d, tok_d, temps_d, tks_d,
                           tps_d, self._mode, chunk_ops=ops_c)
        nxt_h = np.asarray(nxt)               # the step's single host sync
        now = time.perf_counter()
        self.stats.decode_time_s += now - t0
        self.stats.decode_steps += 1

        # -- host-side scheduling on the synced token ids ---------------
        in_admission = bool(self._prefilling) or self._admit_stall
        self._admit_stall = False
        for slot, s in list(self._active.items()):
            tid = int(nxt_h[slot])
            s.out_ids.append(tid)
            self.stats.decoded_tokens += 1
            itl = now - self._last_tok_t[slot]
            self.stats.itl_s.append(itl)
            s.itl.append(itl)
            if in_admission:
                self.stats.itl_admission_s.append(itl)
            self._last_tok_t[slot] = now
            self._lengths[slot] += 1
            if seq_finished(s, tid, eos_id=self.tokenizer.eos_id,
                            max_seq_len=self.max_seq_len):
                self._active.pop(slot)
                self._release(s, slot)
            else:
                self._tokens[slot] = tid

        # -- chunk retirement -------------------------------------------
        if chunk is not None:
            s_c, slot_c, start_c, v_c, _ = chunk
            self.stats.prefill_chunks += 1
            s_c.cursor = start_c + v_c
            if s_c.cursor >= len(s_c.prefill_tokens):
                # last chunk landed: its first token was sampled in-step
                # (row b of the synced vector); a resumed sequence's next
                # token is already known, so that sample is discarded
                self._prefilling.pop(slot_c)
                # (Set KVC for this sequence was already submitted at
                # lookup time by _lookup_and_prefetch, so any duplicate
                # context's later lookup drains it and hits)
                self._finish_prefill(s_c, slot_c, int(nxt_h[b]), now)
                if s_c.done:
                    self._release(s_c, slot_c)
                elif slot_c not in self._active:
                    self._active[slot_c] = s_c
                    self._last_tok_t[slot_c] = now
                self._samp_dirty = self._bt_dirty = True

    # ------------------------------------------------------------------
    # admission / restore
    # ------------------------------------------------------------------
    def _admit(self) -> None:
        admitted: list[tuple[Seq, int]] = []
        while self._pending:
            s = self._pending[0]
            if self._free_slots and self.kv.can_admit_tokens(
                    self._need_tokens(s)):
                self._pending.popleft()
                admitted.append(self._admit_seq(s))
                continue
            # priority pressure: a strictly higher-priority queued request
            # evicts the lowest-priority victim (equal priorities never
            # preempt each other, so plain FIFO streams cannot thrash)
            victim = self._pick_victim()
            if (victim is not None
                    and victim[1].request.priority < s.request.priority):
                # requeue the victim BEHIND the head that evicted it
                self._preempt(victim, requeue_pos=1)
                continue
            break
        # best-effort FIFO: a preempted head waiting for its (larger)
        # restore footprint must not idle free slots -- fresh requests
        # behind it may admit into pages it cannot use yet.  The head
        # regains first claim at the top of every admission round, so it
        # resumes the moment its pages fit and cannot starve.
        if (self._pending and self._free_slots
                and self._pending[0].state is SeqState.PREEMPTED):
            i = 1
            while i < len(self._pending) and self._free_slots:
                s = self._pending[i]
                if (s.state is not SeqState.PREEMPTED
                        and self.kv.can_admit_tokens(self._need_tokens(s))):
                    del self._pending[i]
                    admitted.append(self._admit_seq(s))
                else:
                    i += 1
        if not admitted:
            return
        self._bt_dirty = True

        # fully-restored sequences (host-tier hit: every page back,
        # including the unaligned tail) resume decoding immediately
        live: list[tuple[Seq, int]] = []
        now = time.perf_counter()
        for s, slot in admitted:
            if (s.replay_next is not None
                    and s.cursor >= len(s.prefill_tokens)):
                self._resume_active(s, slot, now)
            else:
                live.append((s, slot))
        if not live:
            return

        if self.chunked and (self._active or self._prefilling):
            # decode is live: chunks ride the decode steps so no running
            # sequence stalls for this admission
            for s, slot in live:
                s.state = SeqState.PREFILLING
                self._prefilling[slot] = s
                # park the slot's decode lane on its last reservable
                # position: the idle lane's unconditional write lands
                # where no chunk data lives (free-list rows point unbacked
                # logical pages at the scratch page) and where any real
                # decode write would overwrite it anyway
                self._lengths[slot] = s.reserve - 1
                self._tokens[slot] = 0
        else:
            # nothing is decoding, so nothing can starve: prefill the
            # whole wave now (as batched chunk steps when chunked, else
            # the bucketed stop-the-world wave)
            self._admit_stall = bool(self._active)
            if self.chunked:
                self._admit_wave_chunked(live)
            else:
                self._admit_wave(live)
            self._samp_dirty = True

    def _admit_seq(self, s: Seq) -> tuple[Seq, int]:
        """Slot + page bookkeeping for one admission (fresh or restore)."""
        slot = self._free_slots.pop()
        # allocate NOW so can_admit for the rest of the wave sees the
        # shrunken free list (free-list pools)
        s.reserve = self._reserve_tokens(s)
        self._bt_dirty |= self.kv.reserve(slot, self._need_tokens(s))
        self._admit_counter += 1
        s.admit_seq = self._admit_counter
        if self._active or self._prefilling:
            self.stats.mid_decode_admissions += 1
        if s.state is SeqState.PREEMPTED:
            self._restore(s, slot)
        return s, slot

    def _restore(self, s: Seq, slot: int) -> None:
        """Bring a preempted sequence's K/V back into pool pages; leaves
        ``s.cursor`` at the covered-token boundary (the tail past it
        replays through the chunk path)."""
        goal = len(s.replay_tokens)
        cached = self.kv.restore(s.request.request_id, slot,
                                 s.replay_tokens)
        self.stats.restores += 1
        if cached < goal:
            self.stats.replayed_tokens += goal - cached
        s.cursor = cached
        s.looked_up = True
        s.pages_future = None
        s.fetch_ready_at = None
        s.dev_ops = None

    def _resume_active(self, s: Seq, slot: int, now: float) -> None:
        """A restored sequence re-enters decode exactly where it left
        off: lane length is its covered-token count and the lane input is
        the token that was already sampled before the swap -- nothing is
        sampled twice, so outputs are unchanged."""
        self._lengths[slot] = len(s.replay_tokens)
        self._tokens[slot] = s.replay_next
        self._samp[slot] = s.request.sampling
        s.state = SeqState.RUNNING
        s.replay_tokens = None
        s.replay_next = None
        self._active[slot] = s
        self._last_tok_t[slot] = now
        self._samp_dirty = self._bt_dirty = True

    # ------------------------------------------------------------------
    # preemption
    # ------------------------------------------------------------------
    def _pick_victim(self) -> tuple[int, Seq, str] | None:
        """Lowest-priority in-flight sequence; ties broken against the
        most recently admitted (LIFO, so long-running work survives)."""
        cands = [(slot, s, "run") for slot, s in self._active.items()]
        cands += [(slot, s, "pre") for slot, s in self._prefilling.items()]
        if not cands:
            return None
        return min(cands,
                   key=lambda c: (c[1].request.priority, -c[1].admit_seq))

    def _grow_active(self) -> None:
        """Every running slot claims the page its next decode write needs;
        on pool exhaustion, preempt victims until it fits (or the grower
        itself is the victim and leaves the machine)."""
        for slot in list(self._active.keys()):
            if slot not in self._active:
                continue          # offloaded by an earlier victim pick
            need = int(self._lengths[slot]) + 1
            while True:
                ok, changed = self.kv.try_grow(slot, need)
                if ok:
                    self._bt_dirty |= changed
                    break
                victim = self._pick_victim()
                vslot = self._preempt(victim)
                if vslot == slot:
                    break         # the grower was the cheapest victim

    def _preempt(self, victim: tuple[int, Seq, str], *,
                 requeue_pos: int = 0) -> int:
        """Offload a victim through the tier hierarchy and requeue it.

        RUNNING victims record their exact replay state (covered tokens +
        the already-sampled next token) and export every covered page.
        PREFILLING victims export what their retired chunks covered and
        go back to QUEUED (no token was emitted yet, so a fresh admission
        -- seeded by the host-tier pages -- reproduces them exactly).
        """
        slot, s, kind = victim
        if kind == "run":
            valid = int(self._lengths[slot])
            s.replay_tokens = (s.tokens + s.out_ids)[:valid]
            s.replay_next = int(self._tokens[slot])
            self.kv.offload(s.request.request_id, slot, s.replay_tokens)
            self._active.pop(slot)
            s.state = SeqState.PREEMPTED
        else:
            if s.pages_future is not None:
                # a fetched prefix is still in flight: land it first so
                # the export below covers everything the cursor claims
                self.kv.wait_fetch(s.fetch_ready_at)
                s.fetch_ready_at = None
                k_blocks, v_blocks = s.pages_future.result()
                s.pages_future = None
                self.kv.pool.write_pages(slot, 0, k_blocks, v_blocks)
            if s.cursor > 0:
                self.kv.offload(s.request.request_id, slot,
                                s.prefill_tokens[: s.cursor])
            self._prefilling.pop(slot)
            s.cursor = 0
            s.looked_up = False
            s.fetch_ready_at = None
            s.dev_ops = None
            # a resumed sequence caught mid-replay keeps its PREEMPTED
            # identity (replay state intact); a fresh prefill re-queues
            s.state = (SeqState.PREEMPTED if s.replay_next is not None
                       else SeqState.QUEUED)
        s.preempt_count += 1
        self.stats.preemptions += 1
        self.kv.release(slot)
        self._lengths[slot] = 0
        self._tokens[slot] = 0
        self._samp[slot] = SamplingParams()
        self._free_slots.append(slot)
        self._samp_dirty = self._bt_dirty = True
        if requeue_pos == 0 or not self._pending:
            self._pending.appendleft(s)
        else:
            self._pending.insert(requeue_pos, s)
        return slot

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _plan_chunk(self):
        """Pick the next prefill chunk (FIFO over prefilling sequences).

        The head sequence's SkyMemory lookup happens lazily here -- after
        any earlier sequence's write-back, so duplicate contexts queued
        together still hit -- and its payload->pages decode runs on the
        adapter's fetch-ahead thread alongside any simulated ISL flight:
        while the head's fetch is pending and other sequences are
        decoding, its chunk is deferred so the flight/deserialization
        overlaps device compute, and the *next* prefilling sequence's
        chunks run instead of head-of-line blocking behind the flight.
        Returns ``(seq, slot, start, n_valid, device_operands)`` or None.
        """
        if not self.chunked or not self._prefilling:
            return None
        # FIFO over prefilling sequences, but a head whose fetched prefix
        # is still pending (payload decoding, or ISL flight on the fabric
        # clock) must not head-of-line-block the others for the whole
        # flight: skip past it and plan the first ready sequence.  Later
        # candidates are only looked up inside such a window, so in the
        # common (no-pending-head) case lookup order stays strictly FIFO.
        deferred: tuple[int, Seq] | None = None
        chosen: tuple[int, Seq] | None = None
        saw_flight = False
        for slot, s in list(self._prefilling.items()):
            if not s.looked_up:
                t0 = time.perf_counter()
                self._lookup_and_prefetch(s)
                self.stats.prefill_time_s += time.perf_counter() - t0
            if s.pages_future is not None and (
                    self.kv.fetch_pending(s.fetch_ready_at)
                    or not s.pages_future.done()):
                saw_flight |= self.kv.fetch_pending(s.fetch_ready_at)
                if deferred is None:
                    deferred = (slot, s)
                continue
            chosen = (slot, s)
            break
        if chosen is None:
            if self._active or deferred is None:
                # every candidate is in flight: this step's chunk slot is
                # spent overlapping the flight(s); the chunks retry next
                # step
                if saw_flight:
                    self.stats.l2_deferred_chunks += 1
                return None
            # nothing is decoding and nothing is ready: experience the
            # first pending sequence's remaining flight
            chosen = deferred
        slot, s = chosen
        if s.pages_future is not None:
            self.kv.wait_fetch(s.fetch_ready_at)
            s.fetch_ready_at = None
            k_blocks, v_blocks = s.pages_future.result()
            s.pages_future = None
            self.kv.pool.write_pages(slot, 0, k_blocks, v_blocks)
        toks = s.prefill_tokens
        n = len(toks)
        start, v = head_span(n, s.cursor, self.chunk_tokens)
        self.kv.pool.note_span(slot, start, v)
        self.chunk_log.append((slot, start, v))
        if s.dev_ops is None:
            # per-sequence invariants, uploaded once per admission: the
            # block-table row is frozen (pages for the whole prompt were
            # allocated at admission) and sampling never changes per
            # request
            s.dev_ops = (
                jnp.asarray(self.kv.pool.table_row(slot)[None], jnp.int32),
                *stack_sampling([s.request.sampling]),
            )
        buf = np.zeros((1, self.ex.chunk_buf(v)), np.int32)
        buf[0, :v] = toks[start:start + v]
        bt_row, c_temp, c_tk, c_tp = s.dev_ops
        ops_c = (
            jnp.asarray(buf), bt_row,
            jnp.asarray([start], jnp.int32), jnp.asarray([v], jnp.int32),
            c_temp, c_tk, c_tp,
        )
        return s, slot, start, v, ops_c

    def _admit_wave_chunked(self, admitted: list[tuple[Seq, int]]) -> None:
        """Cold-start admission wave, chunked flavor: nothing is decoding,
        so the wave's prompts prefill *together* as lockstep batched chunk
        steps over the page pool.

        Phase 1 walks the wave in order: SkyMemory lookup, fetch-ahead
        payload decode (submitted per sequence, resolved after the loop so
        deserialization overlaps the later members' lookups/write-backs),
        and Set KVC write-back -- before the NEXT member's lookup, so
        duplicate contexts within one wave still hit.  Phase 2 runs
        batched chunk steps until every prompt (or restore-replay tail)
        is covered; fresh sequences' final-chunk logits are kept and
        their first tokens sampled in one call with one host sync, while
        resumed sequences re-enter decode with their carried next token.
        """
        t0 = time.perf_counter()
        for s, slot in admitted:
            s.state = SeqState.PREFILLING
            if s.replay_next is not None:
                continue          # restore already repopulated its pages
            # lookup submits this member's Set KVC too, so the NEXT
            # member's lookup drains it and same-wave duplicates hit
            self._lookup_and_prefetch(s)
        for s, slot in admitted:
            if s.pages_future is not None:
                # cold start: nothing is decoding, so the fetch flights
                # cannot hide -- wait them out (clock is monotone, so the
                # wave's total wait is the max remaining flight)
                self.kv.wait_fetch(s.fetch_ready_at)
                s.fetch_ready_at = None
                k_blocks, v_blocks = s.pages_future.result()
                s.pages_future = None
                self.kv.pool.write_pages(slot, 0, k_blocks, v_blocks)

        last_logits: dict[int, jnp.ndarray] = {}
        live = [(s, slot) for s, slot in admitted]
        while live:
            c_b = self.ex.chunk_buf(max(
                min(self.chunk_tokens, len(s.prefill_tokens) - s.cursor)
                for s, _ in live))
            rows = 1
            while rows < len(live):          # pad batch rows to a power
                rows *= 2                    # of two: O(log max_batch)
            buf = np.zeros((rows, c_b), np.int32)
            offs = np.zeros(rows, np.int32)
            valids = np.zeros(rows, np.int32)   # padding rows are no-ops
            bts = np.zeros((rows, self.kv.pool.pages_per_seq), np.int32)
            for i, (s, slot) in enumerate(live):
                toks = s.prefill_tokens
                start = s.cursor
                v = min(c_b, len(toks) - start)
                buf[i, :v] = toks[start:start + v]
                offs[i], valids[i] = start, v
                bts[i] = self.kv.pool.table_row(slot)
                self.kv.pool.note_span(slot, start, v)
                self.chunk_log.append((slot, start, v))
            lg = self.ex.chunk_wave(buf, bts, offs, valids)
            self.stats.prefill_chunks += 1
            nxt_live = []
            for i, (s, slot) in enumerate(live):
                s.cursor = int(offs[i] + valids[i])
                if s.cursor >= len(s.prefill_tokens):
                    if s.replay_next is None:
                        last_logits[id(s)] = lg[i]
                else:
                    nxt_live.append((s, slot))
            live = nxt_live

        self.stats.prefill_time_s += time.perf_counter() - t0
        now = time.perf_counter()
        fresh = [(s, slot) for s, slot in admitted
                 if s.replay_next is None]
        for s, slot in admitted:
            if s.replay_next is not None:
                self._resume_active(s, slot, now)
        if not fresh:
            return
        # first tokens for the wave: one sample call, one host sync
        tids = self.ex.sample_first(
            [last_logits[id(s)] for s, _ in fresh],
            [s.request.sampling for s, _ in fresh])
        now = time.perf_counter()
        for (s, slot), tid in zip(fresh, tids):
            self._finish_prefill(s, slot, int(tid), now)
            if s.done:
                self._release(s, slot)
            else:
                self._active[slot] = s
                self._last_tok_t[slot] = now

    # ------------------------------------------------------------------
    # stop-the-world admission (MoE families / ``chunk_tokens=0``)
    # ------------------------------------------------------------------
    def _admit_wave(self, admitted: list[tuple[Seq, int]]) -> None:
        """Stop-the-world admission: SkyMemory hits restore blocks
        straight into pages and prefill only their suffix (per sequence);
        misses prefill as ONE batched, bucketed forward.  Resumed
        sequences replay their unaligned tail as one paged chunk (logits
        discarded -- the next token is already known).  First tokens for
        the wave's fresh members are sampled in one call with one host
        sync."""
        t0 = time.perf_counter()
        last_logits: list = []
        fresh: list[tuple[Seq, int]] = []
        sampled: list[tuple[Seq, int]] = []
        resumed: list[tuple[Seq, int]] = []
        for s, slot in admitted:
            if s.replay_next is not None:
                if s.cursor < len(s.prefill_tokens):
                    self._replay_tail(s, slot)
                resumed.append((s, slot))
                continue
            # (pages were already allocated in the admission loop)
            self._lookup_and_prefetch(s)
            if s.pages_future is not None:
                last_logits.append(self._prefill_suffix_paged(s, slot))
                sampled.append((s, slot))
            elif self.ex.cfg.num_experts > 0:
                # MoE: capacity-based expert routing is group-composition
                # dependent, so bucket padding would alter real tokens'
                # routing -- prefill exactly, one sequence at a time
                s.cached = 0
                last_logits.append(self._prefill_exact(s, slot))
                sampled.append((s, slot))
            else:
                s.cached = 0
                fresh.append((s, slot))
                last_logits.append(None)
                sampled.append((s, slot))
            # (Set KVC was submitted inside _lookup_and_prefetch, before
            # the NEXT wave member's lookup drains it, so duplicate
            # contexts within one admission wave still hit -- the
            # paper's repeated-context workload)

        if fresh:
            # one batched forward per length bucket; causal masking makes
            # the zero padding past each row's length invisible
            by_bucket: dict[int, list[int]] = {}
            for i, (s, _) in enumerate(fresh):
                by_bucket.setdefault(
                    self.ex.bucket(len(s.tokens)), []).append(i)
            fresh_logits: dict[int, jnp.ndarray] = {}
            for bucket, idxs in by_bucket.items():
                rows = 1
                while rows < len(idxs):      # pad batch dim to a power of
                    rows *= 2                # two: O(log^2) compilations
                toks = np.zeros((rows, bucket), np.int32)
                for row, i in enumerate(idxs):
                    toks[row, : len(fresh[i][0].tokens)] = fresh[i][0].tokens
                lg, _, state = self.ex.prefill_dense(jnp.asarray(toks))
                for row, i in enumerate(idxs):
                    s, slot = fresh[i]
                    n = len(s.tokens)
                    self.kv.pool.write_token_span(
                        slot, 0,
                        state["kv"]["k"][:, row, :n],
                        state["kv"]["v"][:, row, :n],
                    )
                    fresh_logits[i] = lg[row, n - 1]
            fi = 0
            for j, lgt in enumerate(last_logits):
                if lgt is None:
                    last_logits[j] = fresh_logits[fi]
                    fi += 1

        self.stats.prefill_time_s += time.perf_counter() - t0
        now = time.perf_counter()
        for s, slot in resumed:
            self._resume_active(s, slot, now)
        if not sampled:
            return
        # first tokens for the wave from the prefill logits: one sample
        # call, one host sync (at admission, not in the decode loop)
        tids = self.ex.sample_first(
            last_logits, [s.request.sampling for s, _ in sampled])
        now = time.perf_counter()
        for (s, slot), tid in zip(sampled, tids):
            self._finish_prefill(s, slot, int(tid), now)
            if s.done:
                self._release(s, slot)
            else:
                self._active[slot] = s
                self._last_tok_t[slot] = now

    def _prefill_exact(self, s: Seq, slot: int):
        lg, state = self.ex.prefill_exact(s.tokens)
        n = len(s.tokens)
        self.kv.pool.write_token_span(
            slot, 0,
            state["kv"]["k"][:, 0, :n],
            state["kv"]["v"][:, 0, :n],
        )
        return lg

    def _prefill_suffix_paged(self, s: Seq, slot: int):
        """SkyMemory hit under stop-the-world admission (the sequence's
        lookup already ran): fetched blocks drop straight into pool pages
        and the uncached suffix runs as ONE paged chunk attending over
        them *in place* -- no dense ``prefix_state`` restaging anywhere
        in the paged families.  A whole-prompt hit keeps every restored
        block and replays only the final token (the chunk machinery
        handles the one-token, unaligned-start span)."""
        n = len(s.tokens)
        self.kv.wait_fetch(s.fetch_ready_at)
        s.fetch_ready_at = None
        k_blocks, v_blocks = s.pages_future.result()
        s.pages_future = None
        self.kv.pool.write_pages(slot, 0, k_blocks, v_blocks)
        start = s.cursor
        v = n - start
        self.kv.pool.note_span(slot, start, v)
        self.chunk_log.append((slot, start, v))
        toks = np.asarray(s.tokens[start:], np.int32)[None]
        bt_row = np.asarray(self.kv.pool.table_row(slot)[None], np.int32)
        return self.ex.prefill_chunk_eager(toks, bt_row, start, v)

    def _replay_tail(self, s: Seq, slot: int) -> None:
        """Restore replay, stop-the-world flavor: the tokens past the
        restored prefix run as one paged chunk purely to rebuild their
        K/V (their output tokens exist already; the logits are
        discarded)."""
        toks = s.prefill_tokens
        start = s.cursor
        v = len(toks) - start
        self.kv.pool.note_span(slot, start, v)
        self.chunk_log.append((slot, start, v))
        buf = np.asarray(toks[start:], np.int32)[None]
        bt_row = np.asarray(self.kv.pool.table_row(slot)[None], np.int32)
        self.ex.prefill_chunk_eager(buf, bt_row, start, v)
        self.stats.prefill_chunks += 1
        s.cursor = len(toks)

    # ------------------------------------------------------------------
    # shared helpers
    # ------------------------------------------------------------------
    def _lookup_and_prefetch(self, s: Seq) -> None:
        """Prefix sources for a fresh admission, best tier first: the
        host page cache may hold this request's pages from a prefill-time
        preemption (bit-exact, possibly mid-page); otherwise SkyMemory's
        longest-prefix lookup -- on a hit, start at the cached boundary
        (a whole-prompt hit keeps every restored block and replays only
        the final token as a one-token chunk) and submit the
        payload->pages decode to the adapter's fetch-ahead thread.  Any
        in-flight Set KVC write-back is drained first, and this
        sequence's OWN write-back is submitted here -- at lookup time,
        not at prefill completion -- so a duplicate context looked up any
        time after this one (even while this one is still prefilling, as
        the skip-ahead chunk planner allows) drains it and hits."""
        s.looked_up = True
        entry = self.kv.take_host(s.request.request_id)
        if entry is not None:
            s.cursor = min(entry.n_tokens, len(s.tokens) - 1)
            fut = Future()
            fut.set_result((entry.k, entry.v))
            s.pages_future = fut
        else:
            payload, cached, ready_at = self.kv.lookup_prefix(s.tokens)
            if payload is not None and cached:
                restore = cached
                if cached >= len(s.tokens):
                    cached = len(s.tokens) - 1
                s.cached = cached
                s.cursor = cached
                s.fetch_ready_at = ready_at
                s.pages_future = self.kv.pages_async(payload, restore)
        if self.kv.write_back and self.kv.manager is not None:
            # Set KVC for uncached blocks on the worker thread (a no-op
            # radix probe when the lookup fully hit)
            self.kv.write_back_async(s.tokens)

    def _finish_prefill(self, s: Seq, slot: int, tid: int,
                        now: float) -> None:
        """A sequence's last chunk landed.  Fresh admission: book its
        first token.  Resumed sequence: the sampled id is discarded and
        the carried next token re-enters decode instead."""
        if s.replay_next is not None:
            self._resume_active(s, slot, now)
            return
        s.out_ids.append(tid)
        s.ttft_s = now - s.enqueue_t
        self.stats.ttft_s.append(s.ttft_s)
        self.stats.decoded_tokens += 1
        self.stats.cached_tokens += s.cached
        self.stats.prefilled_tokens += len(s.tokens) - s.cached
        s.state = SeqState.RUNNING
        if not seq_finished(s, tid, eos_id=self.tokenizer.eos_id,
                            max_seq_len=self.max_seq_len):
            self._lengths[slot] = len(s.tokens)
            self._tokens[slot] = tid
            self._samp[slot] = s.request.sampling

    def _make_seq(self, req: Request) -> Seq:
        tokens = truncate_prompt(self.tokenizer.encode(req.prompt),
                                 self.max_seq_len)
        return Seq(request=req, tokens=tokens,
                   enqueue_t=time.perf_counter())

    def _reserve_tokens(self, s: Seq) -> int:
        """Worst-case token footprint (prompt + max_new_tokens, capped at
        max_seq_len) -- no longer *reserved* in pages, but still the park
        position for an admitted sequence's idle decode lane."""
        return min(len(s.tokens) + s.request.sampling.max_new_tokens,
                   self.max_seq_len)

    def _need_tokens(self, s: Seq) -> int:
        """Pages a sequence needs AT admission: its prompt (or restored
        span) plus one decode write.  Growth past this is lazy,
        page-by-page, with preemption as the pressure valve."""
        if s.state is SeqState.PREEMPTED:
            return min(len(s.replay_tokens) + 1, self.max_seq_len)
        return min(len(s.tokens) + 1, self._reserve_tokens(s))

    def _release(self, s: Seq, slot: int) -> None:
        s.state = SeqState.FINISHED
        self.kv.release(slot)
        self._lengths[slot] = 0
        self._tokens[slot] = 0
        self._samp[slot] = SamplingParams()
        self._free_slots.append(slot)
        self._samp_dirty = self._bt_dirty = True
        self.stats.requests += 1
        self._finalize(s)

    def _finalize(self, s: Seq) -> None:
        """Resolve a finished sequence's future with its result.  The
        per-request wall clock ends here (a closed batch overwrites it
        with the batch wall afterwards, the legacy contract); future
        callbacks -- e.g. the cluster router's per-request load release
        -- run inline on the servicing thread."""
        s.wall_s = time.perf_counter() - s.enqueue_t
        if s.future is None:
            return
        try:
            s.future.set_result(seq_result(s, self.tokenizer))
        except InvalidStateError:
            pass                      # cancelled while finishing

    def _fail_seq(self, s: Seq, exc: BaseException) -> None:
        if s.future is None:
            raise exc
        try:
            s.future.set_exception(exc)
        except InvalidStateError:
            pass
