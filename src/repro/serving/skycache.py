"""Adapter between model decode state and SkyMemory KVC payloads.

The protocol (core/) moves opaque bytes; this adapter defines what those
bytes are per architecture family (DESIGN.md §4):

* dense/vlm/moe : per-layer K/V covering the cached prefix (cumulative, as
                  the paper's Get step 7 retrieves a single block whose
                  payload reconstructs the full prefix KVC);
* MLA           : compressed latent (c_kv, k_rope) -- ~14x smaller blocks;
* ssm/hybrid    : fixed-size (conv_state, ssm_state) snapshot at the block
                  boundary (+ shared-attn K/V for hybrids).

``kvc_fn`` plugs into ``core.protocol.KVCManager``: it computes one block's
payload by resuming from the previous block's payload -- never recomputing
the already-cached prefix (the compute saving the paper measures).

``codec=`` (a ``core.chunking.PayloadCodec``, or its string spec) shapes
what the payload bytes *are*: f32 ships the arrays verbatim (legacy wire
format), int8/int4 quantize with per-block-chunk scale tables, and
``+delta`` makes each dense cumulative block carry only its own
``block_size`` tokens plus a back-pointer (the KVC manager reassembles
the chain on restore).  Decoding is always codec-agnostic -- payloads
are self-describing -- so mixed-codec fabrics restore fine.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.chunking import (
    PayloadCodec,
    decode_payload_arrays,
    make_delta_payload,
)
from repro.core.hashing import chain_hashes
from repro.models.model import Model


class SkyKVCAdapter:
    def __init__(self, model: Model, params, *,
                 codec: "PayloadCodec | str | None" = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.codec = PayloadCodec.parse(codec) if not isinstance(
            codec, PayloadCodec) else codec
        # delta chains concatenate along the token axis, which only the
        # dense/vlm/moe cumulative K/V payload has end to end; SSM
        # snapshots and hybrid state are not token-sliceable
        self._delta_ok = (not self.cfg.use_mla
                          and self.cfg.arch_type not in ("ssm", "hybrid"))
        self._executor = None    # lazy fetch-ahead worker (pages_async)

    # -- codec-derived size model (the router's fallback price) -----------
    def payload_bytes_per_token(self) -> float | None:
        """Encoded payload bytes one cached token costs under this
        adapter's codec -- the size model the router falls back to when a
        block has no registered ``payload_bytes``.  None for families
        whose payload is not token-linear (SSM/hybrid snapshots)."""
        cfg = self.cfg
        if cfg.arch_type in ("ssm", "hybrid"):
            return None
        if cfg.use_mla:
            values = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        else:
            values = 2 * cfg.num_kv_heads * cfg.head_dim
        values *= cfg.num_layers
        itemsize = np.dtype(np.float32).itemsize
        try:
            itemsize = np.dtype(cfg.dtype).itemsize
        except TypeError:
            import ml_dtypes

            itemsize = np.dtype(getattr(ml_dtypes, cfg.dtype)).itemsize
        return values * self.codec.bytes_per_value(itemsize)

    # -- state <-> payload ------------------------------------------------
    def state_to_payload(self, state: dict, n_tokens: int, *,
                         past_len: int = 0,
                         prev_hash: bytes | None = None) -> bytes:
        """Serialize the decode state for the first ``n_tokens`` positions
        (state arrays carry a batch dim of 1, dropped in the payload).

        Under a ``+delta`` codec, a dense-family block that extends a
        chain (``past_len > 0`` with ``prev_hash``) serializes only its
        own ``[past_len:n_tokens]`` token slice behind a back-pointer --
        the O(1)-byte Set; everything else stays cumulative."""
        delta = (self.codec.delta and self._delta_ok
                 and past_len > 0 and prev_hash is not None)
        lo = past_len if delta else 0
        arrs: list[np.ndarray] = []
        if "ssm" in state:
            arrs.append(np.asarray(state["ssm"]["conv"][:, 0]))
            arrs.append(np.asarray(state["ssm"]["state"][:, 0]))
        if "mla" in state:
            arrs.append(np.asarray(state["mla"]["ckv"][:, 0, :n_tokens]))
            arrs.append(np.asarray(state["mla"]["kr"][:, 0, :n_tokens]))
        if "kv" in state:
            arrs.append(np.asarray(state["kv"]["k"][:, 0, lo:n_tokens]))
            arrs.append(np.asarray(state["kv"]["v"][:, 0, lo:n_tokens]))
        inner = self.codec.encode(arrs)
        if delta:
            return make_delta_payload(inner, prev_hash, past_len)
        return inner

    def payload_to_state(self, payload: bytes) -> dict:
        cfg = self.cfg
        arrs = decode_payload_arrays(payload)
        state: dict = {}
        i = 0
        if cfg.arch_type in ("ssm", "hybrid"):
            state["ssm"] = {
                "conv": jnp.asarray(arrs[i])[:, None],
                "state": jnp.asarray(arrs[i + 1])[:, None],
            }
            i += 2
        if cfg.use_mla:
            state["mla"] = {
                "ckv": jnp.asarray(arrs[i])[:, None],
                "kr": jnp.asarray(arrs[i + 1])[:, None],
            }
            i += 2
        if i < len(arrs):
            state["kv"] = {
                "k": jnp.asarray(arrs[i])[:, None],
                "v": jnp.asarray(arrs[i + 1])[:, None],
            }
        return state

    def payload_to_pages(self, payload: bytes, n_tokens: int,
                         page_size: int):
        """Dense-family payload -> page-shaped K/V blocks, ready to drop
        straight into a ``PagedKVCache`` pool (no dense restacking).

        Returns ``(k_blocks, v_blocks)`` of shape
        ``[layers, n_tokens/page, page, Hkv, hd]``.  ``n_tokens`` must be
        page-aligned -- SkyMemory prefixes always are, because the engine's
        page size equals the constellation block size.
        """
        cfg = self.cfg
        if cfg.use_mla or cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(f"{cfg.name}: payload is not plain paged K/V")
        if n_tokens % page_size:
            raise ValueError("cached prefix must be page-aligned")
        arrs = decode_payload_arrays(payload)
        k, v = arrs[0], arrs[1]                      # [L, n_cov, Hkv, hd]
        la, _, hkv, hd = k.shape
        nb = n_tokens // page_size
        shape = (la, nb, page_size, hkv, hd)
        return (
            jnp.asarray(k[:, :n_tokens]).reshape(shape),
            jnp.asarray(v[:, :n_tokens]).reshape(shape),
        )

    def pages_to_payload(self, k_blocks, v_blocks, n_tokens: int, *,
                         tokens: "Sequence[int] | None" = None) -> bytes:
        """Inverse of ``payload_to_pages``: page-shaped K/V blocks
        (``[layers, n_pages, page, Hkv, hd]``, e.g. a preempted sequence's
        exported pool pages) -> a dense-family KVC payload covering the
        first ``n_tokens`` positions.

        This is how the swap tier writes the constellation without model
        recompute: the pool pages already hold the exact K/V, so the
        payload is a reshape + codec encode.  Under the f32 codec (and
        for integer pools under any codec -- quantized codes are stored
        verbatim, so int8 pools stay int8) a later ``payload_to_pages``
        round trip returns the identical arrays.

        Under a ``+delta`` codec the caller passes the entry's
        ``tokens`` so the back-pointer hash of the preceding block can
        be recomputed from the chain: the payload for a block past the
        first then carries only its own token slice."""
        k = np.asarray(k_blocks)
        v = np.asarray(v_blocks)
        la, nb, page, hkv, hd = k.shape
        if n_tokens > nb * page:
            raise ValueError("n_tokens exceeds the exported pages")
        flat = (la, nb * page, hkv, hd)
        bt = self.codec.block_tokens
        lo = 0
        prev_hash = None
        if (self.codec.delta and self._delta_ok and tokens is not None
                and n_tokens > bt):
            lo = n_tokens - bt
            prev_hash = chain_hashes(list(tokens[:lo]), bt)[-1]
        inner = self.codec.encode([
            np.ascontiguousarray(k.reshape(flat)[:, lo:n_tokens]),
            np.ascontiguousarray(v.reshape(flat)[:, lo:n_tokens]),
        ])
        if prev_hash is not None:
            return make_delta_payload(inner, prev_hash, lo)
        return inner

    def pages_async(self, payload: bytes, n_tokens: int, page_size: int):
        """Fetch-ahead hook: decode a constellation payload into
        page-shaped K/V on a worker thread, returning a Future.

        The byte -> array deserialization is pure host work; submitting it
        here lets the engine keep its in-flight decode step (device
        compute) running while the payload decodes, instead of stalling
        the serving loop -- the communication/compute overlap the chunked
        scheduler exploits for the first fresh chunk after a SkyMemory
        hit.  ``.result()`` gives the same ``(k_blocks, v_blocks)`` as
        ``payload_to_pages``.
        """
        return self.run_async(
            self.payload_to_pages, payload, n_tokens, page_size)

    def run_async(self, fn, *args):
        """Run ``fn(*args)`` on the adapter's single worker thread.

        One worker serializes everything submitted here (payload decodes,
        Set KVC write-backs), so protocol-ordering guarantees -- a
        write-back lands before the next lookup that should hit it --
        survive the move off the engine's decode loop."""
        if self._executor is None:
            from concurrent.futures import ThreadPoolExecutor

            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="skymem-fetch")
        return self._executor.submit(fn, *args)

    # -- the KVCManager hook ----------------------------------------------
    def kvc_fn(self, tokens: Sequence[int], past: bytes | None,
               past_len: int) -> bytes:
        """Payload for the block ending at len(tokens), resuming from
        ``past`` (a payload -- possibly a reassembled cat container --
        covering the first ``past_len`` tokens).  Under a ``+delta``
        codec the emitted payload carries only the new tokens plus a
        back-pointer recomputed from the token chain."""
        toks = jnp.asarray(list(tokens), jnp.int32)[None]
        if past is None or past_len == 0:
            past_len = 0
            _, _, state = self.model.forward(
                self.params, toks, collect_state=True
            )
        else:
            prefix = self.payload_to_state(past)
            _, _, state = self.model.forward(
                self.params, toks[:, past_len:],
                q_offset=past_len, prefix_state=prefix, collect_state=True,
            )
            state = _concat_prefix(self.cfg, prefix, state, past_len)
        prev_hash = None
        if self.codec.delta and self._delta_ok and past_len > 0:
            prev_hash = chain_hashes(
                list(tokens[:past_len]), self.codec.block_tokens)[-1]
        return self.state_to_payload(state, len(tokens),
                                     past_len=past_len, prev_hash=prev_hash)


def _concat_prefix(cfg, prefix: dict, state: dict, past_len: int) -> dict:
    """Stitch prefix K/V back in front of the freshly-computed suffix state.

    For dense families ``forward`` already returns K/V including the prefix
    (the prefix K/V were concatenated inside attention); for SSM the state
    is cumulative by construction; so this is only needed for hybrids' KV
    when the attention path did not include the prefix -- handled uniformly
    by checking lengths.
    """
    out = dict(state)
    if "kv" in state and "kv" in prefix:
        k = state["kv"]["k"]
        if k.shape[2] < past_len:  # suffix-only: prepend prefix
            out["kv"] = {
                "k": jnp.concatenate([prefix["kv"]["k"], k], axis=2),
                "v": jnp.concatenate([prefix["kv"]["v"], state["kv"]["v"]],
                                     axis=2),
            }
    return out
