"""Scale-out serving: N Engine replicas over ONE shared constellation.

``EngineCluster`` is the paper's "Scale Out" axis made concrete:

* one ``ConstellationKVC`` -- the orbital cache, its satellite stores,
  block directory and eviction policy -- shared by every replica;
* N ``Engine`` replicas, each *anchored* at a different satellite
  through ``ConstellationKVC.view`` (per-replica hop costs + transport
  stats on the fabric's ``SimClock``) and bound to the shared §3.10
  radix index through ``KVCManager.sibling`` (one prefix index, N entry
  points, one lock);
* a router (``serving.router``) in front: requests are scored per
  replica by prefix affinity, anchor-to-home-satellite hop latency, and
  load before any engine sees them.

Two serving surfaces share the machinery:

* ``serve`` -- the closed batch: routes a fixed request list up front,
  runs each replica's share on its own thread (replicas really do
  compute concurrently -- the shared fabric is lock-protected, and the
  ``SimClock`` makes every replica *experience* its anchor's fetch
  latency), and returns results in request order.
* ``submit`` / ``serve_stream`` -- the streaming tier: each request is
  routed at its *arrival time* on the fabric clock, handed to a
  long-lived engine worker loop, and its router load released the
  moment it finishes (per-request release -- the load tie-break
  compares true in-flight work).  ``serve_stream`` drives a seeded
  arrival stream (``serving.traffic``) through per-tenant SLO
  accounting and overload shedding (``serving.slo``), returning a
  ``StreamReport`` with goodput, attainment, and tail-ITL counters.

``rotate_every_s`` starts an orbital ticker for the rotation-during-
serving scenario: the constellation rotates on the same clock while
requests are in flight, migrating chunks and shifting prefix affinity
under the live cluster (deterministic streaming runs rotate on virtual
arrival-time crossings instead of a wall-clock thread).

Cluster-level reporting: ``merged_stats`` folds per-replica
``EngineStats`` (true cluster percentiles, not averaged ones), and
``fabric_stats`` aggregates per-view constellation hit/miss counters and
transport latency percentiles next to them.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Iterable, Sequence

from repro.core.chunking import PayloadCodec
from repro.core.constellation import Sat
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.protocol import (
    CacheStats,
    ConstellationKVC,
    GroundStats,
    KVCManager,
    SimClock,
    TransportStats,
)
from repro.models.model import Model
from repro.serving.engine import Engine
from repro.serving.request import GenerationResult, Request
from repro.serving.router import (
    ReplicaHandle,
    RouteDecision,
    Router,
    make_router,
)
from repro.serving.skycache import SkyKVCAdapter
from repro.serving.slo import (
    SLO,
    AdmissionController,
    FaultPhases,
    SLOTracker,
)
from repro.serving.stats import EngineStats
from repro.serving.tokenizer import ByteTokenizer, truncate_prompt
from repro.serving.traffic import Arrival


@dataclass
class StreamRecord:
    """One arrival's fate on the streaming path."""

    arrival: Arrival
    shed: bool = False
    decision: RouteDecision | None = None
    future: Future | None = None
    result: GenerationResult | None = None
    attained: bool = False


@dataclass
class StreamReport:
    """What ``serve_stream`` hands back: per-arrival records plus the
    SLO tracker's goodput/attainment counter block.  ``faults`` (only
    populated when a fault arc ran) holds the stream's OWN fault
    counters: fabric degradation deltas (``degraded_reads``,
    ``degraded_lookups``, ``ground_hits``, ``lost_blocks``,
    ``repaired_*``, ...) plus the injector's applied-event tallies --
    deltas over the stream, so a faulted warmup can't leak in."""

    records: list[StreamRecord] = field(default_factory=list)
    elapsed_s: float = 0.0
    slo: dict = field(default_factory=dict)
    rotations: int = 0
    faults: dict = field(default_factory=dict)

    def results(self) -> list[GenerationResult]:
        return [r.result for r in self.records if r.result is not None]

    def shed(self) -> list[StreamRecord]:
        return [r for r in self.records if r.shed]


def _raise_aggregated(errors: list[tuple[str, BaseException]]) -> None:
    """Surface EVERY failure, not just the first: a lone exception
    re-raises as itself; several aggregate into one RuntimeError whose
    message lists each (ExceptionGroup-style), chained to the first."""
    if not errors:
        return
    if len(errors) == 1:
        raise errors[0][1]
    msg = "; ".join(f"{label}: {type(e).__name__}: {e}"
                    for label, e in errors)
    raise RuntimeError(
        f"{len(errors)} replica failures: {msg}") from errors[0][1]


def spread_anchors(kvc: ConstellationKVC, n: int) -> list[Sat]:
    """Evenly spaced anchor satellites over the LOS window (row-major):
    replicas attach across the window instead of piling on the center,
    so their hop costs to the chunk servers genuinely differ."""
    sats = kvc.window.sats(kvc.spec)
    return [sats[(i * len(sats)) // n] for i in range(n)]


class EngineCluster:
    """Router -> N Engine replicas -> one shared constellation fabric."""

    def __init__(
        self,
        model: Model,
        params,
        kvc: ConstellationKVC,
        *,
        num_replicas: int = 2,
        anchors: Sequence[Sat] | None = None,
        policy: str = "prefix_affinity",
        router: Router | None = None,
        router_seed: int = 0,
        clock: SimClock | None = None,
        rotate_every_s: float | None = None,
        block_size: int = 128,
        max_seq_len: int = 512,
        max_batch: int = 8,
        seed: int = 0,
        payload_codec: "PayloadCodec | str | None" = None,
        **engine_kwargs,
    ) -> None:
        if anchors is not None:
            num_replicas = len(anchors)
        if num_replicas < 1:
            raise ValueError("cluster needs at least one replica")
        self.kvc = kvc
        self.clock = clock if clock is not None else kvc.transport.clock
        self.max_seq_len = max_seq_len
        self.rotate_every_s = rotate_every_s
        self.rotations = 0
        self.tokenizer = ByteTokenizer(model.cfg.vocab_size)
        # one codec for the whole cluster: the shared kvc_fn, every
        # replica's adapter, and the router's size model must agree on
        # what bytes a block payload is
        codec = PayloadCodec.parse(payload_codec, block_size)
        adapter = SkyKVCAdapter(model, params, codec=codec)
        # the shared fabric handle: one radix index + recency policy +
        # lock, adopted by the base store and every sibling below
        self.manager = KVCManager(
            self.tokenizer.encode, adapter.kvc_fn, kvc,
            block_size=block_size,
        )
        self.anchors = list(
            anchors if anchors is not None
            else spread_anchors(kvc, num_replicas))
        self.views = [kvc.view(a, clock=self.clock) for a in self.anchors]
        self.engines = [
            Engine(model, params, manager=self.manager.sibling(view),
                   block_size=block_size, max_seq_len=max_seq_len,
                   max_batch=max_batch, seed=seed + i,
                   payload_codec=codec, **engine_kwargs)
            for i, view in enumerate(self.views)
        ]
        self.handles = [ReplicaHandle(i, view)
                        for i, view in enumerate(self.views)]
        self.router = router if router is not None else make_router(
            policy, self.handles, manager=self.manager, seed=router_seed,
            bytes_per_token=adapter.payload_bytes_per_token(),
            delta_payloads=codec.delta)
        self.decisions: list[RouteDecision] = []   # last serve's verdicts

    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    # ------------------------------------------------------------------
    def serve(self, requests: list[Request], *,
              parallel: bool = True) -> list[GenerationResult]:
        """Route the stream, run every replica's share, and return
        results in request order.  ``parallel=False`` runs replicas
        sequentially (deterministic -- the test mode)."""
        if not requests:
            return []
        self.decisions = []
        buckets: dict[int, list[tuple[int, Request]]] = {}
        for i, req in enumerate(requests):
            # route on the exact tokens the engine will serve (same
            # truncation rule as the schedulers), so the router's
            # affinity memory matches what gets cached
            toks = truncate_prompt(self.tokenizer.encode(req.prompt),
                                   self.max_seq_len)
            d = self.router.route(
                toks, est_new_tokens=req.sampling.max_new_tokens)
            self.decisions.append(d)
            buckets.setdefault(d.replica, []).append((i, req))

        results: list[GenerationResult | None] = [None] * len(requests)
        errors: list[tuple[str, BaseException]] = []

        def run_replica(ridx: int, items: list[tuple[int, Request]]) -> None:
            try:
                out = self.engines[ridx].generate([r for _, r in items])
                for (i, _), res in zip(items, out):
                    results[i] = res
            except BaseException as e:  # surfaced after join
                errors.append((f"replica {ridx}", e))

        ticker = self._start_rotation_ticker()
        try:
            if parallel and len(buckets) > 1:
                threads = [
                    threading.Thread(target=run_replica, args=(r, items),
                                     name=f"replica-{r}")
                    for r, items in buckets.items()
                ]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            else:
                for r, items in sorted(buckets.items()):
                    run_replica(r, items)
        finally:
            if ticker is not None:
                ticker()
            # the batch is over (finished or failed): return its tokens
            # to the load accounting so the tie-break on later serves
            # compares in-flight work, not all-time totals
            for d in self.decisions:
                self.router.release(d.replica, d.committed_tokens)
        _raise_aggregated(errors)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # streaming: per-request routing over long-lived engine workers
    # ------------------------------------------------------------------
    def start_workers(self) -> None:
        """Start every replica's long-lived worker loop (idempotent)."""
        for e in self.engines:
            e.start()

    def stop_workers(self, *, drain: bool = True) -> None:
        """Stop every replica's worker loop; ``drain=True`` finishes the
        backlog first."""
        errors: list[tuple[str, BaseException]] = []
        for i, e in enumerate(self.engines):
            try:
                e.stop(drain=drain)
            except BaseException as exc:
                errors.append((f"replica {i}", exc))
        _raise_aggregated(errors)

    def submit(self, request: Request, *,
               release: bool = True) -> tuple[Future, RouteDecision]:
        """Route ONE request now -- at its arrival, not as part of a
        batch -- and hand it to the winning replica's stream.  With
        ``release=True`` the router's committed tokens come back the
        moment this request finishes (per-request release: the load
        tie-break compares true in-flight work); ``release=False`` leaves
        them to the caller (the end-of-run baseline)."""
        toks = truncate_prompt(self.tokenizer.encode(request.prompt),
                               self.max_seq_len)
        d = self.router.route(
            toks, est_new_tokens=request.sampling.max_new_tokens)
        self.decisions.append(d)
        fut = self.engines[d.replica].submit(request)
        if release:
            fut.add_done_callback(
                lambda _f, d=d: self.router.release(d.replica,
                                                    d.committed_tokens))
        return fut, d

    # fabric counters whose stream-wide deltas a fault arc's report
    # carries: the degradation a request stream actually experienced
    _FAULT_STAT_KEYS = (
        "degraded_reads", "degraded_lookups", "ground_hits",
        "lost_blocks", "repaired_chunks", "repaired_from_ground",
        "dir_repaired_entries", "detoured_ops", "orphaned_chunks",
        "shortened_prefixes",
    )

    def serve_stream(
        self,
        arrivals: Iterable[Arrival],
        *,
        parallel: bool = True,
        slos: dict[str, SLO] | None = None,
        default_slo: SLO | None = None,
        admission: AdmissionController | None = None,
        release_mode: str = "per_request",
        pump_steps_per_s: float = 200.0,
        faults: "FaultPlan | FaultInjector | None" = None,
        slo_window_s: float | None = None,
    ) -> StreamReport:
        """Serve an open arrival stream: route each request at its
        arrival time, shed under overload, and account goodput.

        ``parallel=True`` is the realtime mode: every replica runs its
        worker loop and the front door paces wall time to each arrival's
        virtual time by the fabric clock rate.  ``parallel=False`` is
        the deterministic mode: no threads -- elapsed virtual time buys
        ``pump`` rounds round-robined over the replicas (fractional
        budget carried across gaps) and rotation ticks on virtual
        arrival-time crossings, so the full interleave (and with greedy
        sampling, every output byte) is a pure function of the arrival
        stream.

        ``faults`` composes a chaos arc with the stream: a ``FaultPlan``
        (wrapped in a repairing injector here) or a prebuilt
        ``FaultInjector``, (re)armed at stream start so event times are
        relative to t=0 of the arrival timeline.  In realtime mode the
        injector advances on the fabric clock from inside chunk ops, as
        always; in deterministic mode it is *held* and driven on
        virtual-time crossings interleaved with rotation -- with
        ``reconcile()`` fired on satellite-heal crossings -- so a seeded
        kill->degrade->heal->repair arc replays byte-identically.  The
        report's ``faults`` block carries the stream's degradation
        deltas and the injector's event tallies.

        ``slo_window_s`` turns on the tracker's windowed goodput
        timeline (fixed virtual-time windows keyed by arrival ``t_s``,
        tagged pre_churn/churn/post_heal from the fault plan's
        ``churn_span``).

        ``release_mode``: ``"per_request"`` returns each request's
        committed tokens to the router when it finishes;
        ``"end_of_run"`` holds them to the end (the closed-batch-style
        baseline the benchmark compares against).
        """
        if release_mode not in ("per_request", "end_of_run"):
            raise ValueError(f"unknown release_mode: {release_mode!r}")
        per_request = release_mode == "per_request"
        injector: FaultInjector | None = None
        if isinstance(faults, FaultPlan):
            injector = FaultInjector(self.kvc, faults,
                                     repair_on_heal=True)
        elif faults is not None:
            injector = faults
        phases = None
        if injector is not None:
            span = injector.plan.churn_span
            if span is not None:
                phases = FaultPhases(*span)
        tracker = SLOTracker(slos, default=default_slo,
                             window_s=slo_window_s, phases=phases)
        records: list[StreamRecord] = []
        deferred: list[RouteDecision] = []
        self.decisions = []
        rate = self.clock.rate if self.clock is not None else 1.0
        stats_before = None
        if injector is not None:
            fabric = self.fabric_stats()
            stats_before = {k: fabric[k] for k in self._FAULT_STAT_KEYS}
            inj_before = dataclasses.asdict(injector.stats)

        def admit_and_submit(arr: Arrival) -> None:
            tracker.note_offered(arr.tenant, t_s=arr.t_s)
            if admission is not None and not admission.admit(
                    arr.request.priority, self.router.total_load()):
                tracker.note_shed(arr.tenant, t_s=arr.t_s)
                records.append(StreamRecord(arrival=arr, shed=True))
                return
            fut, d = self.submit(arr.request, release=per_request)
            if not per_request:
                deferred.append(d)
            records.append(StreamRecord(arrival=arr, decision=d,
                                        future=fut))

        t0 = time.perf_counter()
        try:
            if parallel:
                if injector is not None:
                    injector.arm()      # event times relative to now
                ticker = self._start_rotation_ticker()
                self.start_workers()
                try:
                    for arr in arrivals:
                        # pace wall time to the arrival's virtual time
                        # (direct sleep, not SimClock.wait_until: front-
                        # door pacing must not pollute transport wait
                        # accounting)
                        dt = arr.t_s / rate - (time.perf_counter() - t0)
                        if dt > 0:
                            time.sleep(dt)
                        admit_and_submit(arr)
                finally:
                    self.stop_workers(drain=True)
                    if ticker is not None:
                        ticker()
            else:
                if injector is not None:
                    injector.hold()     # crossings drive it, not the clock
                    injector.arm()
                self._serve_stream_deterministic(
                    arrivals, admit_and_submit, pump_steps_per_s,
                    injector=injector)
        finally:
            for d in deferred:     # end-of-run release (the baseline)
                self.router.release(d.replica, d.committed_tokens)
        elapsed = time.perf_counter() - t0

        errors: list[tuple[str, BaseException]] = []
        for rec in records:
            if rec.future is None:
                continue
            err = rec.future.exception()
            if err is not None:
                errors.append(
                    (f"request {rec.arrival.request.request_id}", err))
                continue
            rec.result = rec.future.result()
            rec.attained = tracker.observe(
                rec.arrival.tenant,
                ttft_s=rec.result.ttft_s,
                itl_samples_s=rec.result.itl_samples_s,
                new_tokens=len(rec.result.token_ids),
                t_s=rec.arrival.t_s)
        _raise_aggregated(errors)
        fault_block: dict = {}
        if injector is not None:
            fabric = self.fabric_stats()
            fault_block = {k: fabric[k] - stats_before[k]
                           for k in self._FAULT_STAT_KEYS}
            for k, v in dataclasses.asdict(injector.stats).items():
                fault_block[k] = v - inj_before[k]
        return StreamReport(records=records, elapsed_s=elapsed,
                            slo=tracker.report(elapsed),
                            rotations=self.rotations,
                            faults=fault_block)

    def _serve_stream_deterministic(self, arrivals, admit_and_submit,
                                    pump_steps_per_s: float,
                                    injector: FaultInjector | None = None,
                                    ) -> None:
        """The threadless interleave: walk the virtual timeline arrival
        by arrival, crossing every rotation tick AND fault event that
        falls in the gap in time order (each under the manager lock,
        with the pump budget up to the crossing spent first, so the
        fabric state a crossing mutates is exactly what a realtime run
        would have served by then), settle write-backs (so the shared
        index -- and with it every routing signal -- is in a
        schedule-independent state), then submit.

        The pump budget is an *accumulator*: elapsed virtual time times
        ``pump_steps_per_s``, spending whole rounds and carrying the
        fractional remainder across gaps -- service rate is a function
        of elapsed virtual time, never of how finely the arrival stream
        slices it.  A satellite-heal crossing triggers ``reconcile()``
        (via the injector's ``repair_on_heal``, or directly here when
        the caller's injector doesn't repair), so kill->degrade->heal->
        repair arcs replay byte-identically."""
        acc = 0.0
        prev_t = 0.0
        next_rot = self.rotate_every_s or math.inf

        def spend_until(t: float) -> None:
            nonlocal acc, prev_t
            acc += (t - prev_t) * pump_steps_per_s
            prev_t = t
            rounds = int(acc)
            acc -= rounds
            for _ in range(rounds):
                if not self._pump_all():
                    break       # idle rounds don't bank service

        def cross_until(t: float) -> None:
            nonlocal next_rot
            while True:
                ev_t = math.inf
                if injector is not None:
                    nxt = injector.next_event_at_s
                    if nxt is not None:
                        ev_t = nxt
                cross = min(next_rot, ev_t)
                if cross > t:
                    break
                spend_until(cross)
                # settle async write-backs BEFORE the crossing mutates
                # the fabric: whether a background write has landed by
                # now is thread-schedule noise, and a kill must drop a
                # schedule-independent store (same chunks_dropped every
                # replay), just as a rotation must migrate one
                self._settle_write_backs()
                if next_rot <= ev_t:
                    with self.manager.lock:
                        self.kvc.rotate(1)
                        self.rotations += 1
                    next_rot += self.rotate_every_s
                else:
                    with self.manager.lock:
                        heals = injector.stats.sat_heals
                        injector.advance_to(ev_t)
                        if (injector.stats.sat_heals > heals
                                and not injector.repair_on_heal):
                            self.kvc.reconcile()
            spend_until(t)

        for arr in arrivals:
            cross_until(arr.t_s)
            self._settle_write_backs()
            admit_and_submit(arr)
        while self._pump_all():
            pass
        self._settle_write_backs()

    def _pump_all(self) -> bool:
        busy = False
        for e in self.engines:
            busy |= e.pump()
        return busy

    def _settle_write_backs(self) -> None:
        for e in self.engines:
            if e.paged:
                e.kv.drain_write_back()

    def _start_rotation_ticker(self):
        """Orbital rotation on the serving clock: while requests are in
        flight the LOS window keeps drifting, chunks migrate, and prefix
        affinity shifts.  Returns a stop() callable (None if disabled)."""
        if not self.rotate_every_s:
            return None
        rate = self.clock.rate if self.clock is not None else 1.0
        stop = threading.Event()

        def tick() -> None:
            # deadline-based, not sleep-after-work: each rotation's wall
            # deadline advances by exactly one period regardless of how
            # long the rotate (or the wait for the manager lock) took,
            # so the realized period never drifts under load and a slow
            # tick catches up instead of rescheduling everything after
            # it.  This keeps the realtime rotation count aligned with
            # the deterministic mode's virtual-time crossings.
            period = self.rotate_every_s / rate
            next_deadline = time.perf_counter() + period
            while not stop.wait(max(0.0, next_deadline
                                    - time.perf_counter())):
                with self.manager.lock:
                    self.kvc.rotate(1)
                    self.rotations += 1
                next_deadline += period

        thread = threading.Thread(target=tick, name="orbital-rotation",
                                  daemon=True)
        thread.start()

        def stopper() -> None:
            stop.set()
            thread.join()

        return stopper

    # ------------------------------------------------------------------
    # cluster-level stats
    # ------------------------------------------------------------------
    def merged_stats(self) -> EngineStats:
        """One cluster-level EngineStats: counters summed, TTFT/ITL
        sample lists concatenated (percentiles over the union)."""
        return EngineStats.merged(e.stats for e in self.engines)

    def replica_stats(self) -> list[dict]:
        """Per-replica serving + constellation view of the last runs."""
        out = []
        for i, (eng, view) in enumerate(zip(self.engines, self.views)):
            s = eng.stats
            out.append({
                "replica": i,
                "anchor": (view.anchor.plane, view.anchor.slot),
                "requests": s.requests,
                "cached_tokens": s.cached_tokens,
                "prefilled_tokens": s.prefilled_tokens,
                "decoded_tokens": s.decoded_tokens,
                "l2_wait_s": s.l2_wait_s,
                "latency_percentiles": s.latency_percentiles(),
                "constellation": dataclasses.asdict(view.stats),
                "transport_latency_s":
                    view.transport.stats.latency_percentiles(),
            })
        return out

    def fabric_stats(self) -> dict:
        """Shared-fabric aggregates: view cache stats folded together,
        transport percentiles over every replica's ops, hit rates."""
        cache = CacheStats()
        for view in self.views:
            for f in dataclasses.fields(CacheStats):
                setattr(cache, f.name,
                        getattr(cache, f.name) + getattr(view.stats, f.name))
        merged = self.merged_stats()
        prefix_total = merged.cached_tokens + merged.prefilled_tokens
        # ops-weighted merge of the per-view latency reservoirs: each
        # view's reservoir stands for that view's TOTAL op count, so draw
        # quantile-spaced picks proportional to ops (concatenating raw
        # reservoirs would overweight idle anchors once any busy view's
        # reservoir saturates); the percentile rule itself is
        # TransportStats' -- one implementation, not a copy
        merged_t = TransportStats()
        total_ops = sum(v.transport.stats.ops for v in self.views)
        for view in self.views:
            st = view.transport.stats
            xs = sorted(st.op_latencies_s)
            if not xs or not total_ops:
                continue
            k = max(1, round(st.reservoir_size * st.ops / total_ops))
            if k == 1:
                merged_t.op_latencies_s.append(xs[len(xs) // 2])
            else:
                merged_t.op_latencies_s.extend(
                    xs[round(j * (len(xs) - 1) / (k - 1))]
                    for j in range(k))
        # fault counters fold in the BASE store's too: repair passes and
        # purge-at-loss run through the base, not any replica's view
        base = self.kvc.stats
        return {
            "block_hits": cache.block_hits,
            "block_misses": cache.block_misses,
            "blocks_set": cache.blocks_set,
            "block_hit_rate": cache.block_hits / max(
                cache.block_hits + cache.block_misses, 1),
            "prefix_hit_rate": merged.cached_tokens / max(prefix_total, 1),
            "rotations": self.rotations,
            "transport_latency_s": merged_t.latency_percentiles(),
            "l2_wait_s": merged.l2_wait_s,
            "l2_fetch_waits": merged.l2_fetch_waits,
            "degraded_reads": cache.degraded_reads + base.degraded_reads,
            "lost_blocks": cache.lost_blocks + base.lost_blocks,
            "repaired_chunks": cache.repaired_chunks + base.repaired_chunks,
            # graceful degradation: detours instead of failed ops, the
            # ground tier instead of losses (repair passes credit the
            # base store, data-plane fall-throughs the serving views)
            "detoured_ops": cache.detoured_ops + base.detoured_ops,
            "detour_hops": cache.detour_hops + base.detour_hops,
            "ground_hits": cache.ground_hits + base.ground_hits,
            "repaired_from_ground": (cache.repaired_from_ground
                                     + base.repaired_from_ground),
            # decentralized directory: priced metadata lookups, stripe
            # fall-throughs, reconcile's metadata rebuilds and orphan
            # sweeps, and prefixes the fabric served shorter than the
            # index promised (reconcile runs through the base; lookups
            # through the serving views)
            "dir_lookups": cache.dir_lookups + base.dir_lookups,
            "degraded_lookups": (cache.degraded_lookups
                                 + base.degraded_lookups),
            "dir_repaired_entries": (cache.dir_repaired_entries
                                     + base.dir_repaired_entries),
            "orphaned_chunks": cache.orphaned_chunks + base.orphaned_chunks,
            "shortened_prefixes": (cache.shortened_prefixes
                                   + base.shortened_prefixes),
            # payload codec: block bytes the fabric actually shipped vs
            # what they decode to (Set + served Get), and the dequantize
            # time hidden on the fetch-ahead worker
            "bytes_encoded": cache.bytes_encoded + base.bytes_encoded,
            "bytes_raw": cache.bytes_raw + base.bytes_raw,
            "compression_ratio": (
                (cache.bytes_raw + base.bytes_raw)
                / max(cache.bytes_encoded + base.bytes_encoded, 1)),
            "dequant_overlap_s": merged.dequant_overlap_s,
        }

    def reset_stats(self) -> None:
        """Fresh per-replica EngineStats + view cache/transport stats,
        the BASE store's CacheStats (fabric_stats folds its fault
        counters -- repair passes and loss purges land there, and a
        faulted warmup must not inflate the measured run), and router
        assignment state (benchmarks call this between the warmup and
        the timed run)."""
        for eng in self.engines:
            eng.stats = EngineStats()
        for view in self.views:
            view.stats = CacheStats()
            view.transport.stats = TransportStats()
        self.kvc.stats = CacheStats()
        if self.kvc.ground is not None:
            self.kvc.ground.stats = GroundStats()
        self.router.reset()
        self.rotations = 0
