"""Byte-level tokenizer (self-contained; no external vocab files).

Token ids: 0=pad, 1=bos, 2=eos, 3..258 = raw bytes.  Vocabularies smaller
than 259 wrap bytes modulo the available range (used only by reduced smoke
configs); larger vocabularies simply leave the tail unused -- the cache
protocol and engine only need a deterministic, prefix-stable mapping.
"""
from __future__ import annotations

from dataclasses import dataclass

PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
_OFFSET = 3

# decode headroom reserved past the prompt when admitting a request
PROMPT_HEADROOM = 64


def truncate_prompt(tokens: list[int], max_seq_len: int) -> list[int]:
    """THE prompt-truncation rule, shared by the schedulers (paged and
    dense) and the cluster router: the router must hash exactly the
    token prefix the engine will serve and cache, or affinity memory
    keys on the wrong block chain."""
    return tokens[: max_seq_len - PROMPT_HEADROOM]


@dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int
    add_bos: bool = True

    def encode(self, text: str) -> list[int]:
        span = max(self.vocab_size - _OFFSET, 1)
        ids = [_OFFSET + (b % span) for b in text.encode("utf-8")]
        return ([BOS_ID] if self.add_bos else []) + ids

    def decode(self, ids: list[int]) -> str:
        bs = bytes(
            (i - _OFFSET) % 256 for i in ids if i >= _OFFSET
        )
        return bs.decode("utf-8", errors="replace")

    @property
    def eos_id(self) -> int:
        return EOS_ID
