"""Byte-level tokenizer (self-contained; no external vocab files).

Token ids: 0=pad, 1=bos, 2=eos, 3..258 = raw bytes.  Vocabularies smaller
than 259 wrap bytes modulo the available range (used only by reduced smoke
configs); larger vocabularies simply leave the tail unused -- the cache
protocol and engine only need a deterministic, prefix-stable mapping.
"""
from __future__ import annotations

from dataclasses import dataclass

PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
_OFFSET = 3


@dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int
    add_bos: bool = True

    def encode(self, text: str) -> list[int]:
        span = max(self.vocab_size - _OFFSET, 1)
        ids = [_OFFSET + (b % span) for b in text.encode("utf-8")]
        return ([BOS_ID] if self.add_bos else []) + ids

    def decode(self, ids: list[int]) -> str:
        bs = bytes(
            (i - _OFFSET) % 256 for i in ids if i >= _OFFSET
        )
        return bs.decode("utf-8", errors="replace")

    @property
    def eos_id(self) -> int:
        return EOS_ID
