from repro.distributed.sharding import (
    AxisRules,
    active_rules,
    batch_spec,
    cache_specs,
    maybe_shard,
    param_specs,
    use_rules,
)

__all__ = [
    "AxisRules",
    "active_rules",
    "batch_spec",
    "cache_specs",
    "maybe_shard",
    "param_specs",
    "use_rules",
]
