"""Sharding rules: logical activation/parameter axes -> mesh axes.

Mesh layout (DESIGN.md §5): ``(data, model)`` single-pod or
``(pod, data, model)`` multi-pod.  Batch rides (pod, data); heads / ffn /
experts / vocab ride model; for batch-1 long-context decode the KV-cache
*sequence* dim rides data (the paper's chunk striping, chip-scale).

Parameter specs are derived from leaf path names with divisibility
fallbacks (a dim that does not divide its mesh axes is replicated).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AxisRules:
    mesh: Mesh
    data_axes: tuple[str, ...] = ("data",)      # ("pod", "data") multi-pod
    model_axis: str = "model"
    # beyond-paper levers (hillclimbing):
    shard_kv_heads: bool = True                  # False -> replicate K/V proj
    seq_shard_cache: bool = False                # long_500k context sharding
    fsdp: bool = True                            # shard params over data too
    attn_tp: bool = True                         # False: seq-parallel decode
                                                 # (attention weights keep all
                                                 # heads local; cache seq dim
                                                 # is striped instead)
    seq_parallel_acts: bool = False              # Megatron-SP: residual-
                                                 # stream activations sharded
                                                 # over (data, model)

    @property
    def data(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


_local = threading.local()


def active_rules() -> AxisRules | None:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules | None):
    prev = active_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


# ---------------------------------------------------------------------------
# Activation constraints (called from model code; no-op without rules).
# ---------------------------------------------------------------------------

_LOGICAL_ACT = {
    # (batch, seq, d_model)
    "act_btd": lambda r: P(
        r.data, r.model_axis if r.seq_parallel_acts else None, None),
    # (batch, seq, hidden/heads*hd) - model-parallel feature dim
    "act_btf": lambda r: P(r.data, None, r.model_axis),
    # logits (batch, seq, vocab)
    "logits": lambda r: P(r.data, None, r.model_axis),
    # moe dispatch (groups, tokens, experts, capacity)
    "moe_dispatch": lambda r: P(r.data, None, r.model_axis, None),
    # per-expert activations (groups, experts, capacity, d)
    "moe_expert": lambda r: P(r.data, r.model_axis, None, None),
    # decode q/k/v right after projection [B, 1, H, hd]: replicate heads so
    # the (tiny) query is gathered instead of the (huge) model-striped cache
    "decode_qkv": lambda r: P(r.data, None, None, None),
}


def maybe_shard(x, logical: str):
    rules = active_rules()
    if rules is None:
        return x
    spec_fn = _LOGICAL_ACT.get(logical)
    if spec_fn is None:
        return x
    spec = spec_fn(rules)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    except ValueError:
        return x  # non-divisible shape: skip the constraint


# ---------------------------------------------------------------------------
# Parameter specs.
# ---------------------------------------------------------------------------

def _pad_left(spec: tuple, ndim: int) -> P:
    return P(*((None,) * (ndim - len(spec)) + spec))


def _fits(shape, spec: P, rules: AxisRules) -> bool:
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            continue
        if dim % rules.axis_size(axes) != 0:
            return False
    return True


def _rule_for(path: tuple[str, ...], ndim: int, rules: AxisRules) -> tuple:
    name = path[-1]
    in_moe = "moe" in path and "shared" not in path
    tp = rules.model_axis
    atp = tp if rules.attn_tp else None       # attention tensor parallelism
    dp = rules.data if rules.fsdp else None   # FSDP/ZeRO-3 second axis
    if in_moe and name in ("wi_gate", "wi_up", "wo"):
        return (tp, dp, None)              # (E, ., .) expert parallel + fsdp
    if name == "tok":
        return (tp, dp)                    # vocab-sharded embedding
    if name == "unembed":
        return (dp, tp)
    if name in ("wq", "wq_b"):
        return (dp, atp)
    if name in ("wi", "wi_gate", "wi_up", "wz", "wx", "wdt", "wb", "wc"):
        return (dp, tp)
    if name in ("wk", "wv"):
        return (dp, atp) if rules.shard_kv_heads else (dp, None)
    if name == "wo":
        return (atp, dp)
    if name == "out_proj":
        return (tp, dp)
    if name in ("w_uk", "w_uv"):
        return (atp, dp, None)             # heads
    if name in ("wkv_a", "wq_a"):
        return (dp, None)
    if name in ("conv_x_w",):
        return (None, tp)
    if name in ("conv_x_b", "norm_scale"):
        return (tp,)
    return ()                              # replicate


def param_specs(params, rules: AxisRules):
    """PartitionSpec tree for a parameter pytree (stacked layer dims are
    padded with None on the left; non-divisible dims fall back to None)."""

    def spec_of(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        base = _rule_for(names, leaf.ndim, rules)
        spec = _pad_left(base, leaf.ndim)
        if not _fits(leaf.shape, spec, rules):
            # drop axes that do not divide
            fixed = []
            for dim, axes in zip(leaf.shape, tuple(spec)):
                if axes is not None and dim % rules.axis_size(axes) == 0:
                    fixed.append(axes)
                else:
                    fixed.append(None)
            spec = P(*fixed)
        return spec

    return jax.tree_util.tree_map_with_path(spec_of, params)


def param_shardings(params, rules: AxisRules):
    specs = param_specs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Batch / cache specs.
# ---------------------------------------------------------------------------

def batch_spec(rules: AxisRules, *, batch_shardable: bool = True) -> P:
    return P(rules.data) if batch_shardable else P(None)


def cache_specs(cache, rules: AxisRules, *, batch: int):
    """Decode-cache specs: (layers, batch, seq, heads..., dim).

    The cache *sequence* dim is striped across chips -- the paper's chunk
    striping at ICI scale (DESIGN.md §2):

    * batch >= data-size: batch over data, sequence over model.  (KV-head
      counts rarely divide a 16-way model axis; striping the sequence gives
      the same 16x memory split and decode attention reduces over the
      sharded seq dim with a small psum -- flash-decoding style.)
    * batch < data-size (long_500k): sequence striped over *every* axis.
    """
    dsize = rules.axis_size(rules.data_axes)
    seq_shard = rules.seq_shard_cache or batch < dsize
    tp = rules.model_axis

    def spec_of(path, leaf):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        nd = leaf.ndim
        if "ssm" in names:
            if names[-1] == "state":      # (L, B, H, P, N)
                spec = (None, None if seq_shard else rules.data, tp, None, None)
            else:                          # conv (L, B, K-1, C)
                spec = (None, None if seq_shard else rules.data, None, None)
            return P(*spec[:nd])
        # kv/mla/cross: (L, B, S, ...)
        if seq_shard:
            b_ax = None
            s_ax = tuple(rules.data_axes) + (tp,)
        else:
            b_ax = rules.data
            s_ax = tp
        spec = [None, b_ax, s_ax] + [None] * (nd - 3)
        return P(*spec)

    def fixed(path, leaf):
        spec = spec_of(path, leaf)
        if not _fits(leaf.shape, spec, rules):
            spec = P(*[
                a if a is not None and dim % rules.axis_size(a) == 0 else None
                for dim, a in zip(
                    leaf.shape,
                    tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec))),
                )
            ])
        return spec

    return jax.tree_util.tree_map_with_path(fixed, cache)
