"""SeamlessM4T-large-v2: encoder-decoder transformer backbone.

[arXiv:2308.11596] -- the speech frontend (mel + conformer feature
extractor) is stubbed per the brief; ``input_specs`` provides frame
embeddings.  Source/target each take seq_len/2 of the assigned shape.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,             # decoder
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    mlp_type="gelu",
    norm_type="layernorm",
    is_encoder_decoder=True,
    frontend="audio",
    source="arXiv:2308.11596",
)
