"""DeepSeek-V3 671B: MLA + 1 shared/256 routed top-8 MoE + MTP.

[arXiv:2412.19437] -- the MLA latent (c_kv || k_rope = 576/token/layer) is
the KVC payload SkyMemory chunks for this arch (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,            # dense layers (first_k_dense)
    vocab_size=129280,
    head_dim=128,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    moe_group_size=512,
    source="arXiv:2412.19437",
)
