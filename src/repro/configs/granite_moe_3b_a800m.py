"""Granite-3.0 MoE 3B-A800M: 40 experts top-8, 512-dim experts.

[hf:ibm-granite/granite-3.0-1b-a400m-base] -- assigned 3b-a800m dims.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
