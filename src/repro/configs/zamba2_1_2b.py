"""Zamba2-1.2B: Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_groups=1,
    attn_layer_period=6,
    notes="Mamba2 blocks; one shared full-attention block every 6 layers",
    source="arXiv:2411.15242",
)
