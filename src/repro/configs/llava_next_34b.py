"""LLaVA-NeXT 34B language backbone (anyres vision frontend stubbed).

[hf:llava-hf/llava-v1.6-mistral-7b-hf] -- assigned 34B-scale dims; the
backbone is Nous-Hermes-2-Yi-34B-like (GQA kv=8).  ``input_specs`` supplies
precomputed anyres patch embeddings (up to 5 tiles x 576 = 2880 tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=5_000_000.0,
    num_image_tokens=2880,
    frontend="vision",
    notes="anyres tiling; vision tower + projector stubbed per brief",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
