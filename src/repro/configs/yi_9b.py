"""Yi-9B: llama-architecture dense GQA [arXiv:2403.04652]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    arch_type="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)
