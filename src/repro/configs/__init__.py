"""Assigned architecture configs (``--arch <id>``) + the paper's own model.

Each module defines ``CONFIG`` with the exact assigned dimensions (source
cited in ``source``) and registers it here.  ``smoke_config`` derives the
reduced same-family variant used by CPU smoke tests (2 layers,
d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "llava-next-34b",
    "zamba2-1.2b",
    "nemotron-4-340b",
    "yi-9b",
    "internlm2-1.8b",
    "mamba2-1.3b",
    "granite-moe-3b-a800m",
    "stablelm-12b",
    "deepseek-v3-671b",
    "seamless-m4t-large-v2",
    "skymemory-tinyllama",   # the paper's own testbed model (§5)
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_configs() -> list[str]:
    return list(ARCH_IDS)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = 4 if cfg.num_heads else 0
    kv = min(cfg.num_kv_heads, heads) or heads
    kw = dict(
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=max(1, kv if kv <= heads else heads),
        head_dim=d // heads if heads else 0,
        d_ff=2 * d,
        vocab_size=512,
        num_image_tokens=min(cfg.num_image_tokens, 16),
        moe_group_size=64,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, num_experts_per_tok=2, moe_d_ff=2 * d,
                  first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.use_mla:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16, mtp_depth=cfg.mtp_depth)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.attn_layer_period:
        kw.update(attn_layer_period=1, num_layers=2)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2)
    return dataclasses.replace(cfg, **kw)


def shape_variant(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config tweaks: long-context decode needs sub-quadratic
    memory, so full-attention families switch to the sliding-window cache
    (DESIGN.md §4); SSM/hybrid run natively."""
    if shape.name == "long_500k" and cfg.arch_type not in ("ssm",):
        if cfg.arch_type == "hybrid":
            return cfg.replace(sliding_window=32_768)  # shared-attn windows
        return cfg.replace(sliding_window=32_768)
    return cfg


__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "get_config",
    "list_configs",
    "smoke_config",
    "shape_variant",
]
