"""The paper's own testbed model: TinyLlama-1.1B-Chat-v1.0 (§5, Table 3).

22L, d=2048, 32H GQA kv=4, ffn 5632, vocab 32000 -- used by the KVC-speedup
benchmark that reproduces the paper's 21-24% generation speedup.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="skymemory-tinyllama",
    arch_type="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    head_dim=64,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    source="hf:TinyLlama/TinyLlama-1.1B-Chat-v1.0 (paper §5)",
)
