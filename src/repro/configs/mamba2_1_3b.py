"""Mamba2-1.3B: attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=128,
    notes="attention-free; decode state is a fixed-size snapshot",
    source="arXiv:2405.21060",
)
