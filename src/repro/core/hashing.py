"""Chained block hashing (paper §3.1, Set-KVC steps 1-2).

The hash of token block ``i`` covers all blocks ``1..i``: it is
``H(prev_hash || tokens_i)`` with a null previous hash for the first block.
Longest-prefix lookup therefore reduces to finding the matching hash that is
furthest toward the end of the hash list.
"""
from __future__ import annotations

import hashlib
from typing import Sequence

NULL_HASH = b"\x00" * 32


def split_token_blocks(
    tokens: Sequence[int], block_size: int, *, full_only: bool = True
) -> list[tuple[int, ...]]:
    """Split a token sequence into fixed-size blocks.

    Only full blocks participate in caching (a partial trailing block has no
    stable hash across prompts), mirroring vLLM prefix caching.
    """
    if block_size < 1:
        raise ValueError("block_size must be >= 1")
    n_full = len(tokens) // block_size
    blocks = [
        tuple(tokens[i * block_size : (i + 1) * block_size]) for i in range(n_full)
    ]
    if not full_only and len(tokens) % block_size:
        blocks.append(tuple(tokens[n_full * block_size :]))
    return blocks


def hash_block(prev_hash: bytes, tokens: Sequence[int]) -> bytes:
    h = hashlib.sha256()
    h.update(prev_hash)
    for t in tokens:
        h.update(int(t).to_bytes(8, "little", signed=True))
    return h.digest()


def chain_hashes(tokens: Sequence[int], block_size: int) -> list[bytes]:
    """Chained hashes for every full block of ``tokens`` (paper §3.1)."""
    prev = NULL_HASH
    out: list[bytes] = []
    for block in split_token_blocks(tokens, block_size):
        prev = hash_block(prev, block)
        out.append(prev)
    return out


def hex_id(block_hash: bytes) -> str:
    return block_hash.hex()[:16]
