"""Per-satellite chunk store with LRU eviction (paper §3.9).

Each satellite hosts an in-memory hashtable keyed by ``(block_hash,
chunk_id)``.  Under memory pressure the least-recently-used chunk is evicted;
an eviction callback lets the owning constellation propagate the eviction
(gossip / lazy policies live in ``eviction.py``).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable

ChunkKey = tuple[bytes, int]  # (block_hash, chunk_id)
# (store, victim key, victim bytes): the value rides along because the
# owner may need to spill it to a lower tier -- by callback time it is
# already out of the store, so this is the last reference
EvictionCallback = Callable[["SatelliteStore", ChunkKey, bytes], None]


@dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0
    sets: int = 0
    evictions: int = 0
    bytes_stored: int = 0


@dataclass
class SatelliteStore:
    """LRU key-value store for KVC chunks on one satellite.

    ``policy`` is an optional shared recency clock (``core.eviction.
    LRUClock``, keyed by block hash): when present, victim selection uses
    the *cross-tier* recency stamp instead of this store's private
    insertion order, so radix prefix hits and presence probes at the LLM
    host count as uses here too.  Without it the store falls back to its
    own OrderedDict LRU (seed behavior).
    """

    capacity_bytes: int | None = None
    on_evict: EvictionCallback | None = None
    policy: object | None = None
    _data: OrderedDict = field(default_factory=OrderedDict)
    stats: StoreStats = field(default_factory=StoreStats)

    def __len__(self) -> int:
        return len(self._data)

    @property
    def used_bytes(self) -> int:
        return self.stats.bytes_stored

    def set(self, key: ChunkKey, value: bytes) -> None:
        if key in self._data:
            self.stats.bytes_stored -= len(self._data[key])
            del self._data[key]
        self._data[key] = value
        self.stats.bytes_stored += len(value)
        self.stats.sets += 1
        if self.policy is not None:
            self.policy.touch(key[0])
        self._enforce_capacity()

    def get(self, key: ChunkKey) -> bytes | None:
        if key not in self._data:
            self.stats.misses += 1
            return None
        self._data.move_to_end(key)  # LRU touch
        if self.policy is not None:
            self.policy.touch(key[0])
        self.stats.hits += 1
        return self._data[key]

    def contains(self, key: ChunkKey) -> bool:
        return key in self._data

    def peek(self, key: ChunkKey) -> bytes | None:
        """Read without side effects: no LRU promotion, no policy stamp,
        no hit/miss accounting.  Control-plane movers (rotation
        migration, repair) use this so shuffling a cold chunk between
        satellites does not make it look recently *used* and scramble
        eviction order."""
        return self._data.get(key)

    def touch(self, key: ChunkKey) -> None:
        """Stamp ``key`` as used without reading it.  Presence probes
        (``has_block``'s chunk-0 check) go through ``contains``, which --
        by design -- does not move the LRU clock; before this hook
        existed, a block confirmed present over and over by lookups still
        aged as if untouched and was evicted first (the LRU-clock
        staleness fixed alongside the shared policy)."""
        if key in self._data:
            self._data.move_to_end(key)
            if self.policy is not None:
                self.policy.touch(key[0])

    def delete(self, key: ChunkKey) -> bool:
        if key in self._data:
            self.stats.bytes_stored -= len(self._data[key])
            del self._data[key]
            return True
        return False

    def keys(self) -> list[ChunkKey]:
        return list(self._data.keys())

    def inventory(self) -> dict[bytes, list[int]]:
        """Anti-entropy inventory report: ``block_hash -> chunk ids``
        this satellite holds.  Read-only like ``peek`` -- no recency
        stamps, no hit/miss accounting -- so a ``reconcile`` pass over a
        healthy fabric leaves eviction order untouched."""
        inv: dict[bytes, list[int]] = {}
        for block_hash, cid in self._data:
            inv.setdefault(block_hash, []).append(cid)
        return inv

    def pop_all(self) -> list[tuple[ChunkKey, bytes]]:
        """Drain the store (used by rotation migration)."""
        items = list(self._data.items())
        self._data.clear()
        self.stats.bytes_stored = 0
        return items

    def _enforce_capacity(self) -> None:
        if self.capacity_bytes is None:
            return
        order = None
        while self.stats.bytes_stored > self.capacity_bytes and self._data:
            if self.policy is not None:
                # cross-tier LRU: coldest block-hash stamp first; ties
                # fall back to this store's insertion order.  The order is
                # computed ONCE per enforcement (recency only changes via
                # the evictions themselves), so displacing k chunks costs
                # one O(n log n) sort, not k O(n) scans -- and on_evict
                # typically purges the victim's sibling chunks too, so a
                # stale entry in the order is just skipped.
                if order is None:
                    order = iter(sorted(
                        self._data, key=lambda k: self.policy.recency(k[0])))
                key = next((k for k in order if k in self._data), None)
                if key is None:
                    order = None
                    continue
                value = self._data.pop(key)
            else:
                key, value = self._data.popitem(last=False)  # LRU out
            self.stats.bytes_stored -= len(value)
            self.stats.evictions += 1
            if self.on_evict is not None:
                self.on_evict(self, key, value)
