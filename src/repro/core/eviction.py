"""Eviction policies (paper §3.9).

Three policies over ``ConstellationKVC``:

* **gossip**  -- an LRU eviction of one chunk triggers an immediate
  neighborhood broadcast purging the block's remaining chunks (the default
  wired into ``ConstellationKVC._on_evict`` -> ``purge_block``).  The
  concentric-ring placement keeps all affected chunks in the immediate
  neighborhood, so a simple broadcast in all directions suffices.
* **lazy**    -- nothing is propagated; a later ``get_block`` discovering a
  missing chunk purges the block and notifies the radix index.
* **periodic** -- ``sweep_incomplete`` scans for blocks with missing chunks.

This module adds the shared recency policy every cache tier consults
(``LRUClock``), the gossip *cost model* (how many ISL messages a broadcast
takes), and a helper to run the periodic sweep policy.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Hashable, Iterable

from repro.core.chunking import chunk_server
from repro.core.protocol import ConstellationKVC


class LRUClock:
    """One monotonic recency clock shared across cache tiers.

    Every tier that has to pick a victim -- the serving layer's L1 host
    page cache, the §3.10 radix block index, and the per-satellite chunk
    stores (L2) -- stamps accesses on the *same* clock, so "least
    recently used" means the same thing everywhere: a block kept hot by
    radix prefix hits at the LLM host is not evicted first by a satellite
    store that never saw those lookups, and an offloaded sequence's host
    pages age against the same timeline as constellation blocks.

    Keys are arbitrary hashables (block hashes for L2/radix, sequence
    keys for L1); the clock never dereferences them.  An unknown key has
    recency 0 -- older than anything ever touched.

    Scale-out clusters stamp this clock from several replica threads at
    once, so the tick is drawn from an ``itertools.count`` (atomic under
    CPython) rather than a read-modify-write counter.
    """

    def __init__(self) -> None:
        self._counter = itertools.count(1)
        self._stamp: dict[Hashable, int] = {}

    def __len__(self) -> int:
        return len(self._stamp)

    def touch(self, key: Hashable) -> int:
        """Stamp an access; returns the new clock value."""
        stamp = next(self._counter)
        self._stamp[key] = stamp
        return stamp

    def recency(self, key: Hashable) -> int:
        """Last access stamp (0 = never touched / forgotten)."""
        return self._stamp.get(key, 0)

    def victim(self, keys: Iterable[Hashable]) -> Hashable | None:
        """The least-recently-used key among ``keys`` (stable: the first
        minimal entry wins, so callers iterating in insertion order keep
        FIFO behavior for never-touched keys)."""
        best, best_r = None, None
        for k in keys:
            r = self.recency(k)
            if best_r is None or r < best_r:
                best, best_r = k, r
        return best

    def forget(self, key: Hashable) -> None:
        self._stamp.pop(key, None)


@dataclass(frozen=True)
class GossipCost:
    messages: int
    max_hops: int


def gossip_cost(kvc: ConstellationKVC, block_hash: bytes) -> GossipCost:
    """Cost of broadcasting an eviction of ``block_hash`` from its chunk-0
    server to every other server holding chunks of the block."""
    n_chunks = kvc.directory.get(block_hash)
    if not n_chunks:
        return GossipCost(messages=0, max_hops=0)
    origin = kvc.server_sat(chunk_server(0, kvc.num_servers))
    targets = {
        kvc.server_sat(chunk_server(cid, kvc.num_servers))
        for cid in range(n_chunks)
    } - {origin}
    hops = [kvc.spec.hops(origin, t) for t in targets]
    return GossipCost(messages=len(targets), max_hops=max(hops, default=0))


def run_periodic_sweep(kvc: ConstellationKVC) -> int:
    """Periodic cleanup policy: purge all incomplete blocks."""
    return kvc.sweep_incomplete()
