"""Eviction policies (paper §3.9).

Three policies over ``ConstellationKVC``:

* **gossip**  -- an LRU eviction of one chunk triggers an immediate
  neighborhood broadcast purging the block's remaining chunks (the default
  wired into ``ConstellationKVC._on_evict`` -> ``purge_block``).  The
  concentric-ring placement keeps all affected chunks in the immediate
  neighborhood, so a simple broadcast in all directions suffices.
* **lazy**    -- nothing is propagated; a later ``get_block`` discovering a
  missing chunk purges the block and notifies the radix index.
* **periodic** -- ``sweep_incomplete`` scans for blocks with missing chunks.

This module adds the gossip *cost model* (how many ISL messages a broadcast
takes) and a helper to run the periodic sweep policy.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.chunking import chunk_server
from repro.core.protocol import ConstellationKVC


@dataclass(frozen=True)
class GossipCost:
    messages: int
    max_hops: int


def gossip_cost(kvc: ConstellationKVC, block_hash: bytes) -> GossipCost:
    """Cost of broadcasting an eviction of ``block_hash`` from its chunk-0
    server to every other server holding chunks of the block."""
    n_chunks = kvc.directory.get(block_hash)
    if not n_chunks:
        return GossipCost(messages=0, max_hops=0)
    origin = kvc.server_sat(chunk_server(0, kvc.num_servers))
    targets = {
        kvc.server_sat(chunk_server(cid, kvc.num_servers))
        for cid in range(n_chunks)
    } - {origin}
    hops = [kvc.spec.hops(origin, t) for t in targets]
    return GossipCost(messages=len(targets), max_hops=max(hops, default=0))


def run_periodic_sweep(kvc: ConstellationKVC) -> int:
    """Periodic cleanup policy: purge all incomplete blocks."""
    return kvc.sweep_incomplete()
