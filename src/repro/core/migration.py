"""Rotation chunk migration (paper §3.4, §3.8 step 7, Figs 5/8).

When satellites drift out of the LOS window their chunks are migrated -- in
parallel within each orbital plane -- to the satellites about to enter LOS.
A migration is harmless if the chunk briefly exists on both satellites
(paper §3.7), so moves are modeled copy-then-delete.

Since PR 7 a move carries metadata too: the directory-stripe shards
homed on the departing satellite (and its replica offsets) ride along to
the destination, so lookups keep resolving through the live server map
after rotation (``ConstellationKVC.execute_move``).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.constellation import ConstellationSpec, LosWindow, Sat


@dataclass(frozen=True)
class Move:
    server_id: int  # 1-based logical server id
    src: Sat
    dst: Sat


def plan_migration(
    spec: ConstellationSpec,
    old_window: LosWindow,
    new_window: LosWindow,
    server_map: list[Sat],
) -> list[Move]:
    """Plan per-plane parallel moves for servers whose satellite left LOS.

    A server whose satellite is no longer inside ``new_window`` is reassigned
    to the satellite in the *same orbital plane* offset by the window height
    (the satellite entering LOS at the same relative position), repeatedly
    until it lands inside the window (handles multi-step shifts).
    """
    d_slot = spec.torus_delta(old_window.center, new_window.center)[1]
    step = new_window.rows if d_slot >= 0 else -new_window.rows
    moves: list[Move] = []
    for sid0, sat in enumerate(server_map):
        if new_window.contains(spec, sat):
            continue
        dst = sat
        for _ in range(spec.sats_per_plane):  # bounded walk
            dst = spec.wrap(Sat(dst.plane, dst.slot + step))
            if new_window.contains(spec, dst):
                break
        moves.append(Move(server_id=sid0 + 1, src=sat, dst=dst))
    return moves


def migration_planes(moves: list[Move]) -> dict[int, list[Move]]:
    """Group moves by orbital plane -- each group executes in parallel."""
    groups: dict[int, list[Move]] = {}
    for m in moves:
        groups.setdefault(m.src.plane, []).append(m)
    return groups
