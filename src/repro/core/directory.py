"""Striped, replicated block-metadata directory (the fabric's lookup state).

Until PR 7 the block directory -- ``block_hash -> n_chunks`` for every
block believed stored -- was one host-side dict consulted for free and
immune to churn: the last omniscient-oracle piece of the protocol.  Here
it becomes fabric state, like the chunks it describes:

* every block's entry lives on a *stripe* whose home server is derived
  from the block hash (``stripe_of``, the metadata analogue of
  ``chunking.chunk_server``), replicated ``dir_replication`` times with
  the same ``replica_delta`` plane-diverse geometry as chunk replicas;
* the stripe homes are resolved through the live ``server_map``, so
  rotation migration moves a stripe's entries along with the server
  whose satellite hosts them;
* a satellite death destroys its shard (``drop``) exactly like its
  chunk store -- lookups fall through the surviving stripe replicas
  (priced, degraded), and ``ConstellationKVC.reconcile`` rebuilds lost
  shards from surviving replicas plus per-satellite chunk inventories.

Shards are deliberately NOT stored inside ``SatelliteStore``: chunk
stores hold data bytes subject to LRU capacity eviction, while directory
entries are metadata that must never be displaced by data pressure --
they are only ever destroyed by the satellite dying.
"""
from __future__ import annotations

from repro.core.constellation import Sat


def stripe_of(block_hash: bytes, num_servers: int) -> int:
    """Hash-derived directory stripe (virtual server id) owning a
    block's metadata entry."""
    return int.from_bytes(block_hash[:8], "big") % num_servers


class StripedDirectory:
    """Per-satellite metadata shards: ``sat -> {block_hash: n_chunks}``.

    This class is pure storage; the owning ``ConstellationKVC`` does the
    geometry (which satellites home a stripe's replicas) and the pricing
    (directory ops run on the ``IslTransport`` like any chunk op).
    """

    def __init__(self) -> None:
        self._shards: dict[Sat, dict[bytes, int]] = {}

    def shard(self, sat: Sat) -> dict[bytes, int]:
        """The (mutable) shard hosted by ``sat``, created on first use."""
        return self._shards.setdefault(sat, {})

    def shard_len(self, sat: Sat) -> int:
        """Entry count of ``sat``'s shard without creating one."""
        return len(self._shards.get(sat, ()))

    def drop(self, sat: Sat) -> int:
        """``sat`` died: its shard's entries are destroyed (metadata is
        fabric state -- it does not outlive its host).  Returns the
        number of entries lost."""
        shard = self._shards.pop(sat, None)
        return 0 if shard is None else len(shard)

    def entries(self) -> dict[bytes, int]:
        """Merged view over every surviving shard (control-plane only:
        data-plane lookups must go through the priced stripe walk)."""
        merged: dict[bytes, int] = {}
        for shard in self._shards.values():
            merged.update(shard)
        return merged
