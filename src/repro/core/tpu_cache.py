"""SkyMemory placement math applied to the TPU ICI torus (beyond-paper).

A TPU v5e pod is a 2D ICI torus -- the same +GRID abstraction the paper
assumes for satellites.  This module reuses the paper's chunk-placement and
migration machinery at chip scale:

* *chunk striping*  -> sequence-dim sharding of the paged KV cache across the
  ``data`` mesh axis (each device holds ``1/n`` of the context blocks);
* *hop-aware placement* -> assigning logical cache shards to mesh positions
  in BFS rings around the decode host so a gather touches the fewest ICI
  hops (``ring_layout``);
* *rotation migration* -> ``lax.ppermute`` shifting shards one position
  along the torus (``migrate_shards``), the collective-permute analogue of
  the paper's per-plane parallel chunk moves;
* the paper's worst-case latency estimator with TPU constants
  (``gather_cost_s``): ~1 us/link hop, 50 GB/s/link ICI.

Used by the ``long_500k`` decode path (context-sharded KVC) and by the
roofline/benchmark layers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.4.35 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.mapping import Strategy, _bfs_offsets

ICI_HOP_LATENCY_S = 1e-6          # per-hop ICI latency (order of magnitude)
ICI_LINK_BW_BYTES_S = 50e9        # ~50 GB/s per ICI link


@dataclass(frozen=True)
class TorusGrid:
    """A 2D device torus (rows x cols) -- chip-scale +GRID."""

    rows: int
    cols: int

    @property
    def size(self) -> int:
        return self.rows * self.cols

    def hops(self, a: tuple[int, int], b: tuple[int, int]) -> int:
        dr = abs(a[0] - b[0])
        dc = abs(a[1] - b[1])
        return min(dr, self.rows - dr) + min(dc, self.cols - dc)

    def ring_layout(
        self, num_shards: int, center: tuple[int, int] = (0, 0),
        strategy: Strategy = Strategy.HOP,
    ) -> list[tuple[int, int]]:
        """Positions for logical shards 0..n-1, BFS rings around ``center``.

        The same traversal that reproduces the paper's Figs 14-15, so shard 0
        sits on the host chip and shard *i*'s hop distance grows ~sqrt(i).
        """
        if num_shards > self.size:
            raise ValueError("more shards than devices")
        bound = None
        if strategy is Strategy.ROTATION_HOP:
            side = int(math.ceil(math.sqrt(num_shards)))
            bound = (side, side)
        offs = _bfs_offsets(num_shards, bound=bound, torus=(self.cols, self.rows))
        return [
            ((center[0] + ds) % self.rows, (center[1] + dp) % self.cols)
            for dp, ds in offs
        ]

    def worst_hops(self, layout: list[tuple[int, int]], center: tuple[int, int]) -> int:
        return max((self.hops(center, pos) for pos in layout), default=0)


def gather_cost_s(
    grid: TorusGrid,
    layout: list[tuple[int, int]],
    center: tuple[int, int],
    bytes_per_shard: int,
) -> float:
    """Paper Eq-3-style worst-case fetch estimate with TPU ICI constants.

    Per-shard fetch = hop latency x hops + serialization over the last link;
    all shards move in parallel (paper: chunks queried in parallel), so the
    gather cost is the max.
    """
    per = [
        grid.hops(center, pos) * ICI_HOP_LATENCY_S
        + bytes_per_shard / ICI_LINK_BW_BYTES_S
        for pos in layout
    ]
    return max(per, default=0.0)


def row_major_layout(grid: TorusGrid, num_shards: int) -> list[tuple[int, int]]:
    """The rotation-aware (Fig 13) baseline layout at chip scale."""
    if num_shards > grid.size:
        raise ValueError("more shards than devices")
    return [(i // grid.cols, i % grid.cols) for i in range(num_shards)]


# ---------------------------------------------------------------------------
# JAX pieces: sharded paged-KVC container + ppermute migration.
# ---------------------------------------------------------------------------

def kvc_sharding(
    mesh: Mesh,
    *,
    seq_axis: str = "data",
    head_axis: str = "model",
) -> NamedSharding:
    """Sharding for a paged KV cache [n_blocks, block, kv_heads, head_dim]:
    context blocks striped over ``seq_axis`` (the paper's chunk striping),
    KV heads over ``head_axis`` (tensor parallel)."""
    return NamedSharding(mesh, P(seq_axis, None, head_axis, None))


def migrate_shards(x: jax.Array, mesh: Mesh, *, axis: str = "data", shift: int = 1):
    """Rotation migration at chip scale: cyclically shift cache shards
    ``shift`` positions along ``axis`` with a collective permute.

    The leading dim of ``x`` must be sharded over ``axis``.  Mirrors the
    paper's §3.4 parallel per-plane migration: every device forwards its
    shard to the next position in one collective step.
    """
    n = mesh.shape[axis]
    perm = [(i, (i + shift) % n) for i in range(n)]

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=P(axis),
    )
    def _shift(shard):
        return lax.ppermute(shard, axis_name=axis, perm=perm)

    return _shift(x)


def strategy_cost_table(
    grid: TorusGrid, num_shards: int, bytes_per_shard: int,
    center: tuple[int, int] | None = None,
) -> dict[str, float]:
    """Compare the paper's placements as chip-scale gather costs."""
    if center is None:
        center = (grid.rows // 2, grid.cols // 2)
    layouts = {
        "rotation(row-major)": row_major_layout(grid, num_shards),
        "hop(bfs-rings)": grid.ring_layout(num_shards, center, Strategy.HOP),
        "rotation_hop(boxed-rings)": grid.ring_layout(
            num_shards, center, Strategy.ROTATION_HOP
        ),
    }
    return {
        name: gather_cost_s(grid, layout, center, bytes_per_shard)
        for name, layout in layouts.items()
    }


def device_grid_for_mesh(mesh: Mesh, axes: tuple[str, str] = ("data", "model")) -> TorusGrid:
    return TorusGrid(rows=mesh.shape[axes[0]], cols=mesh.shape[axes[1]])


def shard_layout_permutation(
    grid: TorusGrid, num_shards: int, center: tuple[int, int],
    strategy: Strategy = Strategy.ROTATION_HOP,
) -> np.ndarray:
    """Permutation p where logical shard i lives at flat device index p[i]."""
    layout = grid.ring_layout(num_shards, center, strategy)
    return np.array([r * grid.cols + c for r, c in layout], dtype=np.int32)
