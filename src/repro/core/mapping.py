"""Server-to-satellite placement strategies (paper §3.4-3.7, Figs 13-15).

A *server* is a virtual chunk destination: chunk ``i`` of a block lands on
server ``i mod num_servers`` (paper §3.1).  A placement strategy assigns each
logical server id (1-based, matching the paper's figures) a satellite.

The paper's concentric-circle layouts (Figs 14-15) are reproduced exactly by
a breadth-first traversal from the center satellite with neighbor order
north, east, south, west (up, right, down, left in the figures), optionally
bounded to the LOS box.  This is verified against the published 3x3 and 5x5
grids in the tests.
"""
from __future__ import annotations

import enum
import math
from collections import deque

from repro.core.constellation import ConstellationSpec, LosWindow, Sat


class Strategy(enum.Enum):
    ROTATION = "rotation"
    HOP = "hop"
    ROTATION_HOP = "rotation_hop"


# BFS neighbor order: up (north), right (east), down (south), left (west).
_BFS_STEPS = ((0, -1), (1, 0), (0, 1), (-1, 0))  # (d_plane, d_slot)


def _bfs_offsets(
    num_servers: int,
    *,
    bound: tuple[int, int] | None,
    torus: tuple[int, int] | None,
) -> list[tuple[int, int]]:
    """(d_plane, d_slot) offsets from center for server ids 1..num_servers.

    ``bound``: optional (rows, cols) LOS box limit around the center.
    ``torus``: (num_planes, sats_per_plane) for wraparound dedup; required
    when unbounded so the BFS terminates on small constellations.
    """
    if bound is not None:
        rows, cols = bound
        lo_c, hi_c = -((cols - 1) // 2), cols // 2
        lo_r, hi_r = -((rows - 1) // 2), rows // 2

    def in_bound(dp: int, ds: int) -> bool:
        if bound is None:
            return True
        return lo_c <= dp <= hi_c and lo_r <= ds <= hi_r

    def canon(dp: int, ds: int) -> tuple[int, int]:
        if torus is None:
            return dp, ds
        n, m = torus
        return dp % n, ds % m

    out: list[tuple[int, int]] = []
    seen = {canon(0, 0)}
    queue: deque[tuple[int, int]] = deque([(0, 0)])
    out.append((0, 0))
    while queue and len(out) < num_servers:
        dp, ds = queue.popleft()
        for sp, ss in _BFS_STEPS:
            np_, ns = dp + sp, ds + ss
            key = canon(np_, ns)
            if key in seen or not in_bound(np_, ns):
                continue
            seen.add(key)
            queue.append((np_, ns))
            out.append((np_, ns))
            if len(out) == num_servers:
                break
    if len(out) < num_servers:
        raise ValueError(
            f"cannot place {num_servers} servers: only {len(out)} positions"
        )
    return out


def bounding_box_side(num_servers: int) -> int:
    """Paper §3.7: the LOS bounding box side is ceil(sqrt(num_servers))."""
    return int(math.ceil(math.sqrt(num_servers)))


def place_servers(
    strategy: Strategy,
    spec: ConstellationSpec,
    window: LosWindow,
    num_servers: int,
) -> list[Sat]:
    """Map server ids 1..num_servers to satellites.

    Returns a list where index ``i`` holds the satellite of server ``i+1``.

    * ROTATION      -- row-major, left->right top->bottom over the LOS window
                       (Fig 13 / §3.5); requires num_servers <= window area.
    * HOP           -- concentric BFS rings around the window center,
                       unbounded (Fig 14 / §3.6); for on-board hosts.
    * ROTATION_HOP  -- BFS rings bounded to a ceil(sqrt(S))-sided box
                       centered on the window center (Fig 15 / §3.7).
    """
    if num_servers < 1:
        raise ValueError("num_servers must be >= 1")
    if strategy is Strategy.ROTATION:
        sats = window.sats(spec)
        if num_servers > len(sats):
            raise ValueError(
                f"rotation-aware placement needs num_servers <= LOS area "
                f"({num_servers} > {len(sats)})"
            )
        return sats[:num_servers]
    if strategy is Strategy.HOP:
        offs = _bfs_offsets(
            num_servers,
            bound=None,
            torus=(spec.num_planes, spec.sats_per_plane),
        )
    else:
        side = bounding_box_side(num_servers)
        offs = _bfs_offsets(
            num_servers,
            bound=(side, side),
            torus=(spec.num_planes, spec.sats_per_plane),
        )
    c = window.center
    return [spec.wrap(Sat(c.plane + dp, c.slot + ds)) for dp, ds in offs]


def layout_grid(
    strategy: Strategy, side: int, *, spec: ConstellationSpec | None = None
) -> list[list[int]]:
    """Render a strategy as the paper's side x side figure grid.

    Cell value = logical server id (1-based); 0 = unused cell (possible for
    HOP whose diamond does not fill the square).  Reproduces Figs 13-15.
    """
    if spec is None:
        # Large enough torus that wraparound does not fold the figure.
        spec = ConstellationSpec(4 * side, 4 * side, altitude_km=550.0)
    center = Sat(2 * side, 2 * side)
    window = LosWindow(center, side, side)
    num = side * side
    sats = place_servers(strategy, spec, window, num)
    tl = window.top_left(spec)
    grid = [[0] * side for _ in range(side)]
    for sid, sat in enumerate(sats, start=1):
        dp, ds = spec.torus_delta(tl, sat)
        if 0 <= ds < side and 0 <= dp < side:
            grid[ds][dp] = sid
    return grid


def hop_rings(num_servers: int) -> list[int]:
    """Hop count (ring index) of each server id under BFS placement."""
    offs = _bfs_offsets(num_servers, bound=None, torus=None)
    return [abs(dp) + abs(ds) for dp, ds in offs]
