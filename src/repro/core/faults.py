"""Constellation fault model: satellite churn and ISL outages.

The paper's protocol assumes a cooperative constellation -- every chunk
lives exactly where placement put it and every ISL leg is up.  A
production LEO cache lives with churn: satellites reboot or die, optical
links drop, and the cache must keep serving (degraded) and re-replicate
(repair) without a request ever failing.  This module is the fault
*source*; the degraded-read / repair behavior lives in
``core.protocol.ConstellationKVC``.

Three pieces:

* ``FaultState`` -- the live fault view the data plane consults on every
  chunk op: which satellites are dead, which ISL links are down, and how
  a route from ``src`` to ``dst`` runs *around* them.  A killed ISL no
  longer fails ops whose greedy route crosses it: ``route_hops`` finds
  the cheapest detour on the torus and the op pays the extra hops --
  link outages grade latency instead of failing, and only a genuinely
  partitioned endpoint is unreachable.  Mutation is copy-on-write over
  frozensets so serving threads read without taking a lock.
* ``FaultPlan`` -- a deterministic schedule of kill/heal events with
  times *relative to arming*, on the fabric's virtual clock
  (``core.protocol.SimClock``).  ``seeded_churn`` builds a reproducible
  random outage schedule: the same seed always yields the same kills at
  the same virtual times.
* ``FaultInjector`` -- binds a plan to a ``ConstellationKVC``: ``arm()``
  anchors the plan at the current clock reading, and ``advance()``
  (called by the store at the top of every chunk op, so no extra thread
  is needed) applies every event whose time has passed.  Killing a
  satellite drops its chunk store AND its directory-stripe shard -- data
  and metadata are both fabric state and both die with their host.
  Degraded reads fall through to surviving chunk replicas, degraded
  *lookups* fall through to surviving directory-stripe replicas, and
  ``reconcile()`` rebuilds both from what survives (chunk re-replication
  plus inventory-driven metadata reconstruction).

Two drive modes: on the *clock* (above -- realtime serving), or *held*
(``hold()`` / ``manual=True``): the chunk-op tick becomes a no-op and
only an explicit ``advance_to(rel_s)`` applies events.  Held mode is how
``EngineCluster.serve_stream``'s deterministic pump-budget interleave
replays a chaos arc byte-identically: the fabric clock is wall-anchored
(nondeterministic), so the serve loop drives the injector on virtual
*arrival-time* crossings instead -- exactly like rotation --
interleaving fault events and rotations in virtual-time order.
"""
from __future__ import annotations

import heapq
import math
import random
import threading
from dataclasses import dataclass, field

from repro.core.constellation import ConstellationSpec, Sat

Link = frozenset  # {Sat, Sat} -- ISL links are undirected


def link_key(a: Sat, b: Sat) -> frozenset:
    return frozenset((a, b))


class FaultState:
    """Current dead satellites / ISL links, readable without a lock.

    The sets are replaced wholesale on every mutation (copy-on-write),
    so a serving thread's membership check sees either the old or the
    new frozenset, never a half-updated one.  ``route_hops`` prices the
    route an op actually runs: the greedy +GRID path while it is clean,
    the cheapest detour around killed links otherwise -- so "the link on
    my route is down" grades the op's latency instead of failing it,
    and a per-state route cache keeps the search off the hot path.
    """

    def __init__(self) -> None:
        self.dead_sats: frozenset = frozenset()
        self.dead_links: frozenset = frozenset()
        self._route_cache: dict = {}

    @property
    def clean(self) -> bool:
        return not self.dead_sats and not self.dead_links

    # -- mutation (copy-on-write; callers serialize via the injector) ---
    def kill_sat(self, sat: Sat) -> None:
        self.dead_sats = self.dead_sats | {sat}
        self._route_cache = {}

    def heal_sat(self, sat: Sat) -> None:
        self.dead_sats = self.dead_sats - {sat}
        self._route_cache = {}

    def kill_link(self, a: Sat, b: Sat) -> None:
        self.dead_links = self.dead_links | {link_key(a, b)}
        self._route_cache = {}

    def heal_link(self, a: Sat, b: Sat) -> None:
        self.dead_links = self.dead_links - {link_key(a, b)}
        self._route_cache = {}

    # -- queries --------------------------------------------------------
    def sat_alive(self, sat: Sat) -> bool:
        return sat not in self.dead_sats

    def link_alive(self, a: Sat, b: Sat) -> bool:
        return link_key(a, b) not in self.dead_links

    def route_hops(
        self,
        spec: ConstellationSpec,
        src: Sat,
        dst: Sat,
        *,
        max_extra_hops: int | None = None,
    ) -> tuple[int, int] | None:
        """Hop composition ``(intra_plane, inter_plane)`` of the cheapest
        live route from ``src`` to ``dst`` under the current link faults.

        While no killed link sits on the greedy +GRID route this is just
        the Manhattan hop split the clean transport model prices.  When
        the greedy route crosses a dead link, a bounded uniform-cost
        search over the torus (edge weights = the spec's one-hop intra-/
        inter-plane latencies) finds the cheapest detour: the op still
        completes, at ``+extra_hops`` cost.  Returns ``None`` only when
        ``dst`` is partitioned from ``src`` -- every live path is cut (or
        longer than ``max_extra_hops`` beyond the Manhattan distance,
        when a bound is given).  Dead *satellites* do not block transit
        here: a dead node's links still carry detoured traffic in this
        model unless explicitly killed; endpoint death is ``reachable``'s
        concern (the data is gone, not the path).
        """
        src, dst = spec.wrap(src), spec.wrap(dst)
        dp, ds = spec.torus_delta(src, dst)
        base = (abs(ds), abs(dp))
        if not self.dead_links or src == dst:
            return base
        key = (src, dst, max_extra_hops)
        cache = self._route_cache
        if key in cache:
            return cache[key]
        path = spec.greedy_route(src, dst)
        if all(link_key(a, b) not in self.dead_links
               for a, b in zip(path, path[1:])):
            cache[key] = base
            return base
        li = spec.intra_plane_latency_s()
        le = spec.inter_plane_latency_s()
        budget = (None if max_extra_hops is None
                  else base[0] + base[1] + max_extra_hops)
        # uniform-cost search (Dijkstra) over the torus, skipping dead
        # links; the torus itself bounds the frontier at N*M nodes
        best_lat: dict[Sat, float] = {src: 0.0}
        frontier = [(0.0, 0, 0, src)]   # (latency, intra, inter, sat)
        found: tuple[int, int] | None = None
        while frontier:
            lat, ni, ne, cur = heapq.heappop(frontier)
            if cur == dst:
                found = (ni, ne)
                break
            if lat > best_lat.get(cur, math.inf):
                continue   # stale queue entry
            if budget is not None and ni + ne >= budget:
                continue
            for dpl, dsl, w, intra in (
                    (0, 1, li, 1), (0, -1, li, 1),
                    (1, 0, le, 0), (-1, 0, le, 0)):
                nxt = spec.wrap(Sat(cur.plane + dpl, cur.slot + dsl))
                if link_key(cur, nxt) in self.dead_links:
                    continue
                nlat = lat + w
                if nlat < best_lat.get(nxt, math.inf):
                    best_lat[nxt] = nlat
                    heapq.heappush(
                        frontier,
                        (nlat, ni + intra, ne + (1 - intra), nxt))
        cache[key] = found
        return found

    def extra_hops(self, spec: ConstellationSpec, src: Sat, dst: Sat) -> int:
        """Detour length beyond the clean Manhattan distance (0 when the
        greedy route is clean or the endpoint is partitioned)."""
        rh = self.route_hops(spec, src, dst)
        if rh is None:
            return 0
        return rh[0] + rh[1] - spec.hops(src, dst)

    def routed_latency_s(
        self, spec: ConstellationSpec, src: Sat, dst: Sat
    ) -> float | None:
        """One-way ISL latency of the cheapest live route (detours
        included), or ``None`` when ``dst`` is partitioned from ``src``.
        This is what ``IslTransport`` prices under link faults, so the
        estimate a router sees and the latency a fetch experiences are
        the same detoured path."""
        rh = self.route_hops(spec, src, dst)
        if rh is None:
            return None
        return (rh[0] * spec.intra_plane_latency_s()
                + rh[1] * spec.inter_plane_latency_s())

    def reachable(self, spec: ConstellationSpec, src: Sat, dst: Sat) -> bool:
        """Can a chunk op from ``src`` reach ``dst`` right now?

        The target must be alive and some live route must exist.  Killed
        ISL links no longer fail ops whose greedy route crosses them:
        ``route_hops`` detours around them at extra-hop cost, so a link
        outage only makes ``dst`` unreachable when it *partitions* the
        endpoint -- every path cut.  A dead satellite still blocks as an
        endpoint (its data is gone; that is what degraded reads fall
        through) but not as transit.  ``src`` itself is exempt: it is
        the op's origin (a serving replica's anchor or the ground host's
        uplink satellite), whose failure is the serving layer's problem,
        not the fabric's.
        """
        if dst in self.dead_sats:
            return False
        if not self.dead_links:
            return True
        return self.route_hops(spec, src, dst) is not None


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault transition, ``at_s`` relative to ``arm()``."""

    at_s: float
    action: str               # "kill" | "heal"
    sat: Sat | None = None
    link: tuple[Sat, Sat] | None = None

    def __post_init__(self) -> None:
        if self.action not in ("kill", "heal"):
            raise ValueError(f"unknown fault action {self.action!r}")
        if (self.sat is None) == (self.link is None):
            raise ValueError("a fault event targets a sat XOR a link")


@dataclass
class FaultPlan:
    """A deterministic, time-ordered schedule of fault events."""

    events: list[FaultEvent] = field(default_factory=list)

    def __post_init__(self) -> None:
        self.events = sorted(self.events, key=lambda e: e.at_s)

    @property
    def churn_span(self) -> tuple[float, float] | None:
        """``(first_kill_s, last_heal_s)`` -- the plan's churn phase
        boundaries, relative to arming.  ``None`` with no kills; with
        kills but no heals the churn never ends (``inf``), which also
        covers the end-of-run-drain idiom of heals parked at ``1e9``.
        The SLO timeline tags its goodput windows with these."""
        kills = [e.at_s for e in self.events if e.action == "kill"]
        if not kills:
            return None
        heals = [e.at_s for e in self.events if e.action == "heal"]
        return (min(kills), max(heals) if heals else math.inf)

    @classmethod
    def outages(
        cls,
        sats: list[Sat],
        *,
        kill_at_s: float = 0.0,
        stagger_s: float = 0.0,
        downtime_s: float | None = None,
    ) -> "FaultPlan":
        """Kill ``sats`` starting at ``kill_at_s`` (each ``stagger_s``
        after the previous), healing each ``downtime_s`` after its kill
        (``None`` = never)."""
        events = []
        for i, sat in enumerate(sats):
            t = kill_at_s + i * stagger_s
            events.append(FaultEvent(at_s=t, action="kill", sat=sat))
            if downtime_s is not None:
                events.append(
                    FaultEvent(at_s=t + downtime_s, action="heal", sat=sat))
        return cls(events)

    @classmethod
    def seeded_churn(
        cls,
        sats: list[Sat],
        *,
        seed: int,
        n_outages: int,
        start_s: float = 0.0,
        window_s: float = 1.0,
        downtime_s: float | None = None,
        links: list[tuple[Sat, Sat]] = (),
        n_link_outages: int = 0,
    ) -> "FaultPlan":
        """Reproducible random churn: ``n_outages`` distinct satellites
        from ``sats`` (and ``n_link_outages`` links from ``links``) are
        killed at seeded-uniform times in ``[start_s, start_s+window_s)``
        and healed ``downtime_s`` later.  Same seed, same schedule."""
        rng = random.Random(seed)
        events = []
        for sat in rng.sample(list(sats), min(n_outages, len(sats))):
            t = start_s + rng.random() * window_s
            events.append(FaultEvent(at_s=t, action="kill", sat=sat))
            if downtime_s is not None:
                events.append(
                    FaultEvent(at_s=t + downtime_s, action="heal", sat=sat))
        for link in rng.sample(list(links),
                               min(n_link_outages, len(links))):
            t = start_s + rng.random() * window_s
            events.append(FaultEvent(at_s=t, action="kill", link=link))
            if downtime_s is not None:
                events.append(
                    FaultEvent(at_s=t + downtime_s, action="heal", link=link))
        return cls(events)

    @classmethod
    def chaos_arc(
        cls,
        kvc,
        *,
        seed: int,
        churn_start_s: float,
        churn_window_s: float = 1.0,
        heal_s: float | None = None,
        n_sat_kills: int = 2,
        n_link_cuts: int = 0,
        dir_stripe_wipeout: bool = False,
        ground_pair_server: int | None = None,
    ) -> "FaultPlan":
        """A composite kill->degrade->heal arc over ``kvc``'s CURRENT
        geometry -- the PR 6/7 fault scenarios rolled into one seeded
        schedule meant to run *under live traffic*:

        * ``n_sat_kills`` survivable satellite kills (no data or
          directory home set completed, accounting for every other kill
          in this arc) -- degraded reads/lookups, never losses;
        * ``n_link_cuts`` ISL cuts, each severing the last greedy-route
          hop into a seeded chunk server's home -- ops detour, never
          fail;
        * ``dir_stripe_wipeout``: kill EVERY directory home of one
          seeded stripe -- its metadata is gone until heal + reconcile,
          so lookups for that stripe's blocks clean-miss and recompute;
        * ``ground_pair_server``: kill that server's ENTIRE replica home
          set -- its chunks lose every orbital copy, and Gets must fall
          through to an attached ground tier (or purge without one).

        Every kill lands at a seeded-uniform time in ``[churn_start_s,
        churn_start_s + churn_window_s)`` -- the *ordering* of the kills
        varies with the seed -- and every faulted element heals at
        ``heal_s`` (``None`` parks heals at 1e9: the end-of-run drain
        idiom).  Same ``(geometry, seed)``, same schedule."""
        rng = random.Random(seed)
        heal_at = 1e9 if heal_s is None else heal_s
        events: list[FaultEvent] = []
        killed: set[Sat] = set()

        def kill_heal_sat(sat: Sat) -> None:
            t = churn_start_s + rng.random() * churn_window_s
            events.append(FaultEvent(at_s=t, action="kill", sat=sat))
            events.append(FaultEvent(at_s=heal_at, action="heal", sat=sat))
            killed.add(sat)

        if ground_pair_server is not None:
            for r in range(kvc.replication):
                sat = kvc.replica_sat(ground_pair_server, r)
                if sat not in killed:
                    kill_heal_sat(sat)
        if dir_stripe_wipeout:
            kd = getattr(kvc, "dir_replication", kvc.replication)
            sid = rng.randrange(kvc.num_servers)
            for r in range(kd):
                sat = kvc.replica_sat(sid, r)
                if sat not in killed:
                    kill_heal_sat(sat)
        for sat in plan_survivable_kills(kvc, n_sat_kills,
                                         seed=rng.randrange(1 << 30),
                                         already_killed=killed):
            kill_heal_sat(sat)
        spec = kvc.spec
        for sid in rng.sample(range(kvc.num_servers),
                              min(n_link_cuts, kvc.num_servers)):
            path = spec.greedy_route(kvc.window.center,
                                     kvc.replica_sat(sid, 0))
            if len(path) < 2:
                continue
            link = (path[-2], path[-1])
            t = churn_start_s + rng.random() * churn_window_s
            events.append(FaultEvent(at_s=t, action="kill", link=link))
            events.append(FaultEvent(at_s=heal_at, action="heal", link=link))
        return cls(events)


@dataclass
class FaultInjectorStats:
    sat_kills: int = 0
    sat_heals: int = 0
    link_kills: int = 0
    link_heals: int = 0
    chunks_dropped: int = 0   # store entries destroyed by satellite deaths
    dir_entries_dropped: int = 0  # directory-shard entries destroyed

    @property
    def events_applied(self) -> int:
        return (self.sat_kills + self.sat_heals
                + self.link_kills + self.link_heals)


class FaultInjector:
    """Applies a ``FaultPlan`` to a ``ConstellationKVC`` on its clock.

    ``arm()`` anchors the plan's relative event times at the current
    clock reading; ``advance()`` -- called by the store at the top of
    every chunk op, and manually from tests -- applies every due event
    under one lock, so concurrent serving threads each see a consistent
    prefix of the plan.  With no clock (unclocked fabric) only events at
    ``at_s <= 0`` fire on advance; ``drain()`` force-applies the rest.

    ``hold()`` (or ``manual=True``) detaches the injector from the
    clock: the chunk-op tick no-ops and only ``advance_to(rel_s)``
    applies events -- the deterministic serve loop's drive, where
    "time" is the virtual arrival timeline, not the wall-anchored
    clock.
    """

    def __init__(self, kvc, plan: FaultPlan, *,
                 repair_on_heal: bool = False,
                 manual: bool = False) -> None:
        # views delegate storage to their base; faults live on the base
        self.kvc = getattr(kvc, "base", kvc)
        self.plan = plan
        self.repair_on_heal = repair_on_heal
        self.manual = manual
        self.state = FaultState()
        self.stats = FaultInjectorStats()
        self._idx = 0
        self._t0: float | None = None
        self._lock = threading.Lock()
        self.kvc.attach_faults(self)

    @property
    def clock(self):
        return self.kvc.transport.clock

    def _now(self) -> float:
        return 0.0 if self.clock is None else self.clock.now()

    def arm(self) -> None:
        """Anchor the plan at the current clock reading and rewind it."""
        with self._lock:
            self._t0 = self._now()
            self._idx = 0

    def hold(self) -> None:
        """Detach from the clock: the per-chunk-op ``advance()`` tick
        becomes a no-op and only ``advance_to`` applies events."""
        self.manual = True

    @property
    def next_event_at_s(self) -> float | None:
        """Relative time of the next unapplied event (None when the
        plan is exhausted) -- the deterministic serve loop peeks this to
        interleave fault crossings with rotation crossings in
        virtual-time order."""
        if self._idx >= len(self.plan.events):
            return None
        return self.plan.events[self._idx].at_s

    def advance(self) -> int:
        """Apply every event whose (relative) time has passed; returns
        how many fired.  No-op until ``arm()``, and always a no-op when
        held (``manual``): a clock read mid-pump must never fire events
        a deterministic replay expects at a virtual-time crossing."""
        if self.manual or self._t0 is None \
                or self._idx >= len(self.plan.events):
            return 0
        rel = self._now() - self._t0
        return self._apply_until(rel)

    def advance_to(self, rel_s: float) -> int:
        """Apply every event scheduled at or before ``rel_s`` (seconds
        relative to arming), regardless of the clock; returns how many
        fired.  Arms implicitly if needed.  This is the held-mode drive:
        the caller owns the timeline."""
        if self._t0 is None:
            self._t0 = self._now()
        return self._apply_until(rel_s)

    def drain(self) -> int:
        """Force-apply every remaining event (end-of-scenario settling:
        outstanding heals land regardless of the clock)."""
        if self._t0 is None:
            self._t0 = self._now()
        return self._apply_until(math.inf)

    def _apply_until(self, rel: float) -> int:
        fired = 0
        healed = False
        with self._lock:
            while (self._idx < len(self.plan.events)
                   and self.plan.events[self._idx].at_s <= rel):
                healed |= self._apply(self.plan.events[self._idx])
                self._idx += 1
                fired += 1
        if healed and self.repair_on_heal:
            # OUTSIDE the injector lock: repair purges unrecoverable
            # blocks, whose ``on_block_lost`` takes the serving-side
            # KVCManager lock -- while serving threads holding that lock
            # tick this injector from inside chunk ops.  Repairing under
            # ``self._lock`` would invert that order (ABBA deadlock).
            self.kvc.repair()
        return fired

    def _apply(self, ev: FaultEvent) -> bool:
        """Apply one event; returns True when it healed a satellite."""
        if ev.sat is not None:
            sat = self.kvc.spec.wrap(ev.sat)
            if ev.action == "kill":
                self.state.kill_sat(sat)
                self.stats.sat_kills += 1
                # shard size BEFORE the drop wipes it: the injector is
                # the fault source, so it attributes the metadata loss
                self.stats.dir_entries_dropped += self.kvc.dir_shard_len(sat)
                self.stats.chunks_dropped += self.kvc.drop_satellite(sat)
            else:
                self.state.heal_sat(sat)
                self.stats.sat_heals += 1
                return True
        else:
            a, b = ev.link
            a, b = self.kvc.spec.wrap(a), self.kvc.spec.wrap(b)
            if ev.action == "kill":
                self.state.kill_link(a, b)
                self.stats.link_kills += 1
            else:
                self.state.heal_link(a, b)
                self.stats.link_heals += 1
        return False


def plan_survivable_kills(kvc, n_kills: int, *, seed: int = 0,
                          already_killed: set[Sat] = frozenset()
                          ) -> list[Sat]:
    """Pick up to ``n_kills`` chunk-server satellites to kill such that,
    at the store's replication factor, no chunk loses its *entire*
    replica home set -- and, since PR 7, no directory stripe loses its
    entire metadata home set either -- the benchmark's "replication
    survives this" schedule.  A factor of 1 (data or metadata) means
    nothing at that tier is survivable, so that tier's constraint is
    waived; that is the collapse baseline.  ``already_killed`` names
    satellites some other part of the schedule kills anyway (a composite
    chaos arc's deliberate home-pair / stripe wipeouts): the picks here
    must not complete a home set *in combination with them*, and are
    never drawn from them.  Seeded and deterministic for a given store
    geometry."""
    rng = random.Random(seed)
    home_sets: list[set[Sat]] = []
    if kvc.replication > 1:
        home_sets += [
            {kvc.replica_sat(sid, r) for r in range(kvc.replication)}
            for sid in range(kvc.num_servers)
        ]
    kd = getattr(kvc, "dir_replication", kvc.replication)
    if kd > 1:
        home_sets += [
            {kvc.replica_sat(sid, r) for r in range(kd)}
            for sid in range(kvc.num_servers)
        ]
    # home sets the deliberate kills already complete are lost either
    # way -- only constrain the ones still survivable
    killed: set[Sat] = set(already_killed)
    home_sets = [homes for homes in home_sets if not homes <= killed]
    cands = [s for s in dict.fromkeys(kvc.server_map) if s not in killed]
    rng.shuffle(cands)
    out: list[Sat] = []
    for sat in cands:
        if len(out) >= n_kills:
            break
        if home_sets and any(
                homes <= killed | {sat} for homes in home_sets):
            continue
        killed.add(sat)
        out.append(sat)
    return out
