"""Worst-case chunk-fetch latency simulator (paper §4, Figs 1, 2, 16).

The paper's simulator computes, per placement strategy, the worst-case
latency over all chunk servers -- propagation to the farthest chunk (Eqs
1-4) plus per-chunk processing.  Our cost model (documented here because
Fig 16's exact model is not fully specified in the text):

* per-server latency  ``L_i = prop_i + chunks_i * proc_time``
* block latency       ``L   = max_i L_i``   (all servers queried in parallel)

Propagation per strategy (matching each strategy's §3.5-3.7 use case):

* ROTATION      -- ground-hosted LLM with direct links to *all* LOS
  satellites; servers fill the full LOS window row-major; ``prop_i`` is the
  slant range (Eq 4) to satellite *i*.  Migration re-anchors the mapping, so
  there is no rotation drift.
* HOP           -- single uplink to the (initial) center satellite plus ISL
  ring routing.  No migration, so as the constellation rotates the rings
  drift away from the uplink point: we average the worst case over a full
  within-plane rotation period.
* ROTATION_HOP  -- single uplink to the current center plus ISL routing
  inside the ceil(sqrt(S)) bounding box; per-step migration keeps the rings
  anchored (drift-free).

Reproduced claims: rotation+hop is lowest across altitudes; ~8-9x more
servers cut latency ~90% (the processing term scales 1/S); latency grows
with altitude; one intra-plane ISL hop lands between SSD and HDD latency
for ~50+ satellites per plane.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

from repro.core.constellation import (
    ConstellationSpec,
    LosWindow,
    Sat,
    one_hop_intra_plane_latency_s,
)
from repro.core.mapping import Strategy, bounding_box_side, place_servers
from repro.core.chunking import num_chunks as _num_chunks

# Paper Table 1 (approximate latency per memory type, seconds).
MEMORY_HIERARCHY_S: dict[str, tuple[float, float]] = {
    "CPU": (10e-9, 15e-9),
    "GPU": (50e-9, 100e-9),
    "RDMA": (2e-6, 5e-6),
    "SSD": (20e-6, 200e-6),
    "HDD": (2e-3, 20e-3),
    "NAS": (30e-3, 40e-3),
    "LEO (current RF)": (20e-3, 50e-3),
    "LEO (theoretical Laser)": (2e-3, 4e-3),
}


@dataclass(frozen=True)
class SimConfig:
    """Paper Table 2 defaults."""

    kvc_bytes: int = 221 * 1024 * 1024
    chunk_bytes: int = 6 * 1024
    num_servers: int = 81          # paper sweeps 9..81
    chunk_processing_time_s: float = 0.002  # paper sweeps 0.002..0.02
    altitude_km: float = 550.0     # paper sweeps 160..2000
    max_satellites: int = 15       # window rows  (within-plane)
    max_orbs: int = 15             # window cols  (planes)
    center_satellite: int = 8      # 1-based, paper Table 2
    center_orb: int = 8
    num_planes: int = 15
    sats_per_plane: int = 15


@dataclass(frozen=True)
class SimResult:
    strategy: str
    num_servers: int
    altitude_km: float
    worst_latency_s: float
    worst_propagation_s: float
    worst_processing_s: float
    chunks_total: int


def _spec(cfg: SimConfig) -> ConstellationSpec:
    return ConstellationSpec(
        num_planes=cfg.num_planes,
        sats_per_plane=cfg.sats_per_plane,
        altitude_km=cfg.altitude_km,
    )


def _window(cfg: SimConfig) -> LosWindow:
    center = Sat(cfg.center_orb - 1, cfg.center_satellite - 1)
    return LosWindow(center, cfg.max_satellites, cfg.max_orbs)


def _chunks_per_server(cfg: SimConfig) -> list[int]:
    total = _num_chunks(cfg.kvc_bytes, cfg.chunk_bytes)
    base, rem = divmod(total, cfg.num_servers)
    return [base + (1 if i < rem else 0) for i in range(cfg.num_servers)]


def worst_case_latency(strategy: Strategy, cfg: SimConfig) -> SimResult:
    spec = _spec(cfg)
    window = _window(cfg)
    center = window.center
    chunks = _chunks_per_server(cfg)
    total = sum(chunks)
    uplink_s = spec.uplink_latency_s()

    if strategy is Strategy.ROTATION:
        sats = place_servers(strategy, spec, window, cfg.num_servers)
        props = [spec.ground_latency_s(s, center) for s in sats]
        per = [p + c * cfg.chunk_processing_time_s for p, c in zip(props, chunks)]
        i = max(range(len(per)), key=per.__getitem__)
        return SimResult(
            strategy.value, cfg.num_servers, cfg.altitude_km,
            per[i], props[i], chunks[i] * cfg.chunk_processing_time_s, total,
        )

    sats = place_servers(strategy, spec, window, cfg.num_servers)
    offsets = [spec.torus_delta(center, s) for s in sats]
    # per-hop latencies from the spec -- the single ISL cost source shared
    # with IslTransport / ConstellationSpec.path_latency_s
    lat_m = spec.intra_plane_latency_s()
    lat_n = spec.inter_plane_latency_s()

    if strategy is Strategy.ROTATION_HOP:
        phases = [0]  # per-step migration keeps rings anchored
    else:  # HOP: no migration -> drift over a full within-plane period
        phases = list(range(cfg.sats_per_plane))

    # Vectorized phase sweep (the O(phases x servers) hot loop); argmax's
    # first-max tie-breaking matches the original strict `>` scan.
    dp = np.abs(np.array([o[0] for o in offsets], dtype=np.int64))
    ds = np.array([o[1] for o in offsets], dtype=np.int64)
    proc = np.array(chunks, dtype=np.int64) * cfg.chunk_processing_time_s
    phase_arr = np.array(phases, dtype=np.int64)
    path_s = (dp[None, :] * lat_n
              + np.abs(ds[None, :] - phase_arr[:, None]) * lat_m)
    prop_all = uplink_s + path_s                            # [phases, servers]
    tot_all = prop_all + proc[None, :]
    best = np.argmax(tot_all, axis=1)                       # [phases]
    rows = np.arange(len(phases))
    per_phase_tot = tot_all[rows, best]
    per_phase_prop = prop_all[rows, best]
    per_phase_proc = proc[best]

    worst_total = worst_prop = worst_proc = 0.0
    acc = 0.0
    for i in range(len(phases)):
        acc += float(per_phase_tot[i])   # sequential sum: seed float order
        if per_phase_tot[i] > worst_total:
            worst_total = float(per_phase_tot[i])
            worst_prop = float(per_phase_prop[i])
            worst_proc = float(per_phase_proc[i])
    mean_total = acc / len(phases)
    return SimResult(
        strategy.value, cfg.num_servers, cfg.altitude_km,
        mean_total, worst_prop, worst_proc, total,
    )


def sweep(
    *,
    strategies: tuple[Strategy, ...] = (
        Strategy.ROTATION,
        Strategy.HOP,
        Strategy.ROTATION_HOP,
    ),
    servers: tuple[int, ...] = (9, 25, 49, 81),
    altitudes_km: tuple[float, ...] = (160.0, 550.0, 1000.0, 2000.0),
    base: SimConfig = SimConfig(),
) -> list[SimResult]:
    """The paper's Fig-16 sweep: strategy x #servers x altitude."""
    out: list[SimResult] = []
    for strat in strategies:
        for s in servers:
            for h in altitudes_km:
                cfg = dataclasses.replace(base, num_servers=s, altitude_km=h)
                out.append(worst_case_latency(strat, cfg))
    return out


# ---------------------------------------------------------------------------
# Figs 1-2: intra-plane one-hop ISL latency vs (M, h).
# ---------------------------------------------------------------------------

def intra_plane_latency_s(sats_per_plane: int, altitude_km: float) -> float:
    """One-hop intra-plane latency at an (M, h) point -- delegates to the
    cached single-source helper in ``core.constellation``."""
    return one_hop_intra_plane_latency_s(sats_per_plane, altitude_km)


def isl_latency_grid(
    ms: tuple[int, ...] = (10, 20, 30, 40, 50, 70, 100),
    altitudes_km: tuple[float, ...] = (160, 550, 1000, 1500, 2000),
) -> list[tuple[int, float, float]]:
    return [
        (m, h, intra_plane_latency_s(m, h)) for m in ms for h in altitudes_km
    ]


def memory_tier_for_latency(latency_s: float) -> str:
    """Classify a latency into the paper's Table-1 hierarchy."""
    for name, (lo, hi) in MEMORY_HIERARCHY_S.items():
        if lo <= latency_s <= hi:
            return name
    # Between tiers: report the pair it falls between.
    tiers = sorted(MEMORY_HIERARCHY_S.items(), key=lambda kv: kv[1][0])
    prev = tiers[0][0]
    for name, (lo, _) in tiers:
        if latency_s < lo:
            return f"between {prev} and {name}"
        prev = name
    return prev


def required_sats_per_plane_for(latency_s: float, altitude_km: float) -> int:
    """Smallest M whose one-hop intra-plane latency is below ``latency_s``."""
    for m in range(2, 10_000):
        if intra_plane_latency_s(m, altitude_km) <= latency_s:
            return m
    raise ValueError("unreachable latency")
