"""SkyMemory core: the paper's distributed KVC protocol and placement math."""
from repro.core.constellation import (
    C_KM_S,
    R_EARTH_KM,
    ConstellationSpec,
    LosWindow,
    Sat,
)
from repro.core.hashing import NULL_HASH, chain_hashes, hash_block, split_token_blocks
from repro.core.chunking import (
    arrays_to_bytes,
    bytes_to_arrays,
    bytes_to_dequantized,
    chunk_server,
    join_chunks,
    num_chunks,
    quantized_to_bytes,
    replica_delta,
    split_chunks,
)
from repro.core.faults import (
    FaultEvent,
    FaultInjector,
    FaultPlan,
    FaultState,
    plan_survivable_kills,
)
from repro.core.mapping import Strategy, bounding_box_side, layout_grid, place_servers
from repro.core.migration import Move, migration_planes, plan_migration
from repro.core.protocol import (
    ConstellationKVC,
    ConstellationView,
    IslTransport,
    KVCManager,
    SimClock,
    TransportStats,
)
from repro.core.radix import BlockMeta, RadixBlockIndex
from repro.core.simulator import (
    MEMORY_HIERARCHY_S,
    SimConfig,
    SimResult,
    intra_plane_latency_s,
    isl_latency_grid,
    sweep,
    worst_case_latency,
)
from repro.core.store import SatelliteStore
from repro.core.tpu_cache import TorusGrid, gather_cost_s, migrate_shards

__all__ = [
    "C_KM_S",
    "R_EARTH_KM",
    "ConstellationSpec",
    "LosWindow",
    "Sat",
    "NULL_HASH",
    "chain_hashes",
    "hash_block",
    "split_token_blocks",
    "arrays_to_bytes",
    "bytes_to_arrays",
    "bytes_to_dequantized",
    "chunk_server",
    "join_chunks",
    "num_chunks",
    "quantized_to_bytes",
    "replica_delta",
    "split_chunks",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "FaultState",
    "plan_survivable_kills",
    "Strategy",
    "bounding_box_side",
    "layout_grid",
    "place_servers",
    "Move",
    "migration_planes",
    "plan_migration",
    "ConstellationKVC",
    "ConstellationView",
    "IslTransport",
    "KVCManager",
    "SimClock",
    "TransportStats",
    "BlockMeta",
    "RadixBlockIndex",
    "MEMORY_HIERARCHY_S",
    "SimConfig",
    "SimResult",
    "intra_plane_latency_s",
    "isl_latency_grid",
    "sweep",
    "worst_case_latency",
    "SatelliteStore",
    "TorusGrid",
    "gather_cost_s",
    "migrate_shards",
]
