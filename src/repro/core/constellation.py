"""+GRID 2D-torus LEO constellation model (paper §2, §3.2; Eqs 1-4).

Coordinate convention (matches the paper's simulation section):
  * a satellite is identified by ``Sat(plane, slot)``:
      - ``plane``  -- orbital-plane index, east-west direction, wraps modulo
        ``num_planes`` (the paper's ``s`` / ``N``);
      - ``slot``   -- position within the plane, north-south direction, wraps
        modulo ``sats_per_plane`` (the paper's ``o`` / ``M``).
  * the +GRID torus gives every satellite 4 ISL links: north/south to the
    adjacent slots of its own plane, east/west to the same slot of the
    adjacent planes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterator

R_EARTH_KM = 6371.0
C_KM_S = 299_792.458  # speed of light in vacuum (FSO ISL)


@dataclasses.dataclass(frozen=True, order=True)
class Sat:
    """A satellite position on the torus grid."""

    plane: int  # east-west column
    slot: int   # north-south row within the plane


@dataclasses.dataclass(frozen=True)
class ConstellationSpec:
    """A walker-delta style +GRID constellation (paper §3.2)."""

    num_planes: int        # N
    sats_per_plane: int    # M
    altitude_km: float
    inclination_deg: float = 53.0

    def __post_init__(self) -> None:
        if self.num_planes < 1 or self.sats_per_plane < 1:
            raise ValueError("constellation must have >=1 plane and >=1 sat/plane")
        if self.altitude_km <= 0:
            raise ValueError("altitude must be positive")

    @property
    def num_sats(self) -> int:
        return self.num_planes * self.sats_per_plane

    # -- Eq (1): worst-case distance between adjacent sats in the same plane.
    def intra_plane_distance_km(self) -> float:
        m = self.sats_per_plane
        return (R_EARTH_KM + self.altitude_km) * math.sqrt(
            2.0 * (1.0 - math.cos(2.0 * math.pi / m))
        )

    # -- Eq (2): worst-case distance between adjacent sats of adjacent planes.
    def inter_plane_distance_km(self) -> float:
        n = self.num_planes
        return (R_EARTH_KM + self.altitude_km) * math.sqrt(
            2.0 * (1.0 - math.cos(2.0 * math.pi / n))
        )

    def wrap(self, sat: Sat) -> Sat:
        return Sat(sat.plane % self.num_planes, sat.slot % self.sats_per_plane)

    def all_sats(self) -> Iterator[Sat]:
        for p in range(self.num_planes):
            for s in range(self.sats_per_plane):
                yield Sat(p, s)

    # ------------------------------------------------------------------
    # Torus metric (paper §4 directional distances).
    # ------------------------------------------------------------------
    def d_north(self, slot: int, slot_t: int) -> int:
        m = self.sats_per_plane
        if slot_t < slot:
            return slot - slot_t
        if slot_t > slot:
            return slot + m - slot_t
        return 0

    def d_south(self, slot: int, slot_t: int) -> int:
        m = self.sats_per_plane
        if slot_t > slot:
            return slot_t - slot
        if slot_t < slot:
            return m - slot + slot_t
        return 0

    def d_west(self, plane: int, plane_t: int) -> int:
        n = self.num_planes
        if plane_t < plane:
            return plane - plane_t
        if plane_t > plane:
            return plane + n - plane_t
        return 0

    def d_east(self, plane: int, plane_t: int) -> int:
        n = self.num_planes
        if plane_t > plane:
            return plane_t - plane
        if plane_t < plane:
            return n - plane + plane_t
        return 0

    def torus_delta(self, src: Sat, dst: Sat) -> tuple[int, int]:
        """Signed minimal (d_plane, d_slot) from ``src`` to ``dst``.

        Positive d_plane = east, positive d_slot = south.
        """
        src, dst = self.wrap(src), self.wrap(dst)
        de = self.d_east(src.plane, dst.plane)
        dw = self.d_west(src.plane, dst.plane)
        dn = self.d_north(src.slot, dst.slot)
        ds = self.d_south(src.slot, dst.slot)
        d_plane = de if de <= dw else -dw
        d_slot = ds if ds <= dn else -dn
        return d_plane, d_slot

    def hops(self, src: Sat, dst: Sat) -> int:
        """Minimal number of ISL hops on the +GRID torus (Manhattan)."""
        dp, ds = self.torus_delta(src, dst)
        return abs(dp) + abs(ds)

    def greedy_route(self, src: Sat, dst: Sat) -> list[Sat]:
        """Greedy one-axis-at-a-time route (paper §4), incl. endpoints."""
        src, dst = self.wrap(src), self.wrap(dst)
        path = [src]
        cur = src
        while cur != dst:
            dn = self.d_north(cur.slot, dst.slot)
            ds = self.d_south(cur.slot, dst.slot)
            dw = self.d_west(cur.plane, dst.plane)
            de = self.d_east(cur.plane, dst.plane)
            if 0 < dn <= ds or (ds == 0 and dn > 0):
                step = (0, -1) if dn <= ds else (0, 1)
            elif 0 < ds:
                step = (0, 1)
            elif 0 < dw <= de or (de == 0 and dw > 0):
                step = (-1, 0) if dw <= de else (1, 0)
            elif 0 < de:
                step = (1, 0)
            else:  # pragma: no cover - loop guard
                break
            cur = self.wrap(Sat(cur.plane + step[0], cur.slot + step[1]))
            path.append(cur)
        return path

    # ------------------------------------------------------------------
    # Physical distances / latencies.
    # ------------------------------------------------------------------
    def step_distance_km(self, d_plane: int, d_slot: int) -> float:
        """Eq (3): straight-line ISL distance for a (d_plane, d_slot) offset."""
        dm = self.intra_plane_distance_km()   # along-plane (slot direction)
        dn = self.inter_plane_distance_km()   # across planes
        return math.sqrt((dm * d_slot) ** 2 + (dn * d_plane) ** 2)

    def isl_distance_km(self, src: Sat, dst: Sat) -> float:
        dp, ds = self.torus_delta(src, dst)
        return self.step_distance_km(dp, ds)

    def isl_path_distance_km(self, src: Sat, dst: Sat) -> float:
        """Distance along the greedy +GRID route (one link at a time)."""
        dp, ds = self.torus_delta(src, dst)
        return abs(ds) * self.intra_plane_distance_km() + abs(dp) * (
            self.inter_plane_distance_km()
        )

    def path_latency_s(self, d_plane: int, d_slot: int) -> float:
        """Latency along the greedy +GRID route for a signed torus offset.

        THE single source of truth for routed ISL latency: per-hop
        intra-/inter-plane latencies times hop counts.  ``IslTransport``,
        the analytic simulator sweeps, and the serving router all price
        hops through here (or through the two one-hop scalars below), so
        a replica's hop-awareness score and the latency it later
        experiences come from the same model.
        """
        return (
            abs(d_slot) * self.intra_plane_latency_s()
            + abs(d_plane) * self.inter_plane_latency_s()
        )

    def isl_latency_s(self, src: Sat, dst: Sat, *, routed: bool = True) -> float:
        if routed:
            return self.path_latency_s(*self.torus_delta(src, dst))
        return self.isl_distance_km(src, dst) / C_KM_S

    def slant_range_km(self, ground_offset_km: float) -> float:
        """Eq (4): ground-to-satellite distance for a sub-satellite-point
        offset of ``ground_offset_km`` from the observer."""
        return math.sqrt(ground_offset_km**2 + self.altitude_km**2)

    def uplink_latency_s(self, ground_offset_km: float = 0.0) -> float:
        """Ground-to-overhead-satellite latency (Eq 4 at the given
        sub-satellite-point offset; 0 = directly underneath)."""
        return self.slant_range_km(ground_offset_km) / C_KM_S

    def ground_latency_s(self, sat: Sat, center: Sat) -> float:
        """Latency of a direct ground link to ``sat`` when the observer sits
        under ``center`` (the closest / directly-overhead satellite)."""
        d = self.isl_distance_km(center, sat)  # ground-projected offset
        return self.uplink_latency_s(d)

    def intra_plane_latency_s(self) -> float:
        """Paper Figs 1-2: one-hop intra-plane ISL latency."""
        return self.intra_plane_distance_km() / C_KM_S

    def inter_plane_latency_s(self) -> float:
        """One-hop inter-plane (east-west) ISL latency."""
        return self.inter_plane_distance_km() / C_KM_S


@functools.lru_cache(maxsize=4096)
def one_hop_intra_plane_latency_s(
    sats_per_plane: int, altitude_km: float
) -> float:
    """Figs 1-2 one-hop intra-plane latency for an (M, h) point.

    The analytic sweeps (``core.simulator``) call this in tight loops;
    caching here replaces the throwaway per-call ``ConstellationSpec``
    they used to build and keeps the latency math in this module.
    """
    return ConstellationSpec(
        num_planes=max(sats_per_plane, 2),
        sats_per_plane=sats_per_plane,
        altitude_km=altitude_km,
    ).intra_plane_latency_s()


@dataclasses.dataclass(frozen=True)
class LosWindow:
    """The rectangular LOS region of the grid around a center satellite.

    ``rows`` x ``cols`` box (slots x planes), centered on ``center``.
    """

    center: Sat
    rows: int
    cols: int

    def __post_init__(self) -> None:
        if self.rows < 1 or self.cols < 1:
            raise ValueError("LOS window must be at least 1x1")

    def offsets(self) -> list[tuple[int, int]]:
        """Row-major (d_slot, d_plane) offsets from the window's top-left."""
        return [(r, c) for r in range(self.rows) for c in range(self.cols)]

    def top_left(self, spec: ConstellationSpec) -> Sat:
        return spec.wrap(
            Sat(
                self.center.plane - (self.cols - 1) // 2,
                self.center.slot - (self.rows - 1) // 2,
            )
        )

    def sats(self, spec: ConstellationSpec) -> list[Sat]:
        """Row-major list (left->right, top->bottom) of satellites in LOS."""
        tl = self.top_left(spec)
        return [
            spec.wrap(Sat(tl.plane + c, tl.slot + r)) for r, c in self.offsets()
        ]

    def contains(self, spec: ConstellationSpec, sat: Sat) -> bool:
        dp, ds = spec.torus_delta(self.center, sat)
        return (
            -((self.cols - 1) // 2) <= dp <= self.cols // 2
            and -((self.rows - 1) // 2) <= ds <= self.rows // 2
        )

    def shifted(
        self, spec: ConstellationSpec, d_slot: int = 1, d_plane: int = 0
    ) -> "LosWindow":
        """The window after a rotation step.

        Satellites orbit within their plane, so relative to a ground observer
        the LOS box drifts along the *slot* (within-plane) direction; chunk
        migration is therefore parallel per orbital plane (paper §3.4, Figs
        5/8).  ``d_slot=1`` advances the window by one within-plane position.
        """
        return LosWindow(
            spec.wrap(Sat(self.center.plane + d_plane, self.center.slot + d_slot)),
            self.rows,
            self.cols,
        )
