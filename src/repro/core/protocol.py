"""The SkyMemory Set/Get KVC protocol (paper §3.1, §3.8).

``ConstellationKVC`` is the distributed chunk store spread over the torus:
chunks of a block's payload are striped ``chunk_id mod num_servers`` across
virtual servers placed on satellites by a strategy (``mapping.py``).  All
chunk operations of one block run in parallel, so the modeled latency of a
block set/get is the *max* over its chunk operations (paper §4).

Scale-out additions: a ``SimClock`` gives every Get/Set KVC op a
*completion time* (``IslTransport.last_ready_at``), so serving layers can
defer consuming a fetched payload until its simulated flight is over
instead of treating the constellation as a zero-latency dict.
``ConstellationKVC.view`` hands N serving replicas anchored handles on ONE
shared store: same satellites, directory and eviction policy, but per-view
transports (per-anchor hop costs) and per-view cache stats.

``KVCManager`` is the paper's §3.3 interface bound to a tokenizer and a
KVC-producing model function, with the §3.10 local radix index in front;
``KVCManager.sibling`` binds additional replicas to the same radix index,
recency policy, and lock.
"""
from __future__ import annotations

import random
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.core import migration as migration_mod
from repro.core.chunking import (
    cat_payloads,
    chunk_server,
    is_delta_payload,
    join_chunks,
    num_chunks,
    payload_raw_bytes,
    replica_delta,
    split_chunks,
)
from repro.core.constellation import ConstellationSpec, LosWindow, Sat
from repro.core.directory import StripedDirectory, stripe_of
from repro.core.hashing import chain_hashes, split_token_blocks
from repro.core.mapping import Strategy, place_servers
from repro.core.radix import BlockMeta, RadixBlockIndex
from repro.core.store import SatelliteStore


# ---------------------------------------------------------------------------
# Virtual serving clock.
# ---------------------------------------------------------------------------

class SimClock:
    """The fabric's virtual clock: Get/Set completion times live on it.

    Anchored to the host monotonic clock, so everything that takes real
    time (decode steps, payload deserialization) advances it for free and
    a transport op issued at ``now()`` with latency ``L`` completes at
    ``now() + L``.  ``rate`` compresses virtual time -- at ``rate=10``,
    ten virtual seconds pass per wall second, so tests can simulate long
    ISL flights without sleeping through them.  ``wait_until`` blocks
    (sleeps wall time) until the clock passes a completion time and
    accounts the virtual time spent blocked -- the *experienced* part of
    a fetch the caller could not hide behind useful work.
    """

    def __init__(self, rate: float = 1.0) -> None:
        if rate <= 0.0:
            raise ValueError("clock rate must be positive")
        self.rate = rate
        self._t0 = time.perf_counter()
        self.waited_s = 0.0          # virtual seconds spent blocked
        self.waits = 0
        # one clock is shared by every replica thread of a cluster, so
        # the wait accounting must not lose updates to interleaving
        self._lock = threading.Lock()

    def now(self) -> float:
        """Virtual seconds since the clock was created."""
        return (time.perf_counter() - self._t0) * self.rate

    def wait_until(self, t: float) -> float:
        """Block until virtual time ``t``; returns virtual seconds waited
        (0.0 when ``t`` already passed)."""
        dt = t - self.now()
        if dt <= 0.0:
            return 0.0
        time.sleep(dt / self.rate)
        with self._lock:
            self.waited_s += dt
            self.waits += 1
        return dt


# ---------------------------------------------------------------------------
# Transport cost model.
# ---------------------------------------------------------------------------

@dataclass
class TransportStats:
    """Bounded op-latency record.

    ``op_latencies_s`` is a uniform reservoir over the whole run, capped
    at ``reservoir_size`` samples so a long serving run cannot grow it
    without bound.  Runs shorter than the cap keep every sample in
    arrival order (the pre-reservoir behavior); ``last_latency_s`` /
    ``max_latency_s`` are exact regardless of sampling, and
    ``latency_percentiles`` summarizes the reservoir as p50/p95/p99.
    """

    messages: int = 0
    bytes_moved: int = 0
    # dtype-true bytes the *block payloads* among bytes_moved decode to
    # (codec compression accounting; probe/metadata traffic not included)
    bytes_raw: int = 0
    total_latency_s: float = 0.0
    ops: int = 0
    last_latency_s: float = 0.0
    max_latency_s: float = 0.0
    reservoir_size: int = 512
    op_latencies_s: list[float] = field(default_factory=list)
    _rng: random.Random = field(
        default_factory=lambda: random.Random(0x5EED), repr=False)

    def record(self, latency_s: float) -> None:
        self.ops += 1
        self.total_latency_s += latency_s
        self.last_latency_s = latency_s
        if latency_s > self.max_latency_s:
            self.max_latency_s = latency_s
        if len(self.op_latencies_s) < self.reservoir_size:
            self.op_latencies_s.append(latency_s)
        else:
            j = self._rng.randrange(self.ops)
            if j < self.reservoir_size:
                self.op_latencies_s[j] = latency_s

    def latency_percentiles(self) -> dict[str, float]:
        if not self.op_latencies_s:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        xs = sorted(self.op_latencies_s)
        n = len(xs)
        pick = lambda q: xs[min(n - 1, int(q * (n - 1) + 0.5))]  # noqa: E731
        return {"p50": pick(0.50), "p95": pick(0.95), "p99": pick(0.99)}


@dataclass
class IslTransport:
    """Latency accounting for chunk ops; execution itself is in-process.

    ``ground_hosted``: the LLM sits on the ground under the window center
    (one reliable uplink to the closest satellite, then ISL routing) --
    paper's rotation / rotation+hop scenario.  Otherwise the LLM is on board
    the center satellite (hop-aware scenario) and only ISL legs apply.

    ``anchor``: the satellite this transport's ops originate from -- a
    serving replica's attachment point on the torus.  ``None`` keeps the
    single-engine behavior (ops originate at the LOS window center).

    ``clock``: optional ``SimClock``.  When set, ``record_op`` stamps
    ``last_ready_at = clock.now() + latency`` -- the op's completion time
    -- so callers can defer consuming the result until the flight is over
    (and overlap the flight with other work) instead of experiencing the
    constellation as a free local dict.

    ``probe_timeout_s``: the explicit cost of one FAILED replica attempt
    (a dead or partitioned home that never answers).  ``None`` keeps the
    implicit model -- a failed probe charges the 0-byte round trip it
    would have taken -- while a value models a real timeout budget.  The
    Get fall-through and ``estimate_get_latency_s`` both price failed
    attempts through ``probe_latency_s``, so the router prices exactly
    what the fetch pays.
    """

    spec: ConstellationSpec
    ground_hosted: bool = True
    chunk_processing_time_s: float = 0.0
    link_bandwidth_bytes_s: float | None = None
    anchor: Sat | None = None
    clock: SimClock | None = None
    stats: TransportStats = field(default_factory=TransportStats)
    last_ready_at: float | None = field(default=None, repr=False)
    probe_timeout_s: float | None = None

    def src_for(self, center: Sat) -> Sat:
        return self.anchor if self.anchor is not None else center

    def _isl_leg_s(self, src: Sat, target: Sat, faults) -> float:
        """One-way ISL latency of the route an op actually runs: the
        clean greedy path, or -- under link faults -- the cheapest
        detour (``FaultState.route_hops``).  A partitioned pair falls
        back to the clean-path price: the op itself is already failed by
        reachability, this only prices its timed-out probe."""
        if faults is not None and faults.dead_links:
            lat = faults.routed_latency_s(self.spec, src, target)
            if lat is not None:
                return lat
        return self.spec.isl_latency_s(src, target, routed=True)

    def op_latency_s(
        self, src: Sat, target: Sat, n_bytes: int, *,
        round_trip: bool, faults=None,
    ) -> float:
        """Pure cost model -- no accounting.  The serving router calls
        this to *estimate* fetch costs from candidate anchors without
        polluting transport stats.  ``faults`` (a ``FaultState``) prices
        the ISL leg over the detoured route killed links force."""
        lat = 0.0
        if self.ground_hosted:
            lat += self.spec.uplink_latency_s()
        lat += self._isl_leg_s(src, target, faults)
        if round_trip:
            lat *= 2.0
        lat += self.chunk_processing_time_s
        if self.link_bandwidth_bytes_s:
            lat += n_bytes / self.link_bandwidth_bytes_s
        return lat

    def probe_latency_s(self, src: Sat, target: Sat, *, faults=None) -> float:
        """Cost of one failed replica attempt (dead/partitioned home):
        the explicit ``probe_timeout_s`` when configured, else the
        timed-out 0-byte round trip the attempt would have taken."""
        if self.probe_timeout_s is not None:
            return self.probe_timeout_s
        return self.op_latency_s(src, target, 0, round_trip=True,
                                 faults=faults)

    def chunk_op_latency_s(
        self, center: Sat, target: Sat, n_bytes: int, *,
        round_trip: bool, faults=None,
    ) -> float:
        lat = self.op_latency_s(
            self.src_for(center), target, n_bytes, round_trip=round_trip,
            faults=faults)
        self.stats.messages += 1
        self.stats.bytes_moved += n_bytes
        return lat

    def chunk_probe_latency_s(self, center: Sat, target: Sat, *,
                              faults=None) -> float:
        """Accounting flavor of ``probe_latency_s`` (data-plane failed
        attempts bump the message counter like any other chunk op)."""
        lat = self.probe_latency_s(self.src_for(center), target,
                                   faults=faults)
        self.stats.messages += 1
        return lat

    def record_op(self, latency_s: float) -> float | None:
        """Account one block-level op; returns (and remembers) its
        completion time on the clock, or None when unclocked."""
        self.stats.record(latency_s)
        self.last_ready_at = (
            None if self.clock is None else self.clock.now() + latency_s)
        return self.last_ready_at


# ---------------------------------------------------------------------------
# Distributed constellation-hosted KVC.
# ---------------------------------------------------------------------------

@dataclass
class CacheStats:
    block_hits: int = 0
    block_misses: int = 0
    blocks_set: int = 0
    blocks_purged: int = 0
    migrations: int = 0
    lookup_probes: int = 0
    # fault tolerance (k-replica placement + churn):
    degraded_reads: int = 0   # ops served only after dead-replica fallthrough
    lost_blocks: int = 0      # blocks with an unrecoverable chunk (purged)
    repaired_chunks: int = 0  # chunk copies re-replicated by repair passes
    # graded link faults (detours) + the L3 ground tier:
    detoured_ops: int = 0     # chunk ops completed over a rerouted path
    detour_hops: int = 0      # extra hops those detours cost, summed
    ground_hits: int = 0      # ops answered by the ground tier fall-through
    ground_spills: int = 0    # orbit-evicted blocks demoted to ground
    repaired_from_ground: int = 0  # blocks re-replicated from ground
    # decentralized directory (striped metadata on the fabric):
    dir_lookups: int = 0      # priced directory lookups issued
    degraded_lookups: int = 0  # lookups that probed >=1 dead stripe home
    dir_repaired_entries: int = 0  # entry copies rewritten by reconcile()
    orphaned_chunks: int = 0  # inventoried chunks with no provable entry
    shortened_prefixes: int = 0  # index prefixes walked back at Get time
    # payload codec (quantized / delta-encoded block payloads): what the
    # fabric actually shipped vs what those bytes decode to -- the
    # compression the ISL bandwidth and satellite capacity never paid
    bytes_encoded: int = 0    # block payload bytes moved (Set + served Get)
    bytes_raw: int = 0        # dtype-true bytes those payloads decode to


def _note_codec_bytes(cs: "CacheStats", tr: "IslTransport",
                      payload: bytes) -> None:
    """Account one block payload's encoded-vs-raw size (a header-only
    scan; nothing dequantizes) on the cache and transport stats."""
    raw = payload_raw_bytes(payload)
    cs.bytes_encoded += len(payload)
    cs.bytes_raw += raw
    tr.stats.bytes_raw += raw


# ---------------------------------------------------------------------------
# L3: the durable ground-station tier below the constellation.
# ---------------------------------------------------------------------------

@dataclass
class GroundStats:
    puts: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_stored: int = 0


class GroundStationTier:
    """A bigger, slower, durable block store below the constellation.

    The MegaCacheX-style hierarchical tier: whole payloads keyed by
    block hash (no striping -- ground stations are not satellites), with
    capacity counted in *blocks* and LRU eviction when bounded
    (``capacity_blocks=None`` = unbounded: durable by construction).
    The station sits under the LOS window center, so an op from a
    serving anchor runs anchor -> center over the ISLs (detour-priced
    under link faults, like any chunk op) and then one Eq-4 downlink leg
    -- ``op_latency_s`` prices the round trip on the same transport
    model / ``SimClock`` the orbital ops complete on, plus the tier's
    own (slower) processing and bandwidth terms.

    ``ConstellationKVC`` attaches one via ``ground=`` / ``attach_ground``
    and its ``ground_write`` policy decides what lands here; Gets fall
    through replicas -> ground -> clean miss, and ``repair`` re-seeds
    orbital copies from here when no replica survived.
    """

    def __init__(
        self,
        spec: ConstellationSpec,
        *,
        capacity_blocks: int | None = None,
        processing_time_s: float = 0.0,
        link_bandwidth_bytes_s: float | None = None,
    ) -> None:
        if capacity_blocks is not None and capacity_blocks < 1:
            raise ValueError("ground capacity must be >= 1 block (or None)")
        self.spec = spec
        self.capacity_blocks = capacity_blocks
        self.processing_time_s = processing_time_s
        self.link_bandwidth_bytes_s = link_bandwidth_bytes_s
        self.stats = GroundStats()
        self._blocks: "OrderedDict[bytes, bytes]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._blocks)

    def __contains__(self, block_hash: bytes) -> bool:
        return block_hash in self._blocks

    # -- cost model -----------------------------------------------------
    def op_latency_s(
        self, transport: IslTransport, center: Sat, n_bytes: int, *,
        round_trip: bool = True, faults=None,
    ) -> float:
        """One ground-tier op from ``transport``'s origin: the ISL path
        to the window center (0 bytes -- the tier's own bandwidth term
        prices the payload) plus the downlink to the station under it,
        doubled for a round trip, plus ground processing."""
        lat = transport.op_latency_s(
            transport.src_for(center), center, 0,
            round_trip=round_trip, faults=faults)
        leg = self.spec.uplink_latency_s()
        lat += leg * (2.0 if round_trip else 1.0)
        lat += self.processing_time_s
        if self.link_bandwidth_bytes_s:
            lat += n_bytes / self.link_bandwidth_bytes_s
        return lat

    # -- storage --------------------------------------------------------
    def put(self, block_hash: bytes, payload: bytes) -> None:
        """Durable write (write-through or spill).  Re-putting a known
        hash refreshes recency only -- content addressing makes the
        bytes identical."""
        if block_hash in self._blocks:
            self._blocks.move_to_end(block_hash)
            return
        self._blocks[block_hash] = payload
        self.stats.puts += 1
        self.stats.bytes_stored += len(payload)
        if self.capacity_blocks is not None:
            while len(self._blocks) > self.capacity_blocks:
                _, victim = self._blocks.popitem(last=False)
                self.stats.evictions += 1
                self.stats.bytes_stored -= len(victim)

    def get(self, block_hash: bytes) -> bytes | None:
        """Data-plane read: counts hit/miss, refreshes recency."""
        payload = self._blocks.get(block_hash)
        if payload is None:
            self.stats.misses += 1
            return None
        self._blocks.move_to_end(block_hash)
        self.stats.hits += 1
        return payload

    def peek(self, block_hash: bytes) -> bytes | None:
        """Control-plane read (repair): no stats, no recency."""
        return self._blocks.get(block_hash)

    def contains(self, block_hash: bytes) -> bool:
        return block_hash in self._blocks

    def delete(self, block_hash: bytes) -> bool:
        """Explicit invalidation (purge gossip reaching the ground)."""
        payload = self._blocks.pop(block_hash, None)
        if payload is None:
            return False
        self.stats.bytes_stored -= len(payload)
        return True


class ConstellationKVC:
    """Chunk store striped over the constellation with rotation migration.

    ``replication`` stores ``k`` copies of every chunk: replica 0 on the
    chunk's server satellite, replica ``r`` offset by
    ``chunking.replica_delta`` (plane-diverse while ``k <= num_planes``,
    always a distinct satellite).  Reads fall through dead replicas
    (``degraded_reads``), charging the experienced latency of every
    failed attempt; ``repair`` re-replicates surviving copies after
    churn.  Fault sources attach via ``attach_faults`` (see
    ``core.faults.FaultInjector``); with none attached every path is
    byte-identical to the fault-free protocol.

    ``ground`` attaches a durable ``GroundStationTier`` below the
    constellation.  ``ground_write`` decides what lands there:
    ``"none"`` (reads may still fall through to externally seeded
    content), ``"spill"`` (only orbit-evicted victims are demoted down),
    or ``"all"`` (write-through: every Set also lands on ground, so
    total orbital loss is never data loss).  Gets fall through replicas
    -> ground -> clean miss, and ``repair`` re-replicates from ground
    when no orbital copy survived -- a block is only purged through
    ``on_block_lost`` when ground misses too.
    """

    GROUND_WRITE_POLICIES = ("none", "spill", "all")

    def __init__(
        self,
        spec: ConstellationSpec,
        window: LosWindow,
        strategy: Strategy = Strategy.ROTATION_HOP,
        *,
        num_servers: int | None = None,
        chunk_bytes: int = 6 * 1024,
        per_sat_capacity_bytes: int | None = None,
        transport: IslTransport | None = None,
        replication: int = 1,
        dir_replication: int | None = None,
        ground: "GroundStationTier | None" = None,
        ground_write: str = "none",
    ) -> None:
        self.spec = spec
        self.window = window
        self.strategy = strategy
        self.num_servers = num_servers or (window.rows * window.cols)
        self.chunk_bytes = chunk_bytes
        self.transport = transport or IslTransport(spec)
        self.stats = CacheStats()
        if not 1 <= replication <= spec.num_sats:
            raise ValueError(
                f"replication must be in [1, {spec.num_sats}] "
                f"(got {replication})")
        self.replication = replication
        if dir_replication is None:
            dir_replication = replication
        if not 1 <= dir_replication <= spec.num_sats:
            raise ValueError(
                f"dir_replication must be in [1, {spec.num_sats}] "
                f"(got {dir_replication})")
        self.dir_replication = dir_replication
        self.ground: GroundStationTier | None = None
        self.ground_write = "none"
        # blocks deliberately demoted to ground-only residency (capacity
        # spills): repair must not re-promote them -- the orbit evicted
        # them for a reason -- but Gets keep serving them from below
        self._ground_demoted: set[bytes] = set()
        if ground is not None:
            self.attach_ground(ground, write=ground_write)
        elif ground_write != "none":
            raise ValueError("ground_write needs a ground tier attached")
        self.server_map: list[Sat] = place_servers(
            strategy, spec, window, self.num_servers
        )
        self._stores: dict[Sat, SatelliteStore] = {}
        self._capacity = per_sat_capacity_bytes
        self.policy = None   # shared LRU clock, injected via adopt_policy
        # Block metadata lives ON the fabric: ``block_hash -> n_chunks``
        # entries are striped over the satellites (stripe home =
        # hash-derived server, ``dir_replication`` plane-diverse copies)
        # and die with their hosts.  ``_known_blocks`` is this client's
        # own journal of what it ever registered -- control-plane
        # bookkeeping (sweeps, the purge/lost decision, prefetch), never
        # consulted by a priced data-plane lookup.
        self._dir = StripedDirectory()
        self._known_blocks: dict[bytes, int] = {}
        self.on_block_lost: Callable[[bytes], None] | None = None
        self.injector = None  # core.faults.FaultInjector, via attach_faults
        self._repaired_at_event = -1   # rotate-repair gating

    # -- plumbing ------------------------------------------------------
    def adopt_policy(self, policy) -> None:
        """Share a recency clock (``core.eviction.LRUClock``) with every
        satellite store, present and future, so L2 victim selection sees
        the same access timeline as the host-side tiers (radix index, L1
        page cache)."""
        self.policy = policy
        for store in self._stores.values():
            store.policy = policy

    def store_for(self, sat: Sat) -> SatelliteStore:
        sat = self.spec.wrap(sat)
        if sat not in self._stores:
            self._stores[sat] = SatelliteStore(
                capacity_bytes=self._capacity, on_evict=self._on_evict,
                policy=self.policy,
            )
        return self._stores[sat]

    def attach_ground(self, tier: "GroundStationTier",
                      write: str = "all") -> None:
        """Attach the durable L3 tier with a write policy (see class
        docstring).  Callable after construction so benchmarks can run
        the same fabric with and without a ground segment."""
        if write not in self.GROUND_WRITE_POLICIES:
            raise ValueError(
                f"ground_write must be one of {self.GROUND_WRITE_POLICIES} "
                f"(got {write!r})")
        self.ground = tier
        self.ground_write = write

    def _ground_latency_s(self, tr: IslTransport, n_bytes: int, *,
                          round_trip: bool = True) -> float:
        return self.ground.op_latency_s(
            tr, self.center, n_bytes, round_trip=round_trip,
            faults=self.faults)

    def _on_evict(self, store: SatelliteStore, key: tuple[bytes, int],
                  value: bytes) -> None:
        """LRU eviction of one chunk invalidates its whole block (§3.9)
        -- unless the ground tier holds (or, under ``ground_write=
        "spill"``, receives) the payload, in which case the block is
        *demoted*: orbital chunks dropped, directory entry kept, and
        Gets fall through to ground instead of recomputing."""
        block_hash, cid = key
        if self.ground is not None and block_hash in self._known_blocks:
            if self.ground.contains(block_hash):
                self._demote_to_ground(block_hash)
                return
            if self.ground_write == "spill":
                payload = self._reassemble(block_hash, cid, value)
                if payload is not None:
                    self.ground.put(block_hash, payload)
                    self.stats.ground_spills += 1
                    self.transport.stats.messages += 1
                    self.transport.stats.bytes_moved += len(payload)
                    self._demote_to_ground(block_hash)
                    return
        self.purge_block(block_hash)

    def _reassemble(self, block_hash: bytes, evicted_cid: int,
                    evicted_value: bytes) -> bytes | None:
        """Rebuild a full payload from surviving orbital chunk copies
        (plus the just-evicted one, already out of its store).  Returns
        None when any chunk has no copy left -- then there is nothing
        whole to spill and the eviction degenerates to a purge."""
        n_chunks = self._known_blocks[block_hash]
        chunks: list[bytes] = []
        for cid in range(n_chunks):
            if cid == evicted_cid:
                chunks.append(evicted_value)
                continue
            sid = chunk_server(cid, self.num_servers)
            chunk = None
            for r in range(self.replication):
                chunk = self.store_for(self.replica_sat(sid, r)).peek(
                    (block_hash, cid))
                if chunk is not None:
                    break
            if chunk is None:
                return None
            chunks.append(chunk)
        return join_chunks(chunks)

    def _demote_to_ground(self, block_hash: bytes) -> None:
        """Drop a block's orbital chunks but keep it servable: the
        directory entry stays (ground holds the bytes), no
        ``on_block_lost`` fires, and repair skips it until a fresh Set
        re-promotes it."""
        self._ground_demoted.add(block_hash)
        for store in self._stores.values():
            for key in [k for k in store.keys() if k[0] == block_hash]:
                store.delete(key)

    def server_sat(self, server_id0: int) -> Sat:
        return self.server_map[server_id0]

    def _offset_sat(self, base: Sat, replica: int) -> Sat:
        if replica == 0:
            return base
        dp, ds = replica_delta(
            replica, self.spec.num_planes, self.spec.sats_per_plane)
        return self.spec.wrap(Sat(base.plane + dp, base.slot + ds))

    def replica_sat(self, server_id0: int, replica: int = 0) -> Sat:
        """Home satellite of replica ``replica`` of server
        ``server_id0``'s chunks (replica 0 = the server's own satellite).
        Derived from the live ``server_map``, so rotation migration moves
        every replica's home along with its server.  Directory stripes
        use the same geometry: stripe ``sid`` replica ``r`` lives here
        too (metadata moves with the server it describes)."""
        return self._offset_sat(self.server_map[server_id0], replica)

    # -- the decentralized directory (metadata plane) -------------------
    @property
    def directory(self) -> dict[bytes, int]:
        """Control-plane merged view of the block metadata: the client's
        journal plus every surviving stripe shard.  This is what sweeps,
        gossip-cost models and tests read; it is *free* and therefore
        never consulted by a data-plane op -- ``get_block``/``has_block``
        resolve ``n_chunks`` through the priced stripe walk
        (``_dir_lookup``), which really does lose entries when every
        shard replica dies."""
        merged = dict(self._known_blocks)
        merged.update(self._dir.entries())
        return merged

    def dir_shard_len(self, sat: Sat) -> int:
        """Entry count of the directory shard hosted by ``sat``."""
        return self._dir.shard_len(self.spec.wrap(sat))

    def _replica_order(self, sid: int, src: Sat, tr: IslTransport,
                       f, k: int) -> list[int]:
        """Swarm read order: replica indices of server/stripe ``sid``
        sorted by the round-trip price ``src`` would pay to each home
        (ties by replica index, so a single-replica fabric reduces to
        placement order).  Shared by the Get fall-through, presence
        probes, directory lookups and ``estimate_get_latency_s``, so the
        router prices exactly the walk the fetch will run.  Dead homes
        are NOT filtered: liveness is only learned by paying the probe,
        so a cheap-but-dead home is charged before the cheapest live
        one -- precisely what the estimator prices."""
        if k == 1:
            return [0]
        costs = sorted(
            (tr.op_latency_s(src, self.replica_sat(sid, r), 0,
                             round_trip=True, faults=f), r)
            for r in range(k))
        return [r for _, r in costs]

    def _fallthrough_cost_s(
        self, sid: int, src: Sat, tr: IslTransport, f, k: int,
        n_bytes: int,
    ) -> tuple[float, bool]:
        """Pure price of one replica fall-through walk from ``src``:
        every dead home charges its timed-out probe, the first reachable
        home answers a round trip of ``n_bytes``.  Returns
        ``(latency_s, served)`` -- ``served`` False when every home is
        out (the caller prices the ground leg or declares the op
        unreachable).  No accounting: this is the estimator's half of
        the estimate/fetch agreement."""
        lat = 0.0
        for r in self._replica_order(sid, src, tr, f, k):
            sat = self.replica_sat(sid, r)
            if self._reachable(src, sat):
                lat += tr.op_latency_s(src, sat, n_bytes,
                                       round_trip=True, faults=f)
                return lat, True
            lat += tr.probe_latency_s(src, sat, faults=f)
        return lat, False

    def _dir_lookup(
        self, block_hash: bytes, tr: IslTransport, cs: CacheStats,
    ) -> tuple[int | None, float, bool]:
        """Priced lookup of a block's metadata entry on its stripe.

        Walks the stripe's replica homes in swarm (cheapest-first)
        order, exactly like a degraded data read: a dead or partitioned
        home charges its timed-out probe, a live home answers at its
        real round trip.  A live home *without* the entry falls through
        too -- it may have healed empty after a crash -- and the entry
        is a miss only once every live home answered empty.  Returns
        ``(n_chunks | None, latency_s, unreachable)``; ``unreachable``
        is True only when no home answered at all (genuine partition:
        the metadata may still exist, so callers must not purge on it).
        ``degraded_lookups`` counts lookups that probed at least one
        dead home -- found or not, the metadata plane degraded them."""
        f = self.faults
        src = tr.src_for(self.center)
        sid = stripe_of(block_hash, self.num_servers)
        cs.dir_lookups += 1
        lat = 0.0
        n: int | None = None
        dead_fall = False
        answered = False
        for r in self._replica_order(sid, src, tr, f,
                                     self.dir_replication):
            sat = self.replica_sat(sid, r)
            if not self._reachable(src, sat):
                lat += tr.chunk_probe_latency_s(self.center, sat, faults=f)
                dead_fall = True
                continue
            lat += tr.chunk_op_latency_s(self.center, sat, 0,
                                         round_trip=True, faults=f)
            answered = True
            hit = self._dir.shard(sat).get(block_hash)
            if hit is not None:
                n = hit
                break
        if dead_fall:
            cs.degraded_lookups += 1
        return n, lat, not answered

    def _dir_register(
        self, block_hash: bytes, n_chunks: int, tr: IslTransport,
    ) -> float:
        """Priced register on Set: write the entry to every *reachable*
        stripe replica home (one-way messages, parallel with the data
        writes -- the caller folds the returned worst leg into the Set's
        max).  Dead homes are skipped; ``reconcile`` back-fills them.
        The client always journals the block host-side: it remembers
        what it wrote even when the metadata plane cannot."""
        f = self.faults
        src = tr.src_for(self.center)
        sid = stripe_of(block_hash, self.num_servers)
        self._known_blocks[block_hash] = n_chunks
        worst = 0.0
        for r in range(self.dir_replication):
            sat = self.replica_sat(sid, r)
            if not self._reachable(src, sat):
                continue
            self._dir.shard(sat)[block_hash] = n_chunks
            worst = max(worst, tr.chunk_op_latency_s(
                self.center, sat, 0, round_trip=False, faults=f))
        return worst

    def _dir_unregister(self, block_hash: bytes) -> int | None:
        """Purge-side metadata gossip: drop the entry from every stripe
        home holding it (one message each) and the client journal.
        Modeled as always landing -- a stale entry surviving a missed
        purge would make a later Get charge a full fetch walk, discover
        nothing, and count the block lost, polluting the loss counters
        with blocks that were deliberately purged.  Returns the
        journaled ``n_chunks`` (None when the block was unknown)."""
        n = self._known_blocks.pop(block_hash, None)
        sid = stripe_of(block_hash, self.num_servers)
        for r in range(self.dir_replication):
            sat = self.replica_sat(sid, r)
            if self._dir.shard(sat).pop(block_hash, None) is not None:
                self.transport.stats.messages += 1
        return n

    # -- fault plumbing ------------------------------------------------
    def attach_faults(self, injector) -> None:
        """Bind a ``core.faults.FaultInjector``: its ``FaultState`` gates
        reachability on every chunk op, and ops tick it so scheduled
        kills/heals land at their clock times without a poller thread."""
        self.injector = injector

    @property
    def faults(self):
        return None if self.injector is None else self.injector.state

    def _tick_faults(self) -> None:
        if self.injector is not None:
            self.injector.advance()

    def _reachable(self, src: Sat, sat: Sat) -> bool:
        f = self.faults
        return f is None or f.reachable(self.spec, src, sat)

    def _note_detour(self, cs: CacheStats, src: Sat, sat: Sat) -> None:
        """Account a completed chunk op that ran over a rerouted path
        (killed links on the greedy route): ops keep completing, the
        counters make the grading visible."""
        f = self.faults
        if f is None or not f.dead_links:
            return
        extra = f.extra_hops(self.spec, src, sat)
        if extra > 0:
            cs.detoured_ops += 1
            cs.detour_hops += extra

    def drop_satellite(self, sat: Sat) -> int:
        """A satellite died: its chunk store's contents are destroyed,
        and so is the directory shard it hosted -- metadata is fabric
        state and does not outlive its satellite.

        Not an eviction -- no ``on_evict`` gossip -- because the data
        *may* survive elsewhere: degraded reads fall through to the
        other replicas, degraded lookups to the other stripe homes, and
        ``reconcile`` rebuilds lost shards / re-replicates (or finally
        purges) what the crash orphaned.  Returns the number of chunks
        destroyed (``dir_shard_len`` before the kill tells a fault
        source how many metadata entries died with them)."""
        sat = self.spec.wrap(sat)
        self._dir.drop(sat)
        store = self._stores.get(sat)
        if store is None:
            return 0
        return len(store.pop_all())

    @property
    def center(self) -> Sat:
        return self.window.center

    def view(self, anchor: Sat, *, clock: SimClock | None = None
             ) -> "ConstellationView":
        """A serving replica's anchored handle on this shared store.

        The view shares every byte of storage state (chunk stores,
        directory, server map, eviction policy) with the base, but its
        ops originate from ``anchor`` through the view's own
        ``IslTransport`` -- per-replica hop costs, per-replica transport
        stats, per-replica ``CacheStats`` -- and complete on ``clock``
        (defaulting to the base transport's clock)."""
        base_t = self.transport
        transport = IslTransport(
            self.spec,
            ground_hosted=base_t.ground_hosted,
            chunk_processing_time_s=base_t.chunk_processing_time_s,
            link_bandwidth_bytes_s=base_t.link_bandwidth_bytes_s,
            anchor=self.spec.wrap(anchor),
            clock=clock if clock is not None else base_t.clock,
            probe_timeout_s=base_t.probe_timeout_s,
        )
        return ConstellationView(self, transport)

    def estimate_get_latency_s(
        self,
        anchor: Sat,
        *,
        payload_bytes: int | None = None,
        transport: IslTransport | None = None,
        block_hash: bytes | None = None,
    ) -> float:
        """Predicted Get KVC block latency from ``anchor``: the max
        round-trip chunk op over the chunk servers a block of
        ``payload_bytes`` (default: a full stripe) lands on, plus -- when
        the caller knows which block it will fetch (``block_hash``) --
        the priced directory-stripe lookup that fronts the fetch.  Pure
        -- no stats, no data movement -- this is the router's
        hop-awareness signal, priced by the same swarm walk the fetch
        will run (``_replica_order`` / ``_fallthrough_cost_s``): under
        faults each server is priced as the degraded read would run it
        -- failed probes of dead replicas first (``probe_latency_s``,
        the same explicit timeout the fall-through charges), then the
        cheapest live replica over its detoured route, then -- when
        every replica is out -- the ground tier's round trip.  Detours,
        timeouts, the metadata leg and the ground leg all show up in
        routing scores before any engine experiences them.  Without
        ``block_hash`` the metadata leg is omitted: it is a 0-byte round
        trip every candidate anchor pays alike, so the relative ranking
        the router needs is preserved."""
        self._tick_faults()   # due kills/heals land before pricing
        tr = transport if transport is not None else self.transport
        f = self.faults
        nb = (self.num_servers if payload_bytes is None
              else num_chunks(payload_bytes, self.chunk_bytes))
        servers = {chunk_server(cid, self.num_servers)
                   for cid in range(min(nb, self.num_servers))}
        anchor = self.spec.wrap(anchor)
        pb = (payload_bytes if payload_bytes is not None
              else nb * self.chunk_bytes)
        dir_lat = 0.0
        if block_hash is not None:
            dir_lat, _ = self._fallthrough_cost_s(
                stripe_of(block_hash, self.num_servers), anchor, tr, f,
                self.dir_replication, 0)
        worst = 0.0
        for sid in servers:
            lat, served = self._fallthrough_cost_s(
                sid, anchor, tr, f, self.replication, self.chunk_bytes)
            if not served and self.ground is not None:
                # no orbital copy answerable: the fetch would fall
                # through to ground for the whole payload
                lat += self.ground.op_latency_s(
                    tr, self.center, pb, round_trip=True, faults=f)
            worst = max(worst, lat)
        return dir_lat + worst

    # -- Set KVC (paper §3.8) ------------------------------------------
    def set_block(
        self, block_hash: bytes, payload: bytes, *,
        via: IslTransport | None = None, stats: CacheStats | None = None,
    ) -> BlockMeta:
        """Store (all ``replication`` copies of) every chunk; the block
        latency is the max over the parallel per-copy writes.  Replicas
        whose home is currently dead/unreachable are simply skipped --
        the next ``repair`` pass back-fills them from a surviving copy
        (or, failing that, from ground).  Under ``ground_write="all"``
        the payload also lands on the ground tier, which makes even a
        write whose every orbital copy was refused durable: the block
        registers and Gets fall through to ground until repair
        re-seeds the orbit."""
        tr = via or self.transport
        cs = stats or self.stats
        self._tick_faults()
        f = self.faults
        chunks = split_chunks(payload, self.chunk_bytes)
        src = tr.src_for(self.center)
        worst = 0.0
        complete = True   # every chunk landed at least one copy
        for cid, chunk in enumerate(chunks):
            sid = chunk_server(cid, self.num_servers)
            stored = 0
            for r in range(self.replication):
                sat = self.replica_sat(sid, r)
                if not self._reachable(src, sat):
                    continue
                self.store_for(sat).set((block_hash, cid), chunk)
                stored += 1
                worst = max(
                    worst,
                    tr.chunk_op_latency_s(
                        self.center, sat, len(chunk), round_trip=False,
                        faults=f,
                    ),
                )
                self._note_detour(cs, src, sat)
            complete &= stored > 0
        grounded = False
        if self.ground is not None and self.ground_write == "all":
            # synchronous write-through: the durable copy is part of the
            # Set's critical path, so its (one-way) leg joins the max
            self.ground.put(block_hash, payload)
            tr.stats.messages += 1
            tr.stats.bytes_moved += len(payload)
            worst = max(worst,
                        self._ground_latency_s(tr, len(payload),
                                               round_trip=False))
            grounded = True
        stored_ok = complete or grounded
        if stored_ok:
            # a chunk with zero landed copies makes a purely orbital
            # write a failure: registering it would make the directory
            # (and through it the metrics) claim a block that never
            # existed.  A pre-existing entry for the same hash stays --
            # content addressing makes the old bytes identical to what
            # this write carried.  A grounded write registers even when
            # incomplete: the data exists below, repair promotes it.
            # The register runs in parallel with the chunk writes, so
            # its worst one-way leg joins the Set's max.
            worst = max(worst,
                        self._dir_register(block_hash, len(chunks), tr))
            cs.blocks_set += 1
            _note_codec_bytes(cs, tr, payload)
            self._ground_demoted.discard(block_hash)
        tr.record_op(worst)
        if not stored_ok and block_hash not in self._known_blocks:
            # failed fresh write: drop the partial chunks that did land,
            # or they would linger as orphans no sweep walks (the sweep
            # and repair passes scan the directory, which never learned
            # of this block)
            for cid in range(len(chunks)):
                sid = chunk_server(cid, self.num_servers)
                for r in range(self.replication):
                    self.store_for(self.replica_sat(sid, r)).delete(
                        (block_hash, cid))
        return BlockMeta(
            n_chunks=len(chunks), set_time=time.time(),
            payload_bytes=len(payload), stored=stored_ok,
        )

    # -- Get KVC (paper §3.8) ------------------------------------------
    def _probe_chunk(
        self, block_hash: bytes, cid: int, tr: IslTransport,
        cs: CacheStats, f, src: Sat,
    ) -> tuple[bool, float, bool]:
        """One presence probe with swarm replica fall-through: returns
        ``(present, latency_s, fell_through)``.  A dead home's probe
        times out (``chunk_probe_latency_s``), an empty live home
        answers negatively at its real round trip; either way the next
        cheapest copy is tried.  A positive probe *touches* the chunk's
        LRU clock: a presence check is a use (the caller is about to
        rely on the block), and leaving it unstamped made repeatedly-
        probed blocks look cold and get evicted first."""
        sid = chunk_server(cid, self.num_servers)
        lat = 0.0
        fell = False
        for r in self._replica_order(sid, src, tr, f, self.replication):
            sat = self.replica_sat(sid, r)
            if not self._reachable(src, sat):
                # failed attempt: the probe times out
                lat += tr.chunk_probe_latency_s(self.center, sat, faults=f)
                fell = True
                continue
            lat += tr.chunk_op_latency_s(self.center, sat, 0,
                                         round_trip=True, faults=f)
            store = self.store_for(sat)
            if store.contains((block_hash, cid)):
                store.touch((block_hash, cid))
                self._note_detour(cs, src, sat)
                return True, lat, fell
            fell = True
        return False, lat, fell

    def has_block(
        self, block_hash: bytes, *,
        via: IslTransport | None = None, stats: CacheStats | None = None,
    ) -> bool:
        """Priced presence check: resolve the entry on its directory
        stripe, then probe the block's first AND last chunk at their
        replica homes.  (Chunk 0 alone read as present after a *later*
        chunk died with all its homes -- the false positive that made
        ``lookup_longest`` promise prefixes ``get_block`` could not
        serve.)  The two chunk probes fan out in parallel after the
        lookup, so the op's latency is the lookup plus their max.

        Degraded probes fall through replicas exactly like a degraded
        read (see ``_probe_chunk``).  When the directory entry is
        missing or its stripe unreachable, a ground tier is the
        authority of last resort: one ground round trip answers, and
        absent now means absent from the metadata plane *and* ground.
        A middle chunk lost everywhere can still slip through -- probing
        every chunk would cost a full Get -- but ``get_cache_tokens``
        walks a failed Get back to the longest servable boundary
        (``shortened_prefixes``), so the residue is a shorter prefix,
        never a crash."""
        tr = via or self.transport
        cs = stats or self.stats
        self._tick_faults()
        f = self.faults
        cs.lookup_probes += 1
        src = tr.src_for(self.center)
        n_chunks, lat, _unreach = self._dir_lookup(block_hash, tr, cs)
        present = False
        fell_through = False
        if n_chunks is not None:
            present = True
            probe_worst = 0.0
            for cid in sorted({0, n_chunks - 1}):
                got, plat, pfell = self._probe_chunk(
                    block_hash, cid, tr, cs, f, src)
                probe_worst = max(probe_worst, plat)
                fell_through |= pfell
                present &= got
            lat += probe_worst
        if not present and self.ground is not None \
                and self.ground.contains(block_hash):
            lat += self._ground_latency_s(tr, 0, round_trip=True)
            tr.stats.messages += 1
            cs.ground_hits += 1
            present = True
        tr.record_op(lat)
        if present and fell_through:
            cs.degraded_reads += 1
        return present

    def get_block(
        self, block_hash: bytes, n_chunks: int | None = None, *,
        via: IslTransport | None = None, stats: CacheStats | None = None,
    ) -> bytes | None:
        """Fetch a block's chunks (all chunks in parallel, so the block
        latency is the max over per-chunk fetch sequences).

        The fetch is fronted by a priced directory lookup on the block's
        metadata stripe (``_dir_lookup``) resolving ``n_chunks``; its
        latency is the sequential prelude to the parallel chunk fan-out.
        A lookup miss is a clean block miss -- unless a ground tier is
        attached, in which case the durable tier is the authority of
        last resort and answers the whole payload (metadata loss is not
        data loss).

        Degraded reads: per chunk, replicas are tried cheapest-first
        (the swarm order ``estimate_get_latency_s`` prices) and every
        failed attempt -- a dead/unreachable home's timed-out probe
        (``probe_latency_s``), or a live home that lost the copy
        answering at its real round trip -- charges *before* the next
        replica is tried, so the experienced latency of a degraded fetch
        really contains the detours; ops over routes with killed links
        pay (and count) their rerouted extra hops.  A chunk with no live
        copy falls through to the ground tier when one is attached: the
        whole payload comes back up at one uplink-priced round trip
        (``ground_hits``) and the block survives.  Only when ground
        misses too does the block fail (§3.1): a clean miss, never an
        exception.  The block is lazily purged only when every replica
        home answered empty AND ground missed (it is *gone*); while a
        home is merely unreachable the metadata keeps its entries -- the
        data may still be there when the fault heals."""
        tr = via or self.transport
        cs = stats or self.stats
        self._tick_faults()
        f = self.faults
        dir_lat = 0.0
        if n_chunks is None:
            n_chunks, dir_lat, _unreach = self._dir_lookup(
                block_hash, tr, cs)
            if n_chunks is None:
                if self.ground is not None:
                    payload = self.ground.get(block_hash)
                    if payload is not None:
                        lat = dir_lat + self._ground_latency_s(
                            tr, len(payload), round_trip=True)
                        tr.stats.messages += 1
                        tr.stats.bytes_moved += len(payload)
                        tr.record_op(lat)
                        cs.block_hits += 1
                        cs.ground_hits += 1
                        _note_codec_bytes(cs, tr, payload)
                        return payload
                cs.block_misses += 1
                tr.record_op(dir_lat)
                return None
        src = tr.src_for(self.center)
        chunks: list[bytes] = []
        worst = 0.0
        degraded = False
        for cid in range(n_chunks):
            sid = chunk_server(cid, self.num_servers)
            attempt_s = 0.0
            chunk = None
            unreachable = False
            order = self._replica_order(sid, src, tr, f, self.replication)
            for j, r in enumerate(order):
                sat = self.replica_sat(sid, r)
                if not self._reachable(src, sat):
                    # failed attempt: the probe times out
                    attempt_s += tr.chunk_probe_latency_s(
                        self.center, sat, faults=f)
                    unreachable = True
                    degraded = True
                    continue
                got = self.store_for(sat).get((block_hash, cid))
                if got is None:
                    if j + 1 < len(order):
                        # empty live replica: charge the (answered)
                        # probe and fall through (the copy may have
                        # died with a crash this home has since healed
                        # from)
                        attempt_s += tr.chunk_op_latency_s(
                            self.center, sat, 0, round_trip=True,
                            faults=f)
                        degraded = True
                    continue
                attempt_s += tr.chunk_op_latency_s(
                    self.center, sat, len(got), round_trip=True, faults=f)
                chunk = got
                self._note_detour(cs, src, sat)
                break
            if chunk is None:
                payload = (None if self.ground is None
                           else self.ground.get(block_hash))
                if payload is not None:
                    # replicas -> ground: the durable tier answers with
                    # the whole payload; its round trip stacks on this
                    # chunk's failed attempts (the other chunks' flights
                    # ran in parallel and are already inside `worst`)
                    attempt_s += self._ground_latency_s(
                        tr, len(payload), round_trip=True)
                    tr.stats.messages += 1
                    tr.stats.bytes_moved += len(payload)
                    tr.record_op(dir_lat + max(worst, attempt_s))
                    cs.block_hits += 1
                    cs.ground_hits += 1
                    _note_codec_bytes(cs, tr, payload)
                    if degraded:
                        cs.degraded_reads += 1
                    return payload
                # replicas -> ground -> clean miss (§3.1).
                cs.block_misses += 1
                if not unreachable:
                    # every home answered empty and ground missed too:
                    # unrecoverable
                    self.purge_block(block_hash)
                    cs.lost_blocks += 1
                return None
            worst = max(worst, attempt_s)
            chunks.append(chunk)
        tr.record_op(dir_lat + worst)
        cs.block_hits += 1
        if degraded:
            cs.degraded_reads += 1
        payload = join_chunks(chunks)
        _note_codec_bytes(cs, tr, payload)
        return payload

    def lookup_longest(
        self, hashes: Sequence[bytes], *,
        via: IslTransport | None = None, stats: CacheStats | None = None,
    ) -> int:
        """Binary search for the furthest cached hash (Get steps 3-6).

        The chained-hash prefix property makes presence monotone in the block
        index, so bisect for the rightmost present block.  Returns the number
        of cached prefix blocks (0 = none).
        """
        lo, hi = 0, len(hashes)  # invariant: blocks < lo present
        while lo < hi:
            mid = (lo + hi) // 2
            if self.has_block(hashes[mid], via=via, stats=stats):
                lo = mid + 1
            else:
                hi = mid
        return lo

    # -- eviction (§3.9) -------------------------------------------------
    def purge_block(self, block_hash: bytes) -> int:
        """Gossip-style purge: remove every chunk of the block everywhere
        -- the ground tier included (an invalidation, unlike demotion)
        -- and unregister the entry from its directory stripe (one
        priced message per shard copy dropped)."""
        n = self._dir_unregister(block_hash)
        self._ground_demoted.discard(block_hash)
        removed = 0
        for store in self._stores.values():
            for key in [k for k in store.keys() if k[0] == block_hash]:
                store.delete(key)
                removed += 1
        if self.ground is not None and self.ground.delete(block_hash):
            removed += 1
        if removed or n:
            self.stats.blocks_purged += 1
            if self.on_block_lost is not None:
                self.on_block_lost(block_hash)
        return removed

    def sweep_incomplete(self) -> int:
        """Periodic cleanup: purge blocks with missing chunks (§3.9) --
        under replication, missing means *no replica home* has a copy.
        Blocks the ground tier holds are exempt: they are still
        servable (Get falls through) and repair re-seeds them.  The scan
        walks the client journal -- control-plane housekeeping over what
        this client wrote, not a priced metadata lookup."""
        purged = 0
        for block_hash, n_chunks in list(self._known_blocks.items()):
            ok = all(
                any(
                    self.store_for(
                        self.replica_sat(chunk_server(cid, self.num_servers),
                                         r)
                    ).contains((block_hash, cid))
                    for r in range(self.replication)
                )
                for cid in range(n_chunks)
            )
            if not ok:
                if self.ground is not None \
                        and self.ground.contains(block_hash):
                    continue
                self.purge_block(block_hash)
                purged += 1
        return purged

    # -- anti-entropy reconcile + repair (fault tolerance) -----------------
    def repair(self) -> int:
        """Back-compat name for ``reconcile`` (rotation housekeeping,
        heal hooks and the chaos suite call it by this name).  Returns
        the number of chunk copies re-replicated, as before."""
        return self.reconcile()

    def _reconstruct_n(
        self, block_hash: bytes, slots: dict[int, list[Sat]],
    ) -> int | None:
        """Rebuild a lost directory entry from a chunk inventory alone.

        Provable only when the tail chunk is identifiable: the ground
        tier knows the exact payload length, or the highest inventoried
        chunk is shorter than ``chunk_bytes`` (every non-tail chunk is
        exactly ``chunk_bytes``, so a short chunk IS the tail).  A
        full-size highest chunk proves nothing -- the real tail may have
        died with its homes, and registering a truncated ``n_chunks``
        would serve corrupt payloads -- so those chunks stay orphans."""
        if self.ground is not None:
            gp = self.ground.peek(block_hash)
            if gp is not None:
                return num_chunks(len(gp), self.chunk_bytes)
        max_cid = max(slots)
        for sat in slots[max_cid]:
            tail = self.store_for(sat).peek((block_hash, max_cid))
            if tail is not None and len(tail) < self.chunk_bytes:
                return max_cid + 1
        return None

    def reconcile(self) -> int:
        """Inventory-driven anti-entropy pass, in two phases.

        **Phase 1 -- metadata.**  Every live satellite reports its chunk
        inventory (``SatelliteStore.inventory``, read-only).  Authority
        for directory entries is the union of surviving stripe shards,
        the client journal, and -- for hashes known to neither --
        entries reconstructed from the inventories themselves
        (``_reconstruct_n``): the decentralized replacement for the old
        omniscient directory scan.  Inventoried chunks whose entry
        cannot be proven are deleted and counted (``orphaned_chunks``);
        every reconciled entry is rewritten onto each *live* stripe home
        missing it (``dir_repaired_entries``, one message per copy) --
        this is what rebuilds a wiped directory stripe.

        **Phase 2 -- data.**  The PR-5/6 repair pass over the reconciled
        entries: restore every block to its full replica set by copying
        a surviving chunk copy onto each live replica home that lost (or
        never received) its own.  A chunk with no surviving *orbital*
        copy re-replicates from the ground tier when one holds the
        payload -- ``repaired_from_ground`` counts each block so rescued
        -- and only when ground misses too is the block unrecoverable:
        purged, ``on_block_lost`` fired so the radix index prunes,
        counted in ``stats.lost_blocks``.  Deliberately ground-demoted
        blocks (capacity spills) are skipped: re-promoting them would
        undo the eviction.

        Runs on ``rotate()`` when a fault source is attached, on heal
        events (``FaultInjector(repair_on_heal=True)``), or explicitly.
        Unlike the data-plane ops this is control-plane work: it only
        requires the source and destination satellites to be *alive*
        (background traffic can route around dead ISLs), not the serving
        path's greedy route -- and it must never stamp LRU recency
        (inventories and peeks only).  Returns the number of chunk
        copies re-replicated (also in ``stats.repaired_chunks``)."""
        f = self.faults
        # -- phase 1: reconcile the metadata plane ----------------------
        inv: dict[bytes, dict[int, list[Sat]]] = {}
        for sat, store in self._stores.items():
            if f is not None and not f.sat_alive(sat):
                continue   # a dead satellite cannot report
            for block_hash, cids in store.inventory().items():
                slots = inv.setdefault(block_hash, {})
                for cid in cids:
                    slots.setdefault(cid, []).append(sat)
        entries: dict[bytes, int] = self._dir.entries()
        for block_hash, n in self._known_blocks.items():
            entries.setdefault(block_hash, n)
        for block_hash, slots in list(inv.items()):
            if block_hash in entries:
                continue
            n = self._reconstruct_n(block_hash, slots)
            if n is None:
                # chunks with no provable block: orphans, swept out
                for cid, sats in slots.items():
                    for sat in sats:
                        if self.store_for(sat).delete((block_hash, cid)):
                            self.stats.orphaned_chunks += 1
                del inv[block_hash]
                continue
            entries[block_hash] = n
            self._known_blocks[block_hash] = n
        for block_hash, n in entries.items():
            sid = stripe_of(block_hash, self.num_servers)
            for r in range(self.dir_replication):
                sat = self.replica_sat(sid, r)
                if f is not None and not f.sat_alive(sat):
                    continue
                shard = self._dir.shard(sat)
                if shard.get(block_hash) != n:
                    shard[block_hash] = n
                    self.transport.stats.messages += 1
                    self.stats.dir_repaired_entries += 1
        # -- phase 2: re-replicate the data plane -----------------------
        repaired = 0
        for block_hash, n_chunks in list(entries.items()):
            if block_hash in self._ground_demoted:
                continue
            lost = False
            from_ground = False
            gchunks: list[bytes] | None | bool = None   # lazy, per block
            for cid in range(n_chunks):
                sid = chunk_server(cid, self.num_servers)
                live = [self.replica_sat(sid, r)
                        for r in range(self.replication)
                        if f is None or f.sat_alive(
                            self.replica_sat(sid, r))]
                holders = [sat for sat in live
                           if self.store_for(sat).contains(
                               (block_hash, cid))]
                if not holders:
                    if self.ground is not None and gchunks is None:
                        gp = self.ground.peek(block_hash)
                        gchunks = (split_chunks(gp, self.chunk_bytes)
                                   if gp is not None else False)
                    if gchunks:
                        if not live:
                            # no live home to re-seed right now; the
                            # block stays ground-served (and counted)
                            # until a home heals
                            continue
                        chunk = gchunks[cid]
                        for sat in live:
                            self.store_for(sat).set((block_hash, cid),
                                                    chunk)
                            self.transport.stats.messages += 1
                            self.transport.stats.bytes_moved += len(chunk)
                            repaired += 1
                        from_ground = True
                        continue
                    lost = True
                    break
                missing = [sat for sat in live if sat not in holders]
                if not missing:
                    continue   # full replica set: no read, no LRU touch
                chunk = self.store_for(holders[0]).peek((block_hash, cid))
                for sat in missing:
                    self.store_for(sat).set((block_hash, cid), chunk)
                    self.transport.stats.messages += 1
                    self.transport.stats.bytes_moved += len(chunk)
                    repaired += 1
            if lost:
                self.purge_block(block_hash)
                self.stats.lost_blocks += 1
            elif from_ground:
                self.stats.repaired_from_ground += 1
        self.stats.repaired_chunks += repaired
        return repaired

    # -- predictive prefetch (§3.7, closing remark) -----------------------
    def prefetch_for_rotation(self, block_hash: bytes, steps: int) -> int:
        """Pre-position a block's chunks where they will be needed after
        ``steps`` rotation steps (paper: 'the set of satellites in the LOS
        at that future time is known exactly').

        Copies each chunk to the satellites that will host *all* ``k``
        of its server's replica homes after the rotation (not just
        replica 0 -- a degraded read right after the window arrives
        should find its fall-through copies pre-positioned too);
        harmless double-residency until the window arrives (§3.7).  The
        source is the first live holder in placement order, so a dead
        replica-0 home does not defeat the prefetch; a currently-dead
        *destination* is skipped -- writing into it would resurrect data
        on heal that the dead satellite could never have received (the
        same rule migration applies to copies in transit).  Returns the
        number of chunk copies placed."""
        n_chunks = self._known_blocks.get(block_hash)
        if not n_chunks or self.strategy is Strategy.HOP:
            return 0
        f = self.faults
        # simulate the window/servers 'steps' ahead without moving data
        future_window = self.window
        future_map = list(self.server_map)
        for _ in range(steps):
            nw = future_window.shifted(self.spec, d_slot=1)
            for mv in migration_mod.plan_migration(
                    self.spec, future_window, nw, future_map):
                future_map[mv.server_id - 1] = mv.dst
            future_window = nw
        copied = 0
        for cid in range(n_chunks):
            sid = chunk_server(cid, self.num_servers)
            if self.server_sat(sid) == future_map[sid]:
                continue
            chunk = None
            for r in range(self.replication):
                src = self.replica_sat(sid, r)
                if f is not None and not f.sat_alive(src):
                    continue
                chunk = self.store_for(src).get((block_hash, cid))
                if chunk is not None:
                    break
            if chunk is None:
                continue
            for r in range(self.replication):
                dst = self._offset_sat(future_map[sid], r)
                if dst == self.replica_sat(sid, r):
                    continue
                if f is not None and not f.sat_alive(dst):
                    continue   # no resurrection on heal
                self.store_for(dst).set((block_hash, cid), chunk)
                self.transport.stats.messages += 1
                self.transport.stats.bytes_moved += len(chunk)
                copied += 1
        return copied

    # -- rotation (§3.4) --------------------------------------------------
    def execute_move(self, mv: migration_mod.Move) -> None:
        """Apply one planned migration: move the server's chunks -- every
        replica copy from its old home to the new one -- and repoint the
        server map.  With ``replication == 1`` a server's base home
        cannot cohabit with other servers' data, so the store drains
        wholesale (the seed fast path); replica homes *can* land on other
        servers' satellites, so under replication only this server's
        chunks (``chunk_server(cid) == sid``) are moved."""
        sid0 = mv.server_id - 1
        f = self.faults
        for r in range(self.replication):
            src_store = self.store_for(self._offset_sat(mv.src, r))
            dst = self._offset_sat(mv.dst, r)
            if self.replication == 1:
                items = src_store.pop_all()
            else:
                # peek, not get: migration is data shuffling, not use --
                # promoting every moved chunk on the shared LRU would
                # evict genuinely hot blocks in its place (the k=1
                # pop_all path touches nothing either)
                items = [
                    (key, src_store.peek(key))
                    for key in src_store.keys()
                    if chunk_server(key[1], self.num_servers) == sid0
                ]
                for key, _ in items:
                    src_store.delete(key)
            if f is not None and not f.sat_alive(dst):
                # a dead destination cannot receive the migration: the
                # copies are lost in transit (degraded reads fall through
                # to the other replicas; repair re-replicates once the
                # home -- old or new -- is alive again).  Writing them
                # anyway would "resurrect" data on heal that the dead
                # satellite could never have held.
                continue
            dst_store = self.store_for(dst)
            for key, value in items:
                dst_store.set(key, value)
                self.transport.stats.messages += 1
                self.transport.stats.bytes_moved += len(value)
        # the server's directory stripe rides along: every replica copy
        # of each entry homed on this stripe moves with it (one priced
        # message per entry), under the same dead-destination rule --
        # entries in transit to a dead satellite are dropped; lookups
        # fall through the surviving stripe copies and ``reconcile``
        # rewrites what the move lost.
        for r in range(self.dir_replication):
            src_shard = self._dir.shard(self._offset_sat(mv.src, r))
            moved = [(h, n) for h, n in src_shard.items()
                     if stripe_of(h, self.num_servers) == sid0]
            for h, _ in moved:
                del src_shard[h]
            dst = self._offset_sat(mv.dst, r)
            if f is not None and not f.sat_alive(dst):
                continue
            dst_shard = self._dir.shard(dst)
            for h, n in moved:
                dst_shard[h] = n
                self.transport.stats.messages += 1
        self.server_map[sid0] = mv.dst
        self.stats.migrations += 1

    def rotate(self, steps: int = 1) -> list[migration_mod.Move]:
        """Advance the LOS window ``steps`` within-plane positions and
        migrate chunks of exiting satellites (no-op for HOP: on-board).
        A step ends with a ``repair`` pass when the attached fault
        source has applied events since the last pass or still has live
        faults (active outages let migrations drop copies in transit):
        churn losses are re-replicated as part of the orbital
        housekeeping the window shift already is.  Over a clean fabric
        partial replica sets cannot arise -- set writes every home and
        purges sweep them all -- so the scan is skipped rather than paid
        under the serving lock."""
        self._tick_faults()
        all_moves: list[migration_mod.Move] = []
        for _ in range(steps):
            new_window = self.window.shifted(self.spec, d_slot=1)
            if self.strategy is Strategy.HOP:
                self.window = new_window
                continue
            moves = migration_mod.plan_migration(
                self.spec, self.window, new_window, self.server_map
            )
            for mv in moves:
                self.execute_move(mv)
            self.window = new_window
            all_moves.extend(moves)
            if self.injector is not None and (
                    not self.injector.state.clean
                    or self.injector.stats.events_applied
                    != self._repaired_at_event):
                # partial replica sets only arise from fault events (or,
                # while faults are ACTIVE, from migrations whose dead
                # destinations drop copies in transit) -- an armed-but-
                # quiet injector over a clean fabric has nothing to
                # repair, so skip the directory scan on those steps
                self.repair()
                self._repaired_at_event = (
                    self.injector.stats.events_applied)
        return all_moves


# ---------------------------------------------------------------------------
# Per-replica anchored views over one shared constellation.
# ---------------------------------------------------------------------------

class ConstellationView:
    """An anchored, per-replica facade over a shared ``ConstellationKVC``.

    Storage state -- satellite chunk stores, the block directory, the
    server map, the shared eviction policy -- belongs to the base and is
    visible through every view, so N serving replicas share ONE orbital
    cache.  What is private per view: the ``IslTransport`` (ops originate
    from this view's ``anchor``, so hop costs, completion times, and
    transport stats are the replica's own) and a ``CacheStats`` (per-
    replica hit/miss accounting).  Mutating ops (rotation, purges) always
    go through the base, so views can never diverge.
    """

    def __init__(self, base: ConstellationKVC,
                 transport: IslTransport) -> None:
        self.base = base
        self.transport = transport
        self.stats = CacheStats()

    @property
    def anchor(self) -> Sat:
        return self.transport.src_for(self.base.center)

    # -- shared-state passthrough --------------------------------------
    @property
    def spec(self) -> ConstellationSpec:
        return self.base.spec

    @property
    def window(self) -> LosWindow:
        return self.base.window

    @property
    def strategy(self) -> Strategy:
        return self.base.strategy

    @property
    def num_servers(self) -> int:
        return self.base.num_servers

    @property
    def chunk_bytes(self) -> int:
        return self.base.chunk_bytes

    @property
    def replication(self) -> int:
        return self.base.replication

    @property
    def dir_replication(self) -> int:
        return self.base.dir_replication

    @property
    def faults(self):
        return self.base.faults

    @property
    def ground(self) -> "GroundStationTier | None":
        return self.base.ground

    def repair(self) -> int:
        return self.base.repair()

    def reconcile(self) -> int:
        return self.base.reconcile()

    @property
    def directory(self) -> dict[bytes, int]:
        return self.base.directory

    @property
    def policy(self):
        return self.base.policy

    def adopt_policy(self, policy) -> None:
        self.base.adopt_policy(policy)

    @property
    def on_block_lost(self) -> Callable[[bytes], None] | None:
        return self.base.on_block_lost

    @on_block_lost.setter
    def on_block_lost(self, cb: Callable[[bytes], None] | None) -> None:
        self.base.on_block_lost = cb

    def server_sat(self, server_id0: int) -> Sat:
        return self.base.server_sat(server_id0)

    def store_for(self, sat: Sat) -> SatelliteStore:
        return self.base.store_for(sat)

    def rotate(self, steps: int = 1) -> list[migration_mod.Move]:
        return self.base.rotate(steps)

    def purge_block(self, block_hash: bytes) -> int:
        return self.base.purge_block(block_hash)

    # -- anchored ops --------------------------------------------------
    def set_block(self, block_hash: bytes, payload: bytes) -> BlockMeta:
        return self.base.set_block(block_hash, payload,
                                   via=self.transport, stats=self.stats)

    def has_block(self, block_hash: bytes) -> bool:
        return self.base.has_block(block_hash,
                                   via=self.transport, stats=self.stats)

    def get_block(self, block_hash: bytes,
                  n_chunks: int | None = None) -> bytes | None:
        return self.base.get_block(block_hash, n_chunks,
                                   via=self.transport, stats=self.stats)

    def lookup_longest(self, hashes: Sequence[bytes]) -> int:
        return self.base.lookup_longest(hashes,
                                        via=self.transport, stats=self.stats)

    def estimate_get_latency_s(
        self, *, payload_bytes: int | None = None,
        block_hash: bytes | None = None,
    ) -> float:
        return self.base.estimate_get_latency_s(
            self.anchor, payload_bytes=payload_bytes,
            transport=self.transport, block_hash=block_hash)


# ---------------------------------------------------------------------------
# Paper §3.3 interface.
# ---------------------------------------------------------------------------

# (tokens, past_payload|None, past_len) -> payload bytes for the next block.
KvcFn = Callable[[Sequence[int], bytes | None, int], bytes]


class KVCManager:
    """``init(model, tokenizer) / add_blocks(prompt) / get_cache(prompt)``.

    ``kvc_fn`` computes the serialized KVC payload of one token block given
    the payload covering the preceding blocks -- supplied by the serving
    layer (any model family: K/V lists or SSM state snapshots; the protocol
    only sees bytes).  The §3.10 radix tree indexes block hashes locally so
    lookups usually skip the constellation entirely.

    Scale-out: ``sibling(cache_view)`` binds another serving replica to
    the SAME radix index, recency policy, hash-chain map and lock -- one
    prefix index over one shared constellation, N anchored entry points.
    Every index-mutating / index-reading method takes the (reentrant)
    ``lock``, so sibling replicas may call in concurrently from their own
    threads.
    """

    def __init__(
        self,
        tokenize: Callable[[str], list[int]],
        kvc_fn: KvcFn,
        cache: "ConstellationKVC | ConstellationView",
        *,
        block_size: int = 128,
        use_radix: bool = True,
        policy=None,
        index: RadixBlockIndex | None = None,
        chain_map: dict[bytes, list[bytes]] | None = None,
        lock: "threading.RLock | None" = None,
    ) -> None:
        self.tokenize = tokenize
        self.kvc_fn = kvc_fn
        self.cache = cache
        self.block_size = block_size
        self.use_radix = use_radix
        if policy is None:
            # local import: eviction imports this module at its top level
            from repro.core.eviction import LRUClock

            policy = LRUClock()
        self.policy = policy
        self.index = index if index is not None else RadixBlockIndex(
            policy=policy)
        self.lock = lock if lock is not None else threading.RLock()
        cache.adopt_policy(policy)
        cache.on_block_lost = self._on_block_lost
        self._hash_to_chain: dict[bytes, list[bytes]] = (
            chain_map if chain_map is not None else {})

    def sibling(self, cache: "ConstellationKVC | ConstellationView"
                ) -> "KVCManager":
        """A manager over the same radix index / policy / chain map /
        lock, bound to a different cache handle (typically an anchored
        ``ConstellationView``) -- the per-replica handle in a scale-out
        cluster.  All siblings see one shared prefix index; only
        transport anchoring and stats attribution differ."""
        return KVCManager(
            self.tokenize, self.kvc_fn, cache,
            block_size=self.block_size, use_radix=self.use_radix,
            policy=self.policy, index=self.index,
            chain_map=self._hash_to_chain, lock=self.lock,
        )

    def _on_block_lost(self, block_hash: bytes) -> None:
        with self.lock:
            chain = self._hash_to_chain.pop(block_hash, None)
            if chain is not None:
                self.index.remove(chain)

    # ------------------------------------------------------------------
    def add_blocks(self, prompt: str) -> int:
        """Compute + store the KVC for every uncached full block (Set KVC)."""
        return self.add_blocks_tokens(self.tokenize(prompt))

    def add_blocks_tokens(self, tokens: Sequence[int]) -> int:
        """Token-level Set KVC (serving engines pass their exact, possibly
        truncated token sequence so cache coverage matches what they run).

        The lock is held for index reads and store writes only -- the
        payload computation (one model forward per uncached block) runs
        *outside* it, so sibling replicas keep looking up and writing
        while this replica computes.  A concurrent duplicate therefore
        really misses until the write-back lands (the race prefix-
        affinity routing exists to win); if two replicas compute the same
        block, the second insert overwrites it with identical bytes."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return 0
        blocks = split_token_blocks(tokens, self.block_size)
        with self.lock:
            n_cached, _ = (
                self.index.longest_cached_prefix(hashes)
                if self.use_radix
                else (self.cache.lookup_longest(hashes), None)
            )
            past: bytes | None = None
            if n_cached:
                # lazily-evicted tails (or broken delta chains) shrink
                # the resumable prefix; a None past means recompute all
                past, n_cached = self._fetch_cumulative(hashes, n_cached)
        payloads: list[bytes] = []
        for i in range(n_cached, len(hashes)):
            block_tokens = [t for b in blocks[: i + 1] for t in b]
            payload = self.kvc_fn(block_tokens, past, i * self.block_size)
            payloads.append(payload)
            # a delta payload covers only its own block: the *cumulative*
            # resume state for the next kvc_fn call is the running cat
            if past is not None and is_delta_payload(payload):
                past = cat_payloads([past, payload])
            else:
                past = payload
        if not payloads:
            return 0
        with self.lock:
            metas: list[BlockMeta | None] = [None] * len(hashes)
            stored_upto = len(hashes)
            for i, payload in zip(range(n_cached, len(hashes)), payloads):
                meta = self.cache.set_block(hashes[i], payload)
                if not meta.stored:
                    # the fabric could not land a single copy of some
                    # chunk (total outage on a stripe member): indexing
                    # the hash would create a phantom entry the
                    # directory knows nothing about and no repair pass
                    # could ever prune.  Later blocks of the chain are
                    # unreachable through the radix walk anyway; stop.
                    stored_upto = i
                    break
                metas[i] = meta
                self._hash_to_chain[hashes[i]] = list(hashes[: i + 1])
            if self.use_radix and stored_upto:
                self.index.insert(hashes[:stored_upto], metas[:stored_upto])
        return min(len(payloads), max(0, stored_upto - n_cached))

    def add_precomputed_blocks(
        self,
        tokens: Sequence[int],
        payload_for: Callable[[int], bytes],
    ) -> int:
        """Set KVC for uncached full blocks whose payloads the caller
        already *has* -- ``payload_for(n_blocks)`` returns the serialized
        payload covering blocks ``[0, n_blocks)``.

        This is the swap-tier write path: a preempted sequence's pool
        pages hold the exact K/V of its block-aligned prefix, so spilling
        them to the constellation must not re-run the model the way
        ``add_blocks_tokens`` does -- the bytes are rebuilt from the
        exported pages instead.  Radix indexing and chain hashing are
        identical to the computed path, so later lookups cannot tell the
        difference."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return 0
        with self.lock:
            n_cached, _ = (
                self.index.longest_cached_prefix(hashes)
                if self.use_radix
                else (self.cache.lookup_longest(hashes), None)
            )
            added = 0
            metas: list[BlockMeta | None] = [None] * len(hashes)
            stored_upto = len(hashes)
            for i in range(n_cached, len(hashes)):
                payload = payload_for(i + 1)
                meta = self.cache.set_block(hashes[i], payload)
                if not meta.stored:       # see add_blocks_tokens
                    stored_upto = i
                    break
                metas[i] = meta
                self._hash_to_chain[hashes[i]] = list(hashes[: i + 1])
                added += 1
            if self.use_radix and added:
                self.index.insert(hashes[:stored_upto], metas[:stored_upto])
            return added

    def get_cache(self, prompt: str) -> tuple[bytes | None, int]:
        """Longest-prefix KVC for ``prompt`` (Get KVC).

        Returns ``(payload, n_cached_tokens)``; ``(None, 0)`` on full miss.
        """
        return self.get_cache_tokens(self.tokenize(prompt))

    def get_cache_tokens(
        self, tokens: Sequence[int]
    ) -> tuple[bytes | None, int]:
        """Token-level Get KVC (longest cached prefix of ``tokens``)."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return None, 0
        with self.lock:
            if self.use_radix:
                n, _meta = self.index.longest_cached_prefix(hashes)
            else:
                n = self.cache.lookup_longest(hashes)
            n0 = n
            payload, n = self._fetch_cumulative(hashes, n)
            if payload is not None:
                if n < n0:
                    self._count_shortened_prefix()
                return payload, n * self.block_size
            if n0 > 0:
                self._count_shortened_prefix()
            return None, 0

    def _fetch_cumulative(
        self, hashes: Sequence[bytes], n: int
    ) -> tuple[bytes | None, int]:
        """Payload covering blocks ``[0, n')`` for the largest ``n' <= n``
        the fabric can still serve, walking back on lazy evictions.

        A non-delta payload is cumulative: one Get covers the whole
        prefix.  A delta payload covers only its own block, so the chain
        is fetched back to its nearest cumulative base -- every leg a
        real, priced Get -- and reassembled into a cat container whose
        decode concatenates the segments along the token axis.  A
        missing block below a delta makes everything above it
        unreconstructible: the walk restarts from just under the hole.
        """
        while n > 0:
            segs: list[bytes] = []
            j = n - 1
            while True:
                payload = self.cache.get_block(hashes[j])
                if payload is None:
                    n = j      # blocks >= j are gone or chained onto j
                    break
                segs.append(payload)
                if not is_delta_payload(payload):
                    segs.reverse()
                    return cat_payloads(segs), n
                if j == 0:     # a delta with no base under it: unusable
                    n = 0
                    break
                j -= 1
        return None, 0

    def _count_shortened_prefix(self) -> None:
        """The index/lookup promised a prefix the fabric could not serve
        (e.g. a *later* chunk evicted from every replica while chunk-0
        probes still answered): the walk-back above degraded it to a
        shorter prefix instead of failing.  Count it so serving stats can
        surface the mismatch."""
        stats = getattr(self.cache, "stats", None)
        if stats is not None and hasattr(stats, "shortened_prefixes"):
            stats.shortened_prefixes += 1
