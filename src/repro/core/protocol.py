"""The SkyMemory Set/Get KVC protocol (paper §3.1, §3.8).

``ConstellationKVC`` is the distributed chunk store spread over the torus:
chunks of a block's payload are striped ``chunk_id mod num_servers`` across
virtual servers placed on satellites by a strategy (``mapping.py``).  All
chunk operations of one block run in parallel, so the modeled latency of a
block set/get is the *max* over its chunk operations (paper §4).

``KVCManager`` is the paper's §3.3 interface bound to a tokenizer and a
KVC-producing model function, with the §3.10 local radix index in front.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.core import migration as migration_mod
from repro.core.chunking import chunk_server, join_chunks, split_chunks
from repro.core.constellation import ConstellationSpec, LosWindow, Sat
from repro.core.hashing import chain_hashes, split_token_blocks
from repro.core.mapping import Strategy, place_servers
from repro.core.radix import BlockMeta, RadixBlockIndex
from repro.core.store import SatelliteStore


# ---------------------------------------------------------------------------
# Transport cost model.
# ---------------------------------------------------------------------------

@dataclass
class TransportStats:
    messages: int = 0
    bytes_moved: int = 0
    total_latency_s: float = 0.0
    op_latencies_s: list[float] = field(default_factory=list)


@dataclass
class IslTransport:
    """Latency accounting for chunk ops; execution itself is in-process.

    ``ground_hosted``: the LLM sits on the ground under the window center
    (one reliable uplink to the closest satellite, then ISL routing) --
    paper's rotation / rotation+hop scenario.  Otherwise the LLM is on board
    the center satellite (hop-aware scenario) and only ISL legs apply.
    """

    spec: ConstellationSpec
    ground_hosted: bool = True
    chunk_processing_time_s: float = 0.0
    link_bandwidth_bytes_s: float | None = None
    stats: TransportStats = field(default_factory=TransportStats)

    def chunk_op_latency_s(
        self, center: Sat, target: Sat, n_bytes: int, *, round_trip: bool
    ) -> float:
        lat = 0.0
        if self.ground_hosted:
            lat += self.spec.slant_range_km(0.0) / 299_792.458  # up to center
        lat += self.spec.isl_latency_s(center, target, routed=True)
        if round_trip:
            lat *= 2.0
        lat += self.chunk_processing_time_s
        if self.link_bandwidth_bytes_s:
            lat += n_bytes / self.link_bandwidth_bytes_s
        self.stats.messages += 1
        self.stats.bytes_moved += n_bytes
        return lat

    def record_op(self, latency_s: float) -> None:
        self.stats.total_latency_s += latency_s
        self.stats.op_latencies_s.append(latency_s)


# ---------------------------------------------------------------------------
# Distributed constellation-hosted KVC.
# ---------------------------------------------------------------------------

@dataclass
class CacheStats:
    block_hits: int = 0
    block_misses: int = 0
    blocks_set: int = 0
    blocks_purged: int = 0
    migrations: int = 0
    lookup_probes: int = 0


class ConstellationKVC:
    """Chunk store striped over the constellation with rotation migration."""

    def __init__(
        self,
        spec: ConstellationSpec,
        window: LosWindow,
        strategy: Strategy = Strategy.ROTATION_HOP,
        *,
        num_servers: int | None = None,
        chunk_bytes: int = 6 * 1024,
        per_sat_capacity_bytes: int | None = None,
        transport: IslTransport | None = None,
    ) -> None:
        self.spec = spec
        self.window = window
        self.strategy = strategy
        self.num_servers = num_servers or (window.rows * window.cols)
        self.chunk_bytes = chunk_bytes
        self.transport = transport or IslTransport(spec)
        self.stats = CacheStats()
        self.server_map: list[Sat] = place_servers(
            strategy, spec, window, self.num_servers
        )
        self._stores: dict[Sat, SatelliteStore] = {}
        self._capacity = per_sat_capacity_bytes
        self.policy = None   # shared LRU clock, injected via adopt_policy
        # block hash -> n_chunks for blocks believed stored (server-side dir).
        self.directory: dict[bytes, int] = {}
        self.on_block_lost: Callable[[bytes], None] | None = None

    # -- plumbing ------------------------------------------------------
    def adopt_policy(self, policy) -> None:
        """Share a recency clock (``core.eviction.LRUClock``) with every
        satellite store, present and future, so L2 victim selection sees
        the same access timeline as the host-side tiers (radix index, L1
        page cache)."""
        self.policy = policy
        for store in self._stores.values():
            store.policy = policy

    def store_for(self, sat: Sat) -> SatelliteStore:
        sat = self.spec.wrap(sat)
        if sat not in self._stores:
            self._stores[sat] = SatelliteStore(
                capacity_bytes=self._capacity, on_evict=self._on_evict,
                policy=self.policy,
            )
        return self._stores[sat]

    def _on_evict(self, store: SatelliteStore, key: tuple[bytes, int]) -> None:
        """LRU eviction of one chunk invalidates its whole block (§3.9)."""
        block_hash, _ = key
        self.purge_block(block_hash)

    def server_sat(self, server_id0: int) -> Sat:
        return self.server_map[server_id0]

    @property
    def center(self) -> Sat:
        return self.window.center

    # -- Set KVC (paper §3.8) ------------------------------------------
    def set_block(self, block_hash: bytes, payload: bytes) -> BlockMeta:
        chunks = split_chunks(payload, self.chunk_bytes)
        worst = 0.0
        for cid, chunk in enumerate(chunks):
            sid = chunk_server(cid, self.num_servers)
            sat = self.server_sat(sid)
            self.store_for(sat).set((block_hash, cid), chunk)
            worst = max(
                worst,
                self.transport.chunk_op_latency_s(
                    self.center, sat, len(chunk), round_trip=False
                ),
            )
        self.transport.record_op(worst)
        self.directory[block_hash] = len(chunks)
        self.stats.blocks_set += 1
        return BlockMeta(
            n_chunks=len(chunks), set_time=time.time(), payload_bytes=len(payload)
        )

    # -- Get KVC (paper §3.8) ------------------------------------------
    def has_block(self, block_hash: bytes) -> bool:
        """Probe chunk 0 at its server -- a missing first chunk means the
        block is absent (paper: lookups start at the nearest satellite).

        A positive probe *touches* the chunk's LRU clock: a presence
        check is a use (the caller is about to rely on the block), and
        leaving it unstamped made repeatedly-probed blocks look cold and
        get evicted first -- the staleness the shared policy fixed."""
        self.stats.lookup_probes += 1
        sat = self.server_sat(chunk_server(0, self.num_servers))
        self.transport.record_op(
            self.transport.chunk_op_latency_s(self.center, sat, 0, round_trip=True)
        )
        store = self.store_for(sat)
        present = store.contains((block_hash, 0))
        if present:
            store.touch((block_hash, 0))
        return present

    def get_block(self, block_hash: bytes, n_chunks: int | None = None) -> bytes | None:
        if n_chunks is None:
            n_chunks = self.directory.get(block_hash, 0)
            if n_chunks == 0:
                self.stats.block_misses += 1
                return None
        chunks: list[bytes] = []
        worst = 0.0
        for cid in range(n_chunks):
            sid = chunk_server(cid, self.num_servers)
            sat = self.server_sat(sid)
            chunk = self.store_for(sat).get((block_hash, cid))
            if chunk is None:
                # A single missing chunk fails the block (§3.1); lazy-evict.
                self.stats.block_misses += 1
                self.purge_block(block_hash)
                return None
            worst = max(
                worst,
                self.transport.chunk_op_latency_s(
                    self.center, sat, len(chunk), round_trip=True
                ),
            )
            chunks.append(chunk)
        self.transport.record_op(worst)
        self.stats.block_hits += 1
        return join_chunks(chunks)

    def lookup_longest(self, hashes: Sequence[bytes]) -> int:
        """Binary search for the furthest cached hash (Get steps 3-6).

        The chained-hash prefix property makes presence monotone in the block
        index, so bisect for the rightmost present block.  Returns the number
        of cached prefix blocks (0 = none).
        """
        lo, hi = 0, len(hashes)  # invariant: blocks < lo present
        while lo < hi:
            mid = (lo + hi) // 2
            if self.has_block(hashes[mid]):
                lo = mid + 1
            else:
                hi = mid
        return lo

    # -- eviction (§3.9) -------------------------------------------------
    def purge_block(self, block_hash: bytes) -> int:
        """Gossip-style purge: remove every chunk of the block everywhere."""
        n = self.directory.pop(block_hash, None)
        removed = 0
        for store in self._stores.values():
            for key in [k for k in store.keys() if k[0] == block_hash]:
                store.delete(key)
                removed += 1
        if removed or n:
            self.stats.blocks_purged += 1
            if self.on_block_lost is not None:
                self.on_block_lost(block_hash)
        return removed

    def sweep_incomplete(self) -> int:
        """Periodic cleanup: purge blocks with missing chunks (§3.9)."""
        purged = 0
        for block_hash, n_chunks in list(self.directory.items()):
            ok = all(
                self.store_for(
                    self.server_sat(chunk_server(cid, self.num_servers))
                ).contains((block_hash, cid))
                for cid in range(n_chunks)
            )
            if not ok:
                self.purge_block(block_hash)
                purged += 1
        return purged

    # -- predictive prefetch (§3.7, closing remark) -----------------------
    def prefetch_for_rotation(self, block_hash: bytes, steps: int) -> int:
        """Pre-position a block's chunks where they will be needed after
        ``steps`` rotation steps (paper: 'the set of satellites in the LOS
        at that future time is known exactly').

        Copies each chunk to the satellite that will host its server after
        the rotation; harmless double-residency until the window arrives
        (§3.7).  Returns the number of chunks copied.
        """
        n_chunks = self.directory.get(block_hash)
        if not n_chunks or self.strategy is Strategy.HOP:
            return 0
        # simulate the window/servers 'steps' ahead without moving data
        future_window = self.window
        future_map = list(self.server_map)
        for _ in range(steps):
            nw = future_window.shifted(self.spec, d_slot=1)
            for mv in migration_mod.plan_migration(
                    self.spec, future_window, nw, future_map):
                future_map[mv.server_id - 1] = mv.dst
            future_window = nw
        copied = 0
        for cid in range(n_chunks):
            sid = chunk_server(cid, self.num_servers)
            src, dst = self.server_sat(sid), future_map[sid]
            if src == dst:
                continue
            chunk = self.store_for(src).get((block_hash, cid))
            if chunk is None:
                continue
            self.store_for(dst).set((block_hash, cid), chunk)
            self.transport.stats.messages += 1
            self.transport.stats.bytes_moved += len(chunk)
            copied += 1
        return copied

    # -- rotation (§3.4) --------------------------------------------------
    def rotate(self, steps: int = 1) -> list[migration_mod.Move]:
        """Advance the LOS window ``steps`` within-plane positions and
        migrate chunks of exiting satellites (no-op for HOP: on-board)."""
        all_moves: list[migration_mod.Move] = []
        for _ in range(steps):
            new_window = self.window.shifted(self.spec, d_slot=1)
            if self.strategy is Strategy.HOP:
                self.window = new_window
                continue
            moves = migration_mod.plan_migration(
                self.spec, self.window, new_window, self.server_map
            )
            for mv in moves:
                src_store = self.store_for(mv.src)
                dst_store = self.store_for(mv.dst)
                for key, value in src_store.pop_all():
                    dst_store.set(key, value)
                    self.transport.stats.messages += 1
                    self.transport.stats.bytes_moved += len(value)
                self.server_map[mv.server_id - 1] = mv.dst
                self.stats.migrations += 1
            self.window = new_window
            all_moves.extend(moves)
        return all_moves


# ---------------------------------------------------------------------------
# Paper §3.3 interface.
# ---------------------------------------------------------------------------

# (tokens, past_payload|None, past_len) -> payload bytes for the next block.
KvcFn = Callable[[Sequence[int], bytes | None, int], bytes]


class KVCManager:
    """``init(model, tokenizer) / add_blocks(prompt) / get_cache(prompt)``.

    ``kvc_fn`` computes the serialized KVC payload of one token block given
    the payload covering the preceding blocks -- supplied by the serving
    layer (any model family: K/V lists or SSM state snapshots; the protocol
    only sees bytes).  The §3.10 radix tree indexes block hashes locally so
    lookups usually skip the constellation entirely.
    """

    def __init__(
        self,
        tokenize: Callable[[str], list[int]],
        kvc_fn: KvcFn,
        cache: ConstellationKVC,
        *,
        block_size: int = 128,
        use_radix: bool = True,
        policy=None,
    ) -> None:
        self.tokenize = tokenize
        self.kvc_fn = kvc_fn
        self.cache = cache
        self.block_size = block_size
        self.use_radix = use_radix
        if policy is None:
            # local import: eviction imports this module at its top level
            from repro.core.eviction import LRUClock

            policy = LRUClock()
        self.policy = policy
        self.index = RadixBlockIndex(policy=policy)
        cache.adopt_policy(policy)
        cache.on_block_lost = self._on_block_lost
        self._hash_to_chain: dict[bytes, list[bytes]] = {}

    def _on_block_lost(self, block_hash: bytes) -> None:
        chain = self._hash_to_chain.pop(block_hash, None)
        if chain is not None:
            self.index.remove(chain)

    # ------------------------------------------------------------------
    def add_blocks(self, prompt: str) -> int:
        """Compute + store the KVC for every uncached full block (Set KVC)."""
        return self.add_blocks_tokens(self.tokenize(prompt))

    def add_blocks_tokens(self, tokens: Sequence[int]) -> int:
        """Token-level Set KVC (serving engines pass their exact, possibly
        truncated token sequence so cache coverage matches what they run)."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return 0
        blocks = split_token_blocks(tokens, self.block_size)
        n_cached, _ = (
            self.index.longest_cached_prefix(hashes)
            if self.use_radix
            else (self.cache.lookup_longest(hashes), None)
        )
        past: bytes | None = None
        if n_cached:
            past = self.cache.get_block(hashes[n_cached - 1])
            if past is None:  # lazily evicted under us - recompute all
                n_cached = 0
        added = 0
        metas: list[BlockMeta | None] = [None] * len(hashes)
        for i in range(n_cached, len(hashes)):
            block_tokens = [t for b in blocks[: i + 1] for t in b]
            payload = self.kvc_fn(block_tokens, past, i * self.block_size)
            meta = self.cache.set_block(hashes[i], payload)
            metas[i] = meta
            self._hash_to_chain[hashes[i]] = list(hashes[: i + 1])
            past = payload
            added += 1
        if self.use_radix and added:
            self.index.insert(hashes, metas)
        return added

    def add_precomputed_blocks(
        self,
        tokens: Sequence[int],
        payload_for: Callable[[int], bytes],
    ) -> int:
        """Set KVC for uncached full blocks whose payloads the caller
        already *has* -- ``payload_for(n_blocks)`` returns the serialized
        payload covering blocks ``[0, n_blocks)``.

        This is the swap-tier write path: a preempted sequence's pool
        pages hold the exact K/V of its block-aligned prefix, so spilling
        them to the constellation must not re-run the model the way
        ``add_blocks_tokens`` does -- the bytes are rebuilt from the
        exported pages instead.  Radix indexing and chain hashing are
        identical to the computed path, so later lookups cannot tell the
        difference."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return 0
        n_cached, _ = (
            self.index.longest_cached_prefix(hashes)
            if self.use_radix
            else (self.cache.lookup_longest(hashes), None)
        )
        added = 0
        metas: list[BlockMeta | None] = [None] * len(hashes)
        for i in range(n_cached, len(hashes)):
            payload = payload_for(i + 1)
            metas[i] = self.cache.set_block(hashes[i], payload)
            self._hash_to_chain[hashes[i]] = list(hashes[: i + 1])
            added += 1
        if self.use_radix and added:
            self.index.insert(hashes, metas)
        return added

    def get_cache(self, prompt: str) -> tuple[bytes | None, int]:
        """Longest-prefix KVC for ``prompt`` (Get KVC).

        Returns ``(payload, n_cached_tokens)``; ``(None, 0)`` on full miss.
        """
        return self.get_cache_tokens(self.tokenize(prompt))

    def get_cache_tokens(
        self, tokens: Sequence[int]
    ) -> tuple[bytes | None, int]:
        """Token-level Get KVC (longest cached prefix of ``tokens``)."""
        hashes = chain_hashes(tokens, self.block_size)
        if not hashes:
            return None, 0
        if self.use_radix:
            n, _meta = self.index.longest_cached_prefix(hashes)
        else:
            n = self.cache.lookup_longest(hashes)
        while n > 0:
            payload = self.cache.get_block(hashes[n - 1])
            if payload is not None:
                return payload, n * self.block_size
            n -= 1  # lazy eviction already pruned index; try shorter prefix
        return None, 0
