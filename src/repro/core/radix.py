"""Local radix block index (paper §3.10).

A path-compressed radix tree over *block hash sequences*, kept at the LLM
host.  It answers longest-prefix lookups without touching the constellation
and stores per-block metadata (chunk count, set time) from which the current
chunk locations are computable (rotation is predictable, §3.10).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence


@dataclass
class BlockMeta:
    """Metadata stored for one cached block (paper §3.10).

    ``stored=False`` marks a Set KVC that failed to land a single copy
    of some chunk (total outage on a stripe member): the write is NOT in
    the constellation directory, and callers must not index the hash --
    a phantom index entry would re-probe a block that never existed for
    as long as the outage lasts."""

    n_chunks: int
    set_time: float
    payload_bytes: int = 0
    stored: bool = True
    extra: dict[str, Any] = field(default_factory=dict)


@dataclass
class _Node:
    # Path compression: an edge carries a *sequence* of block hashes.
    edge: tuple[bytes, ...] = ()
    children: dict[bytes, "_Node"] = field(default_factory=dict)
    # meta[i] = metadata for the block ending at edge position i (if cached).
    meta: dict[int, BlockMeta] = field(default_factory=dict)


class RadixBlockIndex:
    """Path-compressed radix tree keyed by chained block hashes.

    ``policy`` is an optional shared recency clock (``core.eviction.
    LRUClock``): every cached block matched by a lookup -- and every
    block inserted -- is stamped on it, so tier victim selection (host
    page cache, satellite stores) sees radix prefix hits as *uses* even
    though they never touch the constellation.  Without the stamp, the
    hottest blocks (the ones the radix answers for locally) look coldest
    to the stores and are evicted first.
    """

    def __init__(self, policy=None) -> None:
        self._root = _Node()
        self._count = 0
        self._policy = policy

    def __len__(self) -> int:
        return self._count

    # ------------------------------------------------------------------
    def insert(self, hashes: Sequence[bytes], metas: Sequence[BlockMeta | None]) -> None:
        """Insert a hash chain; ``metas[i]`` annotates ``hashes[i]`` (None =
        block not cached, path only)."""
        if len(hashes) != len(metas):
            raise ValueError("hashes and metas must align")
        node = self._root
        i = 0
        while i < len(hashes):
            first = hashes[i]
            child = node.children.get(first)
            if child is None:
                child = _Node(edge=tuple(hashes[i:]))
                node.children[first] = child
                for j, m in enumerate(metas[i:]):
                    if m is not None:
                        child.meta[j] = m
                        self._count += 1
                        if self._policy is not None:
                            self._policy.touch(hashes[i + j])
                return
            # Walk the compressed edge.
            edge = child.edge
            k = 0
            while k < len(edge) and i + k < len(hashes) and edge[k] == hashes[i + k]:
                m = metas[i + k]
                if m is not None:
                    if k not in child.meta:
                        self._count += 1
                    child.meta[k] = m
                    if self._policy is not None:
                        self._policy.touch(hashes[i + k])
                k += 1
            if k == len(edge):
                node = child
                i += k
                continue
            # Split the edge at k.
            tail = _Node(
                edge=edge[k:],
                children=child.children,
                meta={p - k: m for p, m in child.meta.items() if p >= k},
            )
            child.edge = edge[:k]
            child.children = {edge[k]: tail}
            child.meta = {p: m for p, m in child.meta.items() if p < k}
            node = child
            i += k
        return

    # ------------------------------------------------------------------
    def longest_cached_prefix(
        self, hashes: Sequence[bytes]
    ) -> tuple[int, BlockMeta | None]:
        """Return (n_blocks, meta) for the longest prefix of ``hashes`` whose
        final block has cached metadata; (0, None) when nothing matches."""
        best_len, best_meta = 0, None
        node = self._root
        i = 0
        while i < len(hashes):
            child = node.children.get(hashes[i])
            if child is None:
                break
            edge = child.edge
            k = 0
            while k < len(edge) and i + k < len(hashes) and edge[k] == hashes[i + k]:
                if k in child.meta:
                    best_len, best_meta = i + k + 1, child.meta[k]
                    if self._policy is not None:
                        self._policy.touch(hashes[i + k])
                k += 1
            if k < len(edge):
                break
            node = child
            i += k
        return best_len, best_meta

    def get(self, hashes: Sequence[bytes]) -> BlockMeta | None:
        """Exact-match metadata for the block ending the given chain."""
        n, meta = self.longest_cached_prefix(hashes)
        return meta if n == len(hashes) else None

    def remove(self, hashes: Sequence[bytes]) -> bool:
        """Remove the metadata of the block ending the chain (lazy eviction)."""
        node = self._root
        i = 0
        while i < len(hashes):
            child = node.children.get(hashes[i])
            if child is None:
                return False
            edge = child.edge
            k = 0
            while k < len(edge) and i + k < len(hashes) and edge[k] == hashes[i + k]:
                k += 1
            if i + k == len(hashes) and k >= 1 and (k - 1) in child.meta:
                del child.meta[k - 1]
                self._count -= 1
                if self._policy is not None:
                    self._policy.forget(hashes[-1])
                return True
            if k < len(edge):
                return False
            node = child
            i += k
        return False
