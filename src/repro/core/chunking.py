"""Chunking + KVC (de)serialization (paper §3.1).

A block's KV-cache payload (several MB even for small models) is split into
fixed-byte chunks; chunk ``i`` maps to virtual server ``i mod num_servers``.
A failed lookup of any single chunk means the block is absent.

Also provides the byte serialization of a KVC block payload -- a list of
numpy arrays (K and V per layer, or SSM state tensors) -- plus the
versioned payload codec layer (paper §5 shipped 8-bit quantized KVC
blocks): a self-describing container that records the codec id and each
array's *source* dtype, so a bf16 KVC dequantizes back to bf16, with

* symmetric int8 per-last-axis-channel scales kept **per block chunk**
  of the token axis (``PayloadCodec.block_tokens``), not per whole
  prefix, so long-prefix outliers don't crush early-block precision;
* optional int4 packing (two nibbles per byte + the same scale table);
* delta encoding for cumulative dense payloads: block *n*'s payload
  carries only its own ``block_size`` tokens plus a back-pointer to
  block *n-1*, turning the O(n)-byte cumulative Set into O(1)
  (``make_delta_payload`` / ``cat_payloads`` reassemble on restore).

Every decoder sniffs the container magic, so f32 (legacy ``SKYM``) and
codec (``SKYC``) payloads coexist on one fabric.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

_MAGIC = b"SKYM"
_VERSION = 1

_CODEC_MAGIC = b"SKYC"
_CODEC_VERSION = 1
# container kinds under the SKYC magic
_KIND_ENC = 1     # quantized array container (codec id + per-array header)
_KIND_DELTA = 2   # back-pointer + inner payload for one block's new tokens
_KIND_CAT = 3     # ordered segments whose decoded arrays concatenate

_CODEC_IDS = {"int8": 1, "int4": 2}
_CODEC_NAMES = {v: k for k, v in _CODEC_IDS.items()}
_QMAX = {"int8": 127.0, "int4": 7.0}
# per-array storage tags inside an ENC container
_STORE_RAW = 0    # verbatim bytes (f32 arrays under codec f32; int pools)
_STORE_Q = 1      # quantized codes + per-(chunk, channel) scale table


def split_chunks(data: bytes, chunk_bytes: int) -> list[bytes]:
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    if not data:
        return [b""]
    return [data[i : i + chunk_bytes] for i in range(0, len(data), chunk_bytes)]


def num_chunks(total_bytes: int, chunk_bytes: int) -> int:
    if total_bytes == 0:
        return 1
    return -(-total_bytes // chunk_bytes)


def join_chunks(chunks: list[bytes]) -> bytes:
    return b"".join(chunks)


def chunk_server(chunk_id: int, num_servers: int) -> int:
    """Virtual server (0-based) for a chunk: chunk_id mod n (paper §3.1).

    This is *replica 0*'s placement.  Under k-replica placement the
    other copies keep the same virtual server but live on satellites
    offset from its home by ``replica_delta`` -- replication changes
    where copies sit on the torus, never which server owns a chunk.
    """
    return chunk_id % num_servers


def replica_delta(
    replica: int, num_planes: int, sats_per_plane: int
) -> tuple[int, int]:
    """Torus offset ``(d_plane, d_slot)`` of replica ``replica``'s home
    satellite from the chunk's base (replica-0) server satellite.

    Replicas walk plane-first: replica ``r`` sits ``r`` planes east of
    the base until the planes are exhausted, then spills one slot south
    and keeps walking planes.  Consequences, both load-bearing for fault
    tolerance:

    * **plane diversity** whenever ``k <= num_planes`` -- every replica
      of a chunk is in a *different orbital plane*, so a whole-plane
      outage (the correlated failure mode: one launch batch, one plane)
      never takes out more than one copy;
    * **distinct satellites** whenever ``k <= num_planes *
      sats_per_plane`` -- no two replicas of a chunk ever share a
      satellite (the placement property the chaos tests check).
    """
    if replica < 0:
        raise ValueError("replica index must be >= 0")
    return replica % num_planes, replica // num_planes


# ---------------------------------------------------------------------------
# KVC payload serialization.
# ---------------------------------------------------------------------------

def _dtype_name(dt: np.dtype) -> bytes:
    """Stable dtype tag; extended floats (bfloat16, ...) go by name since
    their numpy .str is an opaque void type."""
    if dt.kind == "V" or dt.str.startswith("|V"):
        return dt.name.encode()
    return dt.str.encode()


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered by jax

        try:
            return np.dtype(getattr(ml_dtypes, name))
        except (AttributeError, TypeError):
            # a corrupt / truncated header names no dtype at all
            raise ValueError(f"unknown dtype name {name!r}") from None


def arrays_to_bytes(arrays: list[np.ndarray]) -> bytes:
    """Serialize a list of arrays: magic | version | n | per-array header."""
    parts = [_MAGIC, struct.pack("<HI", _VERSION, len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        dt = _dtype_name(a.dtype)
        parts.append(struct.pack("<B", len(dt)))
        parts.append(dt)
        parts.append(struct.pack("<B", a.ndim))
        parts.append(struct.pack(f"<{a.ndim}q", *a.shape))
        raw = a.tobytes()
        parts.append(struct.pack("<q", len(raw)))
        parts.append(raw)
    return b"".join(parts)


def bytes_to_arrays(data: bytes) -> list[np.ndarray]:
    if data[:4] != _MAGIC:
        raise ValueError("not a SkyMemory KVC payload")
    out: list[np.ndarray] = []
    try:
        ver, n = struct.unpack_from("<HI", data, 4)
        if ver != _VERSION:
            raise ValueError(f"unsupported KVC payload version {ver}")
        off = 10
        for _ in range(n):
            (dlen,) = struct.unpack_from("<B", data, off)
            off += 1
            dt = _dtype_from_name(data[off : off + dlen].decode())
            off += dlen
            (ndim,) = struct.unpack_from("<B", data, off)
            off += 1
            shape = struct.unpack_from(f"<{ndim}q", data, off)
            off += 8 * ndim
            (rlen,) = struct.unpack_from("<q", data, off)
            off += 8
            a = np.frombuffer(data[off : off + rlen], dtype=dt).reshape(shape)
            off += rlen
            out.append(a)
    except struct.error as e:
        raise ValueError(f"corrupt KVC payload: {e}") from e
    return out


# ---------------------------------------------------------------------------
# int8 KVC quantization (paper §5 used 8-bit quantized KVC blocks).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantizedArray:
    q: np.ndarray       # int8 values
    scale: np.ndarray   # per-last-axis-channel float32 scale


def quantize_int8(a: np.ndarray) -> QuantizedArray:
    """Symmetric per-channel (last axis) int8 quantization."""
    a = np.asarray(a, dtype=np.float32)
    amax = np.max(np.abs(a), axis=tuple(range(a.ndim - 1)), keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(a / scale), -127, 127).astype(np.int8)
    return QuantizedArray(q=q, scale=scale)


def dequantize_int8(qa: QuantizedArray) -> np.ndarray:
    return qa.q.astype(np.float32) * qa.scale


def quantized_to_bytes(arrays: list[np.ndarray]) -> bytes:
    """Serialize ``arrays`` int8-quantized, recording each array's source
    dtype in the codec header so ``bytes_to_dequantized`` restores it
    exactly (a bf16 KVC comes back bf16, not silently-doubled float32)."""
    return encode_arrays(arrays, PayloadCodec("int8"))


def bytes_to_dequantized(data: bytes) -> list[np.ndarray]:
    """Decode a quantized payload back to (dequantized) arrays.

    New ``SKYC`` payloads restore each array's recorded source dtype;
    legacy ``SKYM`` [q, scale, q, scale, ...] payloads (written before
    the codec header existed) still decode, to float32 as they always
    did -- the pre-header format never recorded the source dtype.
    """
    if data[:4] == _CODEC_MAGIC:
        return decode_payload_arrays(data)
    flat = bytes_to_arrays(data)
    if len(flat) % 2:
        raise ValueError("corrupt quantized payload")
    out = []
    for i in range(0, len(flat), 2):
        out.append(dequantize_int8(QuantizedArray(q=flat[i], scale=flat[i + 1])))
    return out


# ---------------------------------------------------------------------------
# The versioned payload codec layer.
# ---------------------------------------------------------------------------

def _quant_geometry(shape: tuple[int, ...]) -> tuple[int, int, int]:
    """(token_axis, n_tokens, channels) used for per-chunk scale tables.

    KVC payload arrays put the token axis at axis 1 (``[L, T, Hkv, hd]``
    dense K/V, ``[L, T, dc]`` MLA latents) and channels on the last
    axis; lower-rank arrays (SSM snapshots after squeezing) fall back to
    axis 0 -- the segmentation is self-consistent between encode and
    decode either way, which is all correctness needs.
    """
    axis = 1 if len(shape) >= 3 else 0
    return axis, shape[axis], shape[-1]


def _quantize_segmented(
    a: np.ndarray, qmax: float, seg: int
) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-last-axis-channel quantization with one scale row
    per ``seg``-token chunk of the token axis: returns ``(codes, scales)``
    where ``codes`` is int8 in [-qmax, qmax] with ``a``'s shape and
    ``scales`` is float32 ``[n_segs, channels]``."""
    orig_shape = a.shape
    af = np.asarray(a, dtype=np.float32)
    if af.ndim < 2:
        af = af.reshape(1, af.size)
    axis, n_tok, chans = _quant_geometry(af.shape)
    seg = seg if seg and seg > 0 else max(n_tok, 1)
    n_segs = max(1, -(-n_tok // seg)) if n_tok else 1
    scales = np.ones((n_segs, chans), np.float32)
    q = np.zeros(af.shape, np.int8)
    red = tuple(range(af.ndim - 1))
    sl: list[slice] = [slice(None)] * af.ndim
    for s in range(n_segs):
        sl[axis] = slice(s * seg, (s + 1) * seg)
        part = af[tuple(sl)]
        if part.size == 0:
            continue
        amax = np.max(np.abs(part), axis=red, keepdims=True)
        scale = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
        q[tuple(sl)] = np.clip(
            np.round(part / scale), -qmax, qmax).astype(np.int8)
        scales[s] = scale.reshape(chans)
    return q.reshape(orig_shape), scales


def _dequantize_segmented(
    q: np.ndarray, scales: np.ndarray, seg: int, dtype: np.dtype
) -> np.ndarray:
    orig_shape = q.shape
    qf = q.astype(np.float32)
    if qf.ndim < 2:
        qf = qf.reshape(1, qf.size)
    axis, n_tok, chans = _quant_geometry(qf.shape)
    seg = seg if seg and seg > 0 else max(n_tok, 1)
    n_segs = max(1, -(-n_tok // seg)) if n_tok else 1
    if scales.shape != (n_segs, chans):
        raise ValueError("corrupt codec payload: scale table shape "
                         f"{scales.shape} != {(n_segs, chans)}")
    out = np.empty(qf.shape, np.float32)
    sl: list[slice] = [slice(None)] * qf.ndim
    for s in range(n_segs):
        sl[axis] = slice(s * seg, (s + 1) * seg)
        out[tuple(sl)] = qf[tuple(sl)] * scales[s]
    return out.reshape(orig_shape).astype(dtype)


def _pack_int4(q: np.ndarray) -> bytes:
    """[-7, 7] codes -> two offset nibbles per byte (odd tails padded)."""
    flat = (q.reshape(-1).astype(np.int16) + 8).astype(np.uint8)
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.uint8)])
    return (flat[0::2] | (flat[1::2] << 4)).tobytes()


def _unpack_int4(data: bytes, size: int) -> np.ndarray:
    if len(data) != (size + 1) // 2:
        raise ValueError("corrupt codec payload: truncated int4 codes")
    b = np.frombuffer(data, np.uint8)
    out = np.empty(b.size * 2, np.int8)
    out[0::2] = (b & 0x0F).astype(np.int16) - 8
    out[1::2] = (b >> 4).astype(np.int16) - 8
    return out[:size]


@dataclass(frozen=True)
class PayloadCodec:
    """How a KVC payload's bytes are produced.

    ``name``: ``"f32"`` (verbatim, the legacy ``SKYM`` wire format),
    ``"int8"`` or ``"int4"`` (symmetric per-channel quantization).
    ``block_tokens`` is the scale-table chunk along the token axis (0 =
    one table for the whole tensor) AND the block size delta chains are
    hashed at.  ``delta`` opts cumulative dense payloads into delta
    encoding -- it requires ``block_tokens`` so back-pointers can be
    recomputed from the token chain.  Decoding never needs a codec
    (payloads are self-describing); this object only shapes *encoding*
    and the router's bytes-per-token price model.
    """

    name: str = "f32"
    block_tokens: int = 0
    delta: bool = False

    def __post_init__(self) -> None:
        if self.name not in ("f32", "int8", "int4"):
            raise ValueError(f"unknown payload codec {self.name!r}")
        if self.delta and self.block_tokens <= 0:
            raise ValueError("delta encoding needs block_tokens > 0")

    @classmethod
    def parse(cls, spec, block_tokens: int = 0) -> "PayloadCodec":
        """``None`` / ``"f32"`` / ``"int8"`` / ``"int4"`` / ``"int8+delta"``
        / ``"int4+delta"`` / a ready ``PayloadCodec`` -> a codec whose
        chunked scale tables (and delta hashing) use ``block_tokens``."""
        if isinstance(spec, cls):
            return spec
        if spec is None:
            spec = "f32"
        base, _, suffix = spec.partition("+")
        if suffix not in ("", "delta"):
            raise ValueError(f"unknown payload codec {spec!r}")
        return cls(base, block_tokens, delta=suffix == "delta")

    @property
    def quantized(self) -> bool:
        return self.name != "f32"

    def bytes_per_value(self, src_itemsize: int) -> float:
        """Encoded payload bytes per stored value -- the router's
        codec-derived size model (scale tables and headers are noise at
        KVC payload sizes and are deliberately not modeled)."""
        if self.name == "int8":
            return 1.0
        if self.name == "int4":
            return 0.5
        return float(src_itemsize)

    def encode(self, arrays: list[np.ndarray]) -> bytes:
        return encode_arrays(arrays, self)


def encode_arrays(arrays: list[np.ndarray],
                  codec: PayloadCodec) -> bytes:
    """Serialize ``arrays`` under ``codec``.  ``f32`` emits the legacy
    ``SKYM`` format byte-for-byte; quantized codecs emit a ``SKYC``
    container recording the codec id and, per array, the source dtype,
    shape, and per-chunk scale table.  Integer/bool arrays (e.g. an
    already-int8 device pool's pages) are always stored verbatim --
    re-quantizing quantized codes would corrupt them."""
    if not codec.quantized:
        return arrays_to_bytes(arrays)
    qmax = _QMAX[codec.name]
    parts = [_CODEC_MAGIC,
             struct.pack("<HBB", _CODEC_VERSION, _KIND_ENC,
                         _CODEC_IDS[codec.name]),
             struct.pack("<I", len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        dt = _dtype_name(a.dtype)
        parts.append(struct.pack("<B", len(dt)))
        parts.append(dt)
        parts.append(struct.pack("<B", a.ndim))
        parts.append(struct.pack(f"<{a.ndim}q", *a.shape))
        if a.dtype.kind in "iub":
            raw = a.tobytes()
            parts.append(struct.pack("<B", _STORE_RAW))
            parts.append(struct.pack("<q", len(raw)))
            parts.append(raw)
            continue
        q, scales = _quantize_segmented(a, qmax, codec.block_tokens)
        body = (_pack_int4(q) if codec.name == "int4" else q.tobytes())
        parts.append(struct.pack("<B", _STORE_Q))
        parts.append(struct.pack("<ii", codec.block_tokens, scales.shape[0]))
        parts.append(scales.tobytes())
        parts.append(struct.pack("<q", len(body)))
        parts.append(body)
    return b"".join(parts)


def _codec_kind(data: bytes) -> int | None:
    """SKYC container kind, or None for anything else (incl. SKYM)."""
    if len(data) < 7 or data[:4] != _CODEC_MAGIC:
        return None
    ver, kind = struct.unpack_from("<HB", data, 4)
    if ver != _CODEC_VERSION:
        raise ValueError(f"unsupported KVC codec version {ver}")
    return kind


def _decode_enc(data: bytes) -> list[np.ndarray]:
    out: list[np.ndarray] = []
    try:
        codec_id, = struct.unpack_from("<B", data, 7)
        name = _CODEC_NAMES.get(codec_id)
        if name is None:
            raise ValueError(f"unknown KVC codec id {codec_id}")
        n, = struct.unpack_from("<I", data, 8)
        off = 12
        for _ in range(n):
            (dlen,) = struct.unpack_from("<B", data, off)
            off += 1
            dt = _dtype_from_name(data[off:off + dlen].decode())
            off += dlen
            (ndim,) = struct.unpack_from("<B", data, off)
            off += 1
            shape = struct.unpack_from(f"<{ndim}q", data, off)
            off += 8 * ndim
            (store,) = struct.unpack_from("<B", data, off)
            off += 1
            if store == _STORE_RAW:
                (rlen,) = struct.unpack_from("<q", data, off)
                off += 8
                if off + rlen > len(data):
                    raise ValueError("truncated")
                a = np.frombuffer(data[off:off + rlen], dtype=dt)
                out.append(a.reshape(shape))
                off += rlen
                continue
            if store != _STORE_Q:
                raise ValueError(f"unknown storage tag {store}")
            seg, n_segs = struct.unpack_from("<ii", data, off)
            off += 8
            chans = shape[-1] if ndim else 1
            slen = 4 * n_segs * chans
            if n_segs < 1 or off + slen > len(data):
                raise ValueError("truncated")
            scales = np.frombuffer(
                data[off:off + slen], np.float32).reshape(n_segs, chans)
            off += slen
            (qlen,) = struct.unpack_from("<q", data, off)
            off += 8
            if off + qlen > len(data):
                raise ValueError("truncated")
            size = int(np.prod(shape, dtype=np.int64)) if ndim else 1
            if name == "int4":
                q = _unpack_int4(data[off:off + qlen], size)
            else:
                if qlen != size:
                    raise ValueError("truncated")
                q = np.frombuffer(data[off:off + qlen], np.int8)
            off += qlen
            out.append(_dequantize_segmented(
                q.reshape(shape), scales, seg, dt))
    except struct.error as e:
        raise ValueError(f"corrupt codec payload: {e}") from e
    return out


# -- delta containers (O(1)-byte cumulative chains) -------------------------

def make_delta_payload(inner: bytes, prev_hash: bytes,
                       prev_tokens: int) -> bytes:
    """Wrap ``inner`` (this block's *own* tokens, already encoded) with a
    back-pointer: the previous block's hash and how many tokens its
    cumulative payload covers."""
    return b"".join([
        _CODEC_MAGIC, struct.pack("<HB", _CODEC_VERSION, _KIND_DELTA),
        struct.pack("<B", len(prev_hash)), prev_hash,
        struct.pack("<q", prev_tokens), inner,
    ])


def is_delta_payload(data: bytes) -> bool:
    return _codec_kind(data) == _KIND_DELTA


def delta_info(data: bytes) -> tuple[bytes, int, bytes]:
    """``(prev_hash, prev_tokens, inner_payload)`` of a delta payload."""
    if _codec_kind(data) != _KIND_DELTA:
        raise ValueError("not a delta payload")
    try:
        (hlen,) = struct.unpack_from("<B", data, 7)
        prev_hash = data[8:8 + hlen]
        if len(prev_hash) != hlen:
            raise ValueError("corrupt delta payload: truncated hash")
        (prev_tokens,) = struct.unpack_from("<q", data, 8 + hlen)
    except struct.error as e:
        raise ValueError(f"corrupt delta payload: {e}") from e
    return prev_hash, prev_tokens, data[16 + hlen:]


# -- cat containers (reassembled cumulative prefixes) -----------------------

def cat_payloads(parts: list[bytes]) -> bytes:
    """Concatenation container: an ordered list of payloads (a cumulative
    base followed by delta segments) whose decoded arrays concatenate
    along the token axis.  Nested cats flatten; a single segment returns
    itself (no wrapper)."""
    segs: list[bytes] = []
    for p in parts:
        segs.extend(split_cat_payload(p) if is_cat_payload(p) else [p])
    if not segs:
        raise ValueError("cat of zero payloads")
    if len(segs) == 1:
        return segs[0]
    out = [_CODEC_MAGIC, struct.pack("<HB", _CODEC_VERSION, _KIND_CAT),
           struct.pack("<I", len(segs))]
    for s in segs:
        out.append(struct.pack("<q", len(s)))
        out.append(s)
    return b"".join(out)


def is_cat_payload(data: bytes) -> bool:
    return _codec_kind(data) == _KIND_CAT


def split_cat_payload(data: bytes) -> list[bytes]:
    if _codec_kind(data) != _KIND_CAT:
        raise ValueError("not a cat payload")
    segs: list[bytes] = []
    try:
        n, = struct.unpack_from("<I", data, 7)
        off = 11
        for _ in range(n):
            (slen,) = struct.unpack_from("<q", data, off)
            off += 8
            if slen < 0 or off + slen > len(data):
                raise ValueError("corrupt cat payload: truncated segment")
            segs.append(data[off:off + slen])
            off += slen
    except struct.error as e:
        raise ValueError(f"corrupt cat payload: {e}") from e
    return segs


# -- the one decoder every tier calls ---------------------------------------

def decode_payload_arrays(data: bytes) -> list[np.ndarray]:
    """Decode ANY payload this module can emit back to arrays: legacy
    ``SKYM``, quantized ``SKYC`` containers (source dtype restored), a
    bare delta segment (its own tokens only), or a cat container (the
    segments' arrays concatenated position-wise along the token axis)."""
    kind = _codec_kind(data)
    if kind is None:
        return bytes_to_arrays(data)
    if kind == _KIND_ENC:
        return _decode_enc(data)
    if kind == _KIND_DELTA:
        return decode_payload_arrays(delta_info(data)[2])
    if kind == _KIND_CAT:
        seg_arrays = [decode_payload_arrays(s)
                      for s in split_cat_payload(data)]
        n = len(seg_arrays[0])
        if any(len(sa) != n for sa in seg_arrays):
            raise ValueError("corrupt cat payload: ragged segments")
        out = []
        for i in range(n):
            pieces = [sa[i] for sa in seg_arrays]
            axis = 1 if pieces[0].ndim >= 3 else 0
            out.append(np.concatenate(pieces, axis=axis))
        return out
    raise ValueError(f"unknown KVC container kind {kind}")


def payload_raw_bytes(data: bytes) -> int:
    """Dtype-true bytes ``data`` decodes to -- a header-only scan (bodies
    are skipped, nothing dequantizes), so Set/Get paths can account
    ``bytes_raw`` vs ``bytes_encoded`` per block at negligible cost.
    Best-effort: anything unparseable (the fabric also stores opaque
    test bytes) counts at face value instead of raising."""
    try:
        return _payload_raw_bytes(data)
    except (ValueError, IndexError, UnicodeDecodeError, struct.error):
        return len(data)


def _payload_raw_bytes(data: bytes) -> int:
    kind = _codec_kind(data)
    if kind == _KIND_DELTA:
        return payload_raw_bytes(delta_info(data)[2])
    if kind == _KIND_CAT:
        return sum(payload_raw_bytes(s) for s in split_cat_payload(data))
    total = 0
    if kind == _KIND_ENC:
        n, = struct.unpack_from("<I", data, 8)
        off = 12
        for _ in range(n):
            (dlen,) = struct.unpack_from("<B", data, off)
            off += 1
            dt = _dtype_from_name(data[off:off + dlen].decode())
            off += dlen
            (ndim,) = struct.unpack_from("<B", data, off)
            off += 1
            shape = struct.unpack_from(f"<{ndim}q", data, off)
            off += 8 * ndim
            (store,) = struct.unpack_from("<B", data, off)
            off += 1
            size = int(np.prod(shape, dtype=np.int64)) if ndim else 1
            total += size * dt.itemsize
            if store == _STORE_RAW:
                (rlen,) = struct.unpack_from("<q", data, off)
                off += 8 + rlen
            else:
                seg, n_segs = struct.unpack_from("<ii", data, off)
                off += 8 + 4 * n_segs * (shape[-1] if ndim else 1)
                (qlen,) = struct.unpack_from("<q", data, off)
                off += 8 + qlen
        return total
    if data[:4] == _MAGIC:
        _, n = struct.unpack_from("<HI", data, 4)
        off = 10
        for _ in range(n):
            (dlen,) = struct.unpack_from("<B", data, off)
            off += 1 + dlen
            (ndim,) = struct.unpack_from("<B", data, off)
            off += 1 + 8 * ndim
            (rlen,) = struct.unpack_from("<q", data, off)
            off += 8 + rlen
            total += rlen
        return total
    return len(data)
