"""Chunking + KVC (de)serialization (paper §3.1).

A block's KV-cache payload (several MB even for small models) is split into
fixed-byte chunks; chunk ``i`` maps to virtual server ``i mod num_servers``.
A failed lookup of any single chunk means the block is absent.

Also provides the byte serialization of a KVC block payload -- a list of
numpy arrays (K and V per layer, or SSM state tensors) -- plus the optional
int8 quantization the paper's testbed used (optimum-quanto / HQQ 8-bit).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

_MAGIC = b"SKYM"
_VERSION = 1


def split_chunks(data: bytes, chunk_bytes: int) -> list[bytes]:
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    if not data:
        return [b""]
    return [data[i : i + chunk_bytes] for i in range(0, len(data), chunk_bytes)]


def num_chunks(total_bytes: int, chunk_bytes: int) -> int:
    if total_bytes == 0:
        return 1
    return -(-total_bytes // chunk_bytes)


def join_chunks(chunks: list[bytes]) -> bytes:
    return b"".join(chunks)


def chunk_server(chunk_id: int, num_servers: int) -> int:
    """Virtual server (0-based) for a chunk: chunk_id mod n (paper §3.1).

    This is *replica 0*'s placement.  Under k-replica placement the
    other copies keep the same virtual server but live on satellites
    offset from its home by ``replica_delta`` -- replication changes
    where copies sit on the torus, never which server owns a chunk.
    """
    return chunk_id % num_servers


def replica_delta(
    replica: int, num_planes: int, sats_per_plane: int
) -> tuple[int, int]:
    """Torus offset ``(d_plane, d_slot)`` of replica ``replica``'s home
    satellite from the chunk's base (replica-0) server satellite.

    Replicas walk plane-first: replica ``r`` sits ``r`` planes east of
    the base until the planes are exhausted, then spills one slot south
    and keeps walking planes.  Consequences, both load-bearing for fault
    tolerance:

    * **plane diversity** whenever ``k <= num_planes`` -- every replica
      of a chunk is in a *different orbital plane*, so a whole-plane
      outage (the correlated failure mode: one launch batch, one plane)
      never takes out more than one copy;
    * **distinct satellites** whenever ``k <= num_planes *
      sats_per_plane`` -- no two replicas of a chunk ever share a
      satellite (the placement property the chaos tests check).
    """
    if replica < 0:
        raise ValueError("replica index must be >= 0")
    return replica % num_planes, replica // num_planes


# ---------------------------------------------------------------------------
# KVC payload serialization.
# ---------------------------------------------------------------------------

def _dtype_name(dt: np.dtype) -> bytes:
    """Stable dtype tag; extended floats (bfloat16, ...) go by name since
    their numpy .str is an opaque void type."""
    if dt.kind == "V" or dt.str.startswith("|V"):
        return dt.name.encode()
    return dt.str.encode()


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered by jax

        return np.dtype(getattr(ml_dtypes, name))


def arrays_to_bytes(arrays: list[np.ndarray]) -> bytes:
    """Serialize a list of arrays: magic | version | n | per-array header."""
    parts = [_MAGIC, struct.pack("<HI", _VERSION, len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        dt = _dtype_name(a.dtype)
        parts.append(struct.pack("<B", len(dt)))
        parts.append(dt)
        parts.append(struct.pack("<B", a.ndim))
        parts.append(struct.pack(f"<{a.ndim}q", *a.shape))
        raw = a.tobytes()
        parts.append(struct.pack("<q", len(raw)))
        parts.append(raw)
    return b"".join(parts)


def bytes_to_arrays(data: bytes) -> list[np.ndarray]:
    if data[:4] != _MAGIC:
        raise ValueError("not a SkyMemory KVC payload")
    ver, n = struct.unpack_from("<HI", data, 4)
    if ver != _VERSION:
        raise ValueError(f"unsupported KVC payload version {ver}")
    off = 10
    out: list[np.ndarray] = []
    for _ in range(n):
        (dlen,) = struct.unpack_from("<B", data, off)
        off += 1
        dt = _dtype_from_name(data[off : off + dlen].decode())
        off += dlen
        (ndim,) = struct.unpack_from("<B", data, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", data, off)
        off += 8 * ndim
        (rlen,) = struct.unpack_from("<q", data, off)
        off += 8
        a = np.frombuffer(data[off : off + rlen], dtype=dt).reshape(shape)
        off += rlen
        out.append(a)
    return out


# ---------------------------------------------------------------------------
# int8 KVC quantization (paper §5 used 8-bit quantized KVC blocks).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantizedArray:
    q: np.ndarray       # int8 values
    scale: np.ndarray   # per-last-axis-channel float32 scale


def quantize_int8(a: np.ndarray) -> QuantizedArray:
    """Symmetric per-channel (last axis) int8 quantization."""
    a = np.asarray(a, dtype=np.float32)
    amax = np.max(np.abs(a), axis=tuple(range(a.ndim - 1)), keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(a / scale), -127, 127).astype(np.int8)
    return QuantizedArray(q=q, scale=scale)


def dequantize_int8(qa: QuantizedArray) -> np.ndarray:
    return qa.q.astype(np.float32) * qa.scale


def quantized_to_bytes(arrays: list[np.ndarray]) -> bytes:
    flat: list[np.ndarray] = []
    for a in arrays:
        qa = quantize_int8(a)
        flat.append(qa.q)
        flat.append(qa.scale)
    return arrays_to_bytes(flat)


def bytes_to_dequantized(data: bytes) -> list[np.ndarray]:
    flat = bytes_to_arrays(data)
    if len(flat) % 2:
        raise ValueError("corrupt quantized payload")
    out = []
    for i in range(0, len(flat), 2):
        out.append(dequantize_int8(QuantizedArray(q=flat[i], scale=flat[i + 1])))
    return out
