import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: jit with
explicit in/out shardings over the production mesh, ``.lower().compile()``
must succeed, and the compiled artifact yields the roofline terms
(EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --arch yi-9b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every combo, both meshes
  python -m repro.launch.dryrun --all --resume   # skip combos already done

Skips (DESIGN.md §4): seamless-m4t-large-v2 x long_500k (encoder-decoder
with no windowed encoder variant).
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.roofline import build_roofline
from repro.launch.specs import lower_plan, make_plan
from repro.models.config import INPUT_SHAPES

SKIPS: set[tuple[str, str]] = {
    ("seamless-m4t-large-v2", "long_500k"),
}
DEFAULT_OUT = "benchmarks/results/dryrun"


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    remat: str | None = "full",
    fsdp: bool | None = None,
    seq_shard: bool | None = None,
    shard_kv_heads: bool = True,
    seq_parallel_acts: bool = False,
    grad_accum: int = 1,
    moe_group_size: int = 0,
    capacity_factor: float = 0.0,
    kvc_int8: bool = False,
    attn_tp: bool | None = None,
    bf16_moments: bool = False,
    verbose: bool = True,
) -> dict:
    """Lower + compile one (arch x shape x mesh) combo.

    Two-part measurement (see launch/probe.py): the full-depth *scanned*
    program is the deployable artifact and provides memory_analysis; tiny
    unrolled probe variants provide exact per-layer flops/bytes/collective
    costs (scan bodies are cost-counted once), combined linearly.
    """
    from repro.launch.probe import extract_metrics, probe_set, solve_linear
    from repro.launch.roofline import (
        Roofline, model_flops, streaming_attn_correction,
    )

    cfg = get_config(arch)
    if moe_group_size:
        cfg = cfg.replace(moe_group_size=moe_group_size)
    if capacity_factor:
        cfg = cfg.replace(capacity_factor=capacity_factor)
    if kvc_int8:
        cfg = cfg.replace(kvc_dtype="int8")
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = 512 if multi_pod else 256
    rules = make_rules(mesh, cfg, shape, fsdp=fsdp, seq_shard=seq_shard,
                       shard_kv_heads=shard_kv_heads,
                       seq_parallel_acts=seq_parallel_acts, attn_tp=attn_tp)
    opt = None
    if bf16_moments:
        from repro.training.optimizer import AdamWConfig
        opt = AdamWConfig(moment_dtype="bfloat16")
    t0 = time.perf_counter()
    with mesh:
        # 1) full-depth scanned program (the deployable one): must compile.
        plan = make_plan(cfg, shape, rules, remat=remat, unroll=False,
                         grad_accum=grad_accum, opt=opt)
        lowered = lower_plan(plan)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        t_full = time.perf_counter() - t0
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] {plan.name}")
            print(f"  memory_analysis: {mem}")

        # 2) per-layer cost probes (tiny unrolled variants).
        pset = probe_set(cfg)
        measured = []
        for overrides, _counts in pset.variants:
            pcfg = cfg.replace(**overrides)
            pplan = make_plan(pcfg, shape, rules, remat=remat, unroll=True,
                              grad_accum=grad_accum, opt=opt)
            pcompiled = lower_plan(pplan).compile()
            measured.append(extract_metrics(pcompiled))
        solved = solve_linear(pset, measured)
        t_probe = time.perf_counter() - t0 - t_full
        if verbose:
            print(f"  cost (probed): flops={solved['flops']:.3e} "
                  f"bytes={solved['bytes']:.3e} "
                  f"coll={solved['collective_bytes']:.3e}")

    corr = streaming_attn_correction(plan.cfg, shape, remat) / n_chips
    roof = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, step=plan.name,
        flops_per_device=solved["flops"] + corr,
        bytes_per_device=solved["bytes"],
        collective_bytes=solved["collective_bytes"],
        collectives={k[5:]: v for k, v in solved.items()
                     if k.startswith("coll:")},
        peak_memory_bytes=float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        ),
        argument_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
        model_flops=model_flops(plan.cfg, shape),
    )
    rec = roof.to_dict()
    rec.update(
        full_compile_s=round(t_full, 1),
        probe_compile_s=round(t_probe, 1),
        remat=remat,
        fsdp=rules.fsdp,
        seq_shard=rules.seq_shard_cache,
        shard_kv_heads=rules.shard_kv_heads,
        seq_parallel_acts=rules.seq_parallel_acts,
        grad_accum=grad_accum,
        moe_group_size=moe_group_size or cfg.moe_group_size,
        kvc_int8=kvc_int8,
        attn_tp=rules.attn_tp,
        gqa_grouped=os.environ.get("REPRO_GQA_GROUPED", "0") == "1",
        status="ok",
    )
    if verbose:
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} "
              f"useful={roof.useful_flops_ratio:.2f}")
        print(f"  peak {roof.peak_memory_bytes/2**30:.2f} GiB/device "
              f"(full {t_full:.0f}s probes {t_probe:.0f}s)")
    return rec


def _result_path(out_dir, arch, shape, mesh_name):
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=list(INPUT_SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--resume", action="store_true",
                   help="skip combos whose result JSON already exists")
    p.add_argument("--remat", default="full",
                   choices=["none", "dots", "dots_no_batch", "full"])
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--seq-shard", action="store_true", default=None)
    p.add_argument("--no-shard-kv", action="store_true")
    p.add_argument("--seq-parallel", action="store_true")
    p.add_argument("--grad-accum", type=int, default=1)
    p.add_argument("--moe-group-size", type=int, default=0)
    p.add_argument("--capacity-factor", type=float, default=0.0)
    p.add_argument("--kvc-int8", action="store_true")
    p.add_argument("--attn-tp", action="store_true", default=None)
    p.add_argument("--bf16-moments", action="store_true")
    p.add_argument("--out", default=DEFAULT_OUT)
    p.add_argument("--tag", default="", help="suffix for result files")
    args = p.parse_args(argv)

    remat = None if args.remat == "none" else args.remat
    os.makedirs(args.out, exist_ok=True)
    assert len(jax.devices()) >= 512, "dry-run needs 512 host devices"

    combos: list[tuple[str, str, bool]] = []
    if args.all:
        arch_list = [args.arch] if args.arch else ARCH_IDS
        if "skymemory-tinyllama" in arch_list and not args.arch:
            arch_list = [a for a in arch_list if a != "skymemory-tinyllama"]
        for arch in arch_list:
            for shape in INPUT_SHAPES:
                if (arch, shape) in SKIPS:
                    continue
                combos.append((arch, shape, False))
                combos.append((arch, shape, True))
    else:
        if not (args.arch and args.shape):
            p.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, mp in combos:
        mesh_name = ("2x16x16" if mp else "16x16") + (
            f"__{args.tag}" if args.tag else "")
        path = _result_path(args.out, arch, shape, mesh_name)
        if args.resume and os.path.exists(path):
            continue
        try:
            rec = run_one(
                arch, shape, multi_pod=mp, remat=remat,
                fsdp=False if args.no_fsdp else None,
                seq_shard=args.seq_shard,
                shard_kv_heads=not args.no_shard_kv,
                seq_parallel_acts=args.seq_parallel,
                grad_accum=args.grad_accum,
                moe_group_size=args.moe_group_size,
                capacity_factor=args.capacity_factor,
                kvc_int8=args.kvc_int8,
                attn_tp=args.attn_tp,
                bf16_moments=args.bf16_moments,
            )
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": f"error: {type(e).__name__}: {e}"}
            failures += 1
        rec["tag"] = args.tag
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
