"""Training launcher.

On real hardware this drives the pjit train step over the production mesh;
on this CPU container it runs the same code single-device (use --mesh to
request a device mesh when one exists).

  PYTHONPATH=src python -m repro.launch.train --arch skymemory-tinyllama \
      --steps 100 --seq 256 --batch 4 --tiny
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models.model import Model
from repro.training import (
    AdamWConfig,
    DataConfig,
    TrainConfig,
    make_dataset,
    save_checkpoint,
    train,
)


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="skymemory-tinyllama")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--remat", default=None,
                   choices=[None, "full", "dots", "dots_no_batch"])
    p.add_argument("--tiny", action="store_true",
                   help="reduced same-family config (CPU-friendly)")
    p.add_argument("--data", default=None, help="optional text corpus path")
    p.add_argument("--ckpt", default=None)
    p.add_argument("--mesh", action="store_true",
                   help="use a (data, model) mesh over available devices")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = smoke_config(cfg)
    cfg = cfg.replace(dtype="float32")
    model = Model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps}")

    rules = None
    if args.mesh:
        from repro.launch.mesh import make_rules
        from repro.models.config import InputShape

        n = len(jax.devices())
        dm = max(n // 2, 1)
        mesh = jax.make_mesh((n // dm, dm), ("data", "model"))
        rules = make_rules(mesh, cfg,
                           InputShape("train", args.seq, args.batch, "train"))

    ds = make_dataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        path=args.data, d_model=cfg.d_model,
        num_image_tokens=cfg.num_image_tokens,
        is_encoder_decoder=cfg.is_encoder_decoder, arch_type=cfg.arch_type,
    ))
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
        remat=args.remat,
        log_every=max(args.steps // 20, 1),
    )
    params, opt, hist = train(
        model, ds, tcfg, num_steps=args.steps, rules=rules,
        log_fn=lambda s, m: print(
            f"step {s:5d} loss={m['loss']:.4f} lr={m['lr']:.2e} "
            f"gnorm={m['grad_norm']:.2f} ({m['elapsed_s']:.0f}s)"
        ),
    )
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt, step=args.steps,
                        metadata={"arch": cfg.name})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
