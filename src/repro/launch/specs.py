"""ShapeDtypeStruct input specs + step builders for every (arch x shape).

No device allocation anywhere: specs feed ``jit(...).lower()`` in the
dry-run, and the same builders drive the real train/serve launchers when
actual devices exist.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import shape_variant
from repro.distributed.sharding import (
    AxisRules,
    cache_specs,
    param_specs,
    use_rules,
)
from repro.models.config import InputShape, ModelConfig
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass
class StepPlan:
    """A lowered-able step: fn(*args), arg specs, and shardings."""

    name: str
    fn: Callable
    args: tuple            # ShapeDtypeStructs (pytrees)
    in_shardings: Any
    out_shardings: Any
    model: Model
    cfg: ModelConfig
    donate_argnums: tuple = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Model-input ShapeDtypeStructs for one assigned input shape."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            half = s // 2
            return {
                "tokens": _sds((b, half), jnp.int32),
                "targets": _sds((b, half), jnp.int32),
                "frames": _sds((b, half, cfg.d_model), dt),
            }
        if cfg.arch_type == "vlm":
            s_text = s - cfg.num_image_tokens
            return {
                "tokens": _sds((b, s_text), jnp.int32),
                "targets": _sds((b, s_text), jnp.int32),
                "image_embeds": _sds((b, cfg.num_image_tokens, cfg.d_model), dt),
            }
        return {
            "tokens": _sds((b, s), jnp.int32),
            "targets": _sds((b, s), jnp.int32),
        }
    # decode: ONE new token over a cache of seq_len
    return {"tokens": _sds((b, 1), jnp.int32)}


def _params_shardings(model: Model, rules: AxisRules):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, rules)
    return shapes, jax.tree.map(
        lambda sp: NamedSharding(rules.mesh, sp), specs,
        is_leaf=lambda s: isinstance(s, P),
    )


def make_plan(
    cfg: ModelConfig,
    shape: InputShape,
    rules: AxisRules,
    *,
    remat: str | None = "dots",
    opt: AdamWConfig | None = None,
    unroll: bool = True,
    grad_accum: int = 1,
) -> StepPlan:
    """Build the (train|prefill|serve) step for an (arch x shape) combo.

    ``unroll=True`` (dry-run default) unrolls layer scans so XLA cost
    analysis counts every layer -- scan bodies are otherwise costed once.
    ``grad_accum``: split the global batch into microbatches with gradient
    accumulation (train only) -- the activation-memory lever.
    """
    cfg = shape_variant(cfg, shape)
    model = Model(cfg, unroll=unroll)
    mesh = rules.mesh
    repl = NamedSharding(mesh, P())
    batch_sh = NamedSharding(mesh, P(rules.data))
    pshapes, psh = _params_shardings(model, rules)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = opt or AdamWConfig()
        oshapes = jax.eval_shape(
            lambda q: init_opt_state(q, opt.moment_dtype), pshapes)
        osh = {
            "m": psh, "v": psh,
            "step": repl,
        }

        def train_step(params, opt_state, batch):
            with use_rules(rules):
                if grad_accum <= 1:
                    def loss_fn(p):
                        return model.train_loss(p, batch, remat=remat)

                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params)
                else:
                    # microbatched gradient accumulation
                    def reshape(x):
                        return x.reshape(
                            (grad_accum, x.shape[0] // grad_accum)
                            + x.shape[1:])

                    micro = {k: reshape(v) for k, v in batch.items()}

                    def body(acc, mb):
                        (loss, metrics), g = jax.value_and_grad(
                            lambda p: model.train_loss(p, mb, remat=remat),
                            has_aux=True,
                        )(params)
                        acc = jax.tree.map(
                            lambda a, b: a + b.astype(a.dtype) / grad_accum,
                            acc, g)
                        return acc, metrics

                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    grads, ms = jax.lax.scan(
                        body, zeros, micro, unroll=unroll or 1)
                    metrics = jax.tree.map(lambda m: m[-1], ms)
                params, opt_state, om = adamw_update(
                    opt, params, grads, opt_state)
            return params, opt_state, {**metrics, **om}

        bsh = {k: batch_sh for k in specs}
        return StepPlan(
            name="train_step", fn=train_step,
            args=(pshapes, oshapes, specs),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            model=model, cfg=cfg,
            donate_argnums=(0, 1),      # params + optimizer state
        )

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            with use_rules(rules):
                logits, _, state = model.forward(
                    params, batch["tokens"],
                    image_embeds=batch.get("image_embeds"),
                    frames=batch.get("frames"),
                    collect_state=True,
                    sliding_window=cfg.sliding_window or None,
                )
            return logits[:, -1:], state

        bsh = {k: batch_sh for k in specs if k != "targets"}
        specs_p = {k: v for k, v in specs.items() if k != "targets"}
        return StepPlan(
            name="prefill_step", fn=prefill_step,
            args=(pshapes, specs_p),
            in_shardings=(psh, bsh),
            out_shardings=None,
            model=model, cfg=cfg,
        )

    # decode
    b, s = shape.global_batch, shape.seq_len
    src_len = (s // 2) if cfg.is_encoder_decoder else None
    cache_shapes = model.init_cache(b, s, specs_only=True, src_len=src_len)
    cspecs = cache_specs(cache_shapes, rules, batch=b)
    csh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tok_sh = batch_sh if b >= rules.axis_size(rules.data_axes) else repl

    def serve_step(params, cache, tokens, pos):
        with use_rules(rules):
            logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache

    return StepPlan(
        name="serve_step", fn=serve_step,
        args=(pshapes, cache_shapes, specs["tokens"],
              _sds((), jnp.int32)),
        in_shardings=(psh, csh, tok_sh, repl),
        out_shardings=(None, csh),
        model=model, cfg=cfg,
        donate_argnums=(1,),            # cache updates in place
    )


def lower_plan(plan: StepPlan):
    jitted = jax.jit(
        plan.fn,
        in_shardings=plan.in_shardings,
        out_shardings=plan.out_shardings,
        donate_argnums=plan.donate_argnums,
    )
    return jitted.lower(*plan.args)
