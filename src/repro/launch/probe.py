"""Linear cost probing: exact per-layer HLO costs without unrolling 96 layers.

XLA cost analysis counts a lax.scan body once, and fully unrolling a 96-layer
model makes single-core compiles prohibitive.  Both problems disappear with a
linear model: every metric (flops, bytes, per-type collective traffic) is

    metric = outside + sum_t  n_t * per_layer_t

over the architecture's layer types t (dense block, moe block, mamba block,
shared-attn block, encoder block, decoder block).  We compile 2-3 *tiny
unrolled* variants (1-2 layers, full d_model and batch), measure each, and
solve for (outside, per_layer_t) exactly.  The full-depth scanned compile is
still produced -- it is the deployable program and supplies the memory
analysis -- but its once-counted flops are replaced by the solved model.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.launch.roofline import parse_collectives
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ProbeSet:
    var_names: tuple[str, ...]           # layer-type variables
    full_counts: dict[str, int]          # counts in the real config
    variants: tuple[tuple[dict, dict], ...]  # (cfg overrides, counts)


def probe_set(cfg: ModelConfig) -> ProbeSet:
    if cfg.is_encoder_decoder:
        return ProbeSet(
            ("enc", "dec"),
            {"enc": cfg.num_encoder_layers, "dec": cfg.num_layers},
            (
                ({"num_encoder_layers": 1, "num_layers": 1},
                 {"enc": 1, "dec": 1}),
                ({"num_encoder_layers": 2, "num_layers": 1},
                 {"enc": 2, "dec": 1}),
                ({"num_encoder_layers": 1, "num_layers": 2},
                 {"enc": 1, "dec": 2}),
            ),
        )
    if cfg.arch_type == "hybrid" and cfg.attn_layer_period:
        n_attn = cfg.num_layers // cfg.attn_layer_period
        return ProbeSet(
            ("mamba", "attn"),
            {"mamba": cfg.num_layers, "attn": n_attn},
            (
                ({"num_layers": 2, "attn_layer_period": 0},
                 {"mamba": 2, "attn": 0}),
                ({"num_layers": 4, "attn_layer_period": 0},
                 {"mamba": 4, "attn": 0}),
                ({"num_layers": 2, "attn_layer_period": 2},
                 {"mamba": 2, "attn": 1}),
            ),
        )
    if cfg.use_mla and cfg.first_k_dense:
        n_moe = cfg.num_layers - cfg.first_k_dense
        return ProbeSet(
            ("dense", "moe"),
            {"dense": cfg.first_k_dense, "moe": n_moe},
            (
                ({"num_layers": 2, "first_k_dense": 1},
                 {"dense": 1, "moe": 1}),
                ({"num_layers": 3, "first_k_dense": 2},
                 {"dense": 2, "moe": 1}),
                ({"num_layers": 3, "first_k_dense": 1},
                 {"dense": 1, "moe": 2}),
            ),
        )
    # homogeneous stacks (dense / vlm / moe / ssm)
    return ProbeSet(
        ("block",),
        {"block": cfg.num_layers},
        (
            ({"num_layers": 1}, {"block": 1}),
            ({"num_layers": 2}, {"block": 2}),
        ),
    )


def extract_metrics(compiled) -> dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    colls = parse_collectives(compiled.as_text())
    m = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(sum(colls.values())),
    }
    for k, v in colls.items():
        m[f"coll:{k}"] = float(v)
    return m


def solve_linear(
    pset: ProbeSet, measured: list[dict[str, float]]
) -> dict[str, float]:
    """Solve metric = outside + sum_t n_t x_t for the full-depth counts."""
    nvar = len(pset.var_names)
    a = np.zeros((len(measured), nvar + 1))
    a[:, 0] = 1.0
    for i, (_, counts) in enumerate(pset.variants):
        for j, name in enumerate(pset.var_names):
            a[i, j + 1] = counts.get(name, 0)
    keys = sorted({k for m in measured for k in m})
    out: dict[str, float] = {}
    for key in keys:
        y = np.array([m.get(key, 0.0) for m in measured])
        sol, *_ = np.linalg.lstsq(a, y, rcond=None)
        total = sol[0] + sum(
            sol[j + 1] * pset.full_counts[name]
            for j, name in enumerate(pset.var_names)
        )
        out[key] = max(float(total), 0.0)
    return out
