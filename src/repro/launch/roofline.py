"""Roofline terms from a compiled dry-run artifact (no real hardware).

compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
memory term     = HLO_bytes / (chips x 819 GB/s HBM)
collective term = collective_bytes / (chips x 50 GB/s/link ICI)

``cost_analysis`` of an SPMD executable reports *per-partition* flops/bytes,
so the per-chip terms divide by the peak directly.  Collective bytes come
from parsing the post-SPMD HLO: per-partition result shapes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
converted to per-device link traffic with ring multipliers from the
replica-group size.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

# TPU v5e-class constants (brief).
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Per-device link-traffic bytes by collective type (ring estimates)."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-start" in line and "-done" not in line and False:
            continue
        if m is None:
            continue
        dtype, dims, op = m.groups()
        result_bytes = _shape_bytes(dtype, dims)
        n = _group_size(line)
        frac = (n - 1) / n if n > 1 else 0.0
        if op == "all-gather":
            traffic = result_bytes * frac
        elif op == "all-reduce":
            traffic = 2.0 * result_bytes * frac
        elif op == "reduce-scatter":
            traffic = result_bytes * (n - 1)
        elif op == "all-to-all":
            traffic = result_bytes * frac
        else:  # collective-permute
            traffic = float(result_bytes)
        out[op] = out.get(op, 0.0) + traffic
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # conservative default


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: dict = field(default_factory=dict)
    peak_memory_bytes: float = 0.0
    argument_bytes: float = 0.0
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs -- remat/redundancy waste probe."""
        n_chips = {"16x16": 256, "2x16x16": 512}.get(self.mesh, 256)
        total = self.flops_per_device * n_chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
        )
        return d


def streaming_attn_correction(cfg, shape, remat: str | None) -> float:
    """Global FLOPs that the HLO undercounts for the 32k+ prefill shapes.

    Long sequences route through the streaming (flash-style) jnp attention,
    whose kv-block lax.scan body is cost-counted once; the analytic
    correction restores the missing (nb-1)/nb of the attention matmul work.
    Decode shapes have no attention loop; <8k sequences use the naive path
    (fully counted in the unrolled graph).
    """
    from repro.kernels.ref import STREAMING_BLOCK_K, STREAMING_KV_THRESHOLD
    from repro.models import cache as cache_lib

    if shape.kind not in ("train", "prefill") or cfg.is_attention_free:
        return 0.0
    s = shape.seq_len // 2 if cfg.is_encoder_decoder else shape.seq_len
    if s < STREAMING_KV_THRESHOLD:
        return 0.0
    nb = -(-s // STREAMING_BLOCK_K)
    hd = cfg.head_dim
    if cfg.use_mla:
        hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    per_layer = 4.0 * shape.global_batch * cfg.num_heads * hd * float(s) ** 2
    n_attn = cache_lib.n_attn_layers(cfg)
    if cfg.is_encoder_decoder:
        # encoder self + decoder self + cross, all at s = seq/2
        n_attn = cfg.num_encoder_layers + 2 * cfg.num_layers
    fwd = per_layer * n_attn
    if shape.kind == "train":
        factor = {"full": 4.0, "dots": 3.0, "dots_no_batch": 3.0}.get(
            remat or "none", 3.0)
    else:
        factor = 1.0
    return fwd * factor * (nb - 1) / nb


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·new_tokens (decode)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def build_roofline(arch, shape, mesh_name, step, compiled, cfg,
                   remat: str | None = "dots") -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    n_chips = {"16x16": 256, "2x16x16": 512}.get(mesh_name, 256)
    corr = streaming_attn_correction(cfg, shape, remat) / n_chips
    return Roofline(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        step=step,
        flops_per_device=float(cost.get("flops", 0.0)) + corr,
        bytes_per_device=float(
            cost.get("bytes accessed", 0.0)
            or sum(v for k, v in cost.items()
                   if k.startswith("bytes accessed"))
        ),
        collective_bytes=float(sum(colls.values())),
        collectives={k: float(v) for k, v in colls.items()},
        peak_memory_bytes=float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        ),
        argument_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
        model_flops=model_flops(cfg, shape),
    )
