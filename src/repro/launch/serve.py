"""Serving launcher: batched generation with the SkyMemory prefix cache.

  PYTHONPATH=src python -m repro.launch.serve --arch skymemory-tinyllama \
      --tiny --prompt "hello" --repeat 3
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.core import (
    ConstellationKVC,
    ConstellationSpec,
    LosWindow,
    Sat,
    Strategy,
)
from repro.models.model import Model
from repro.serving import Engine, Request, SamplingParams


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="skymemory-tinyllama")
    p.add_argument("--tiny", action="store_true")
    p.add_argument("--prompt", default="SkyMemory caches KV blocks in orbit. ")
    p.add_argument("--repeat", type=int, default=3)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--strategy", default="rotation_hop",
                   choices=[s.value for s in Strategy])
    p.add_argument("--no-cache", action="store_true")
    p.add_argument("--planes", type=int, default=5)
    p.add_argument("--sats-per-plane", type=int, default=19)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = smoke_config(cfg).replace(dtype="float32")
    if cfg.is_encoder_decoder or cfg.arch_type == "vlm":
        raise SystemExit("serve launcher supports text-only archs; "
                         "see examples/ for frontends")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    kvc = None
    if not args.no_cache:
        spec = ConstellationSpec(args.planes, args.sats_per_plane, 550.0)
        kvc = ConstellationKVC(
            spec,
            LosWindow(Sat(args.planes // 2, args.sats_per_plane // 2), 5, 5),
            Strategy(args.strategy), num_servers=10, chunk_bytes=6 * 1024,
        )
    engine = Engine(model, params, kvc=kvc, block_size=128, max_seq_len=512)
    sp = SamplingParams(temperature=args.temperature,
                        max_new_tokens=args.max_new)
    for i in range(args.repeat):
        res = engine.generate([Request(prompt=args.prompt * 4, sampling=sp)])
        r = res[0]
        print(f"round {i}: cached={r.cached_tokens}/{r.prompt_tokens} tok "
              f"wall={r.wall_time_s:.2f}s out={r.text[:40]!r}")
    if kvc:
        print(f"cache: hits={kvc.stats.block_hits} "
              f"sets={kvc.stats.blocks_set} "
              f"messages={kvc.transport.stats.messages}")


if __name__ == "__main__":
    main()
