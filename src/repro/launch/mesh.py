"""Production meshes: 16x16 single-pod (256 chips) / 2x16x16 multi-pod.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state -- the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, and everything else sees the real single device.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import AxisRules
from repro.models.config import InputShape, ModelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(
    mesh,
    cfg: ModelConfig,
    shape: InputShape,
    *,
    fsdp: bool | None = None,
    seq_shard: bool | None = None,
    shard_kv_heads: bool = True,
    seq_parallel_acts: bool = False,
    attn_tp: bool | None = None,
) -> AxisRules:
    """Per-(arch, shape) axis rules (DESIGN.md §5).

    * train/prefill: batch over (pod, data), TP over model, FSDP params.
    * decode: batch over (pod, data); batch-1 long-context shards the KV
      cache *sequence* over data instead -- the SkyMemory chunk striping.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    if seq_shard is None:
        seq_shard = shape.is_decode and shape.global_batch < dsize
    if fsdp is None:
        fsdp = True
    # Decode stripes the cache sequence dim over the model axis (the
    # SkyMemory chunk striping), so the attention computation runs
    # sequence-parallel: attention weights keep all heads local by default
    # (override attn_tp=True to TP the projections and gather the tiny q
    # instead -- §Perf pair 3 iteration 4).
    if attn_tp is None:
        attn_tp = not shape.is_decode
    return AxisRules(
        mesh=mesh,
        data_axes=data_axes,
        model_axis="model",
        shard_kv_heads=shard_kv_heads,
        seq_shard_cache=seq_shard,
        fsdp=fsdp,
        attn_tp=attn_tp,
        seq_parallel_acts=seq_parallel_acts,
    )
