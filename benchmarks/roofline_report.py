"""Render the dry-run results as the EXPERIMENTS.md roofline tables.

Run after ``python -m repro.launch.dryrun --all``:
  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

RESULTS = "benchmarks/results/dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str = RESULTS) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        parts = os.path.basename(path)[:-5].split("__")
        rec.setdefault("tag", parts[3] if len(parts) > 3 else "")
        rows.append(rec)
    return rows


def _ms(x) -> str:
    return f"{x*1e3:10.2f}"


def table(rows: list[dict], mesh: str) -> str:
    rows = [r for r in rows if r.get("mesh") == mesh
            and r.get("status") == "ok" and not r.get("tag")]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = [
        f"### Roofline — mesh {mesh} "
        f"({512 if mesh.startswith('2x') else 256} chips)",
        "",
        "| arch | shape | step | compute(ms) | memory(ms) | coll(ms) | "
        "dominant | useful | peak GiB/dev |",
        "|---|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} |"
            f"{_ms(r['compute_s'])} |{_ms(r['memory_s'])} |"
            f"{_ms(r['collective_s'])} | {r['dominant']} |"
            f" {r['useful_flops_ratio']:.2f} |"
            f" {r['peak_memory_bytes']/2**30:.1f} |"
        )
    return "\n".join(out)


def failures(rows: list[dict]) -> list[str]:
    return [
        f"{r['arch']} x {r['shape']} x {r['mesh']}: {r['status']}"
        for r in rows if r.get("status") != "ok"
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--dir", default=RESULTS)
    args = ap.parse_args()
    rows = load(args.dir)
    meshes = [args.mesh] if args.mesh else ["16x16", "2x16x16"]
    for mesh in meshes:
        print(table(rows, mesh))
        print()
    bad = failures(rows)
    if bad:
        print("### Failures")
        for b in bad:
            print(" -", b)
    print(f"({len(rows)} results loaded)")


if __name__ == "__main__":
    main()


def remark(r: dict) -> str:
    """One sentence: what would move the dominant term down."""
    dom, step = r["dominant"], r["step"]
    if step == "train_step":
        if dom == "collective":
            return ("fuse/convert the per-block TP activation all-reduces "
                    "to bf16 reduce-scatter+all-gather (Megatron-SP) and "
                    "overlap FSDP weight gathers with compute")
        if dom == "memory":
            return ("cut op-level HBM traffic: flash-attention kernel "
                    "instead of streamed jnp softmax passes, fused "
                    "norm/residual, microbatching for resident activations")
        return "increase per-chip arithmetic intensity (larger microbatch)"
    if step == "prefill_step":
        if dom == "collective":
            return ("drop FSDP weight gathers for serving (resident TP "
                    "weights) and keep activations sequence-sharded")
        return ("flash prefill kernel (Pallas chunked_prefill) removes "
                "softmax round-trips to HBM")
    # serve_step
    if dom == "collective":
        return ("serve with resident (non-FSDP) weights; only the "
                "flash-decoding psums over the striped cache remain")
    return ("int8 KVC (paper's 8-bit trade-off) + grouped-GQA decode "
            "halve cache traffic; fuse the one-hot cache write")


def experiments_tables() -> str:
    rows = load()
    out = []
    for mesh in ("16x16", "2x16x16"):
        sel = [r for r in rows if r.get("mesh") == mesh
               and r.get("status") == "ok" and not r.get("tag")]
        sel.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
        out.append(f"### Roofline — mesh {mesh} "
                   f"({512 if mesh.startswith('2x') else 256} chips)\n")
        out.append("| arch | shape | compute(ms) | memory(ms) | coll(ms) | "
                   "dominant | useful | peak GiB/dev | to move the dominant "
                   "term down |")
        out.append("|---|---|---:|---:|---:|---|---:|---:|---|")
        for r in sel:
            out.append(
                f"| {r['arch']} | {r['shape']} |{_ms(r['compute_s'])} |"
                f"{_ms(r['memory_s'])} |{_ms(r['collective_s'])} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['peak_memory_bytes']/2**30:.1f} | {remark(r)} |")
        out.append("")
    return "\n".join(out)
